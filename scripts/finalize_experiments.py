"""Regenerate the §Roofline table inside EXPERIMENTS.md from results/dryrun."""

import re
import sys
from pathlib import Path

sys.path.insert(0, "src")

from benchmarks.roofline import fmt_table, load  # noqa: E402


def main():
    rows = load(Path("results/dryrun"))
    table = "```\n" + fmt_table(rows) + "\n```"
    p = Path("EXPERIMENTS.md")
    s = p.read_text()
    if "<!-- ROOFLINE_TABLE -->" in s:
        s = s.replace("<!-- ROOFLINE_TABLE -->", table)
    else:
        # replace a previously inserted table (between the §Roofline header
        # fence markers)
        s = re.sub(r"```\narch .*?\n```", table, s, count=1, flags=re.S)
    p.write_text(s)
    print(f"roofline table refreshed: {len(rows)} rows")


if __name__ == "__main__":
    main()
