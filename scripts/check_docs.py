#!/usr/bin/env python
"""Docs link check (CI gate): relative links and code-path references in
README.md and docs/*.md must resolve to files that actually exist.

Two classes of reference are validated:

1. **Markdown links** ``[text](target)`` whose target is relative (no URL
   scheme, not a pure ``#fragment``): the target path — resolved against
   the file containing the link — must exist.
2. **Code-path references**: any ``src/repro/...``, ``benchmarks/...``,
   ``tests/...``, ``examples/...`` or ``scripts/...`` path-like token
   (in backticks, tables, or prose) must point at an existing file or
   directory, so the paper→code map in docs/ARCHITECTURE.md can never
   silently rot as modules move.

Exit status 1 (with a listing) if any reference dangles. No third-party
dependencies — runs on a bare Python.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images is unnecessary; they must resolve too
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# path-like code references rooted at well-known repo directories
_CODE_REF = re.compile(
    r"\b((?:src/repro|benchmarks|tests|examples|scripts|docs)"
    r"(?:/[A-Za-z0-9_.\-]+)+)")
_SCHEME = re.compile(r"^[a-z][a-z0-9+.\-]*:", re.IGNORECASE)


def doc_files() -> list:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if _SCHEME.match(target) or target.startswith("#"):
            continue                      # external URL / in-page anchor
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(REPO)}: broken link ({target})")
    for m in _CODE_REF.finditer(text):
        ref = m.group(1).rstrip(".")
        if not (REPO / ref).exists():
            errors.append(
                f"{path.relative_to(REPO)}: dangling code reference ({ref})")
    return errors


def main() -> int:
    errors = []
    for f in doc_files():
        errors.extend(check_file(f))
    if errors:
        print(f"{len(errors)} dangling doc reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs ok: {len(doc_files())} files, all references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
