#!/usr/bin/env python
"""CI trace-smoke gate: tracing must observe, never perturb.

Runs the same small served query twice — tracing detached, then with a
``Tracer`` + ``MetricsRegistry`` attached — and asserts, in order:

1. **Byte-identity.** The traced run returns the identical count and
   incurs the identical measured ``block_reads`` (the ``BlockDevice``
   ledger) as the untraced run.
2. **Span taxonomy.** The trace contains the full acceptance set:
   admission + planning spans, at least one per-box fetch/compute pair,
   at least one cache event, and at least one kernel-launch event (the
   pallas lane runs in interpret mode on CPU).
3. **Chrome schema.** The exported ``trace_event`` JSON round-trips
   through ``json``, every record carries ``ph``/``pid``/``tid``/
   ``name``, begin/end events are balanced, durations are non-negative,
   and lane metadata (``process_name``) is present.
4. **Exact sums.** The registry's per-tag ``io.*`` series (including
   the ``_untagged`` residual) sum to the raw device ledger, and the
   per-tenant ``cache.*`` series (including ``_shared``) to the raw
   shared-cache globals.

Writes the validated trace to ``--out`` (CI uploads it as an artifact).
Exit status is non-zero on any violation. No dependencies beyond the
repo itself; run as ``PYTHONPATH=src python scripts/trace_smoke.py``.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

REQUIRED_SPANS = ("serve.admission", "serve.query", "query.plan",
                  "box.fetch", "box.compute")
IO_FIELDS = ("block_reads", "block_writes", "word_reads", "probes",
             "cache_served_words")
CACHE_FIELDS = ("hits", "misses", "hit_words", "miss_words",
                "passthrough_words")


def run_query(tracer=None, metrics=None):
    """One served triangle count over a small RMAT graph; returns
    (count, block_reads, server) — the server is closed but its ledgers
    stay readable."""
    from repro.data.graphs import rmat_graph
    from repro.serve import Server

    src, dst = rmat_graph(512, 6000, seed=21)
    with Server.from_graph(src, dst, mem_words=1 << 15,
                           backend="pallas", use_pallas_kernels=False,
                           tracer=tracer, metrics=metrics) as srv:
        count = srv.submit("triangle", "count").result(timeout=300)
    return count, int(srv.device.stats.block_reads), srv


def check_taxonomy(tracer) -> None:
    names = tracer.span_names()
    for span in REQUIRED_SPANS:
        assert span in names, f"missing span {span!r} (got {names})"
    events = {e["name"] for e in tracer.snapshot() if e["ph"] == "i"}
    assert any(n.startswith("cache.") for n in events), \
        f"no cache event (got {sorted(events)})"
    assert "kernel.launch" in events, \
        f"no kernel-launch event (got {sorted(events)})"
    fetches = sum(1 for e in tracer.snapshot()
                  if e["ph"] == "B" and e["name"] == "box.fetch")
    computes = sum(1 for e in tracer.snapshot()
                   if e["ph"] == "B" and e["name"] == "box.compute")
    assert fetches >= 1 and computes >= 1, (fetches, computes)
    print(f"trace-smoke: taxonomy ok "
          f"({len(names)} span kinds, {len(events)} event kinds, "
          f"{fetches} fetch / {computes} compute spans)")


def check_chrome(doc: dict) -> None:
    doc = json.loads(json.dumps(doc))           # must round-trip
    events = doc["traceEvents"]
    assert events, "empty traceEvents"
    opens = {}
    for e in events:
        for key in ("ph", "pid", "tid", "name"):
            assert key in e, f"record missing {key!r}: {e}"
        if e["ph"] == "M":
            assert e["name"] == "process_name" and "name" in e["args"]
            continue
        assert "ts" in e, f"timed record missing ts: {e}"
        if e["ph"] == "B":
            opens.setdefault((e["pid"], e["tid"]), []).append(e)
        elif e["ph"] == "E":
            stack = opens.get((e["pid"], e["tid"]))
            assert stack, f"E without open B on ({e['pid']},{e['tid']})"
            b = stack.pop()
            assert b["name"] == e["name"], (b["name"], e["name"])
            assert e["ts"] >= b["ts"], "negative span duration"
        else:
            assert e["ph"] == "i", f"unknown phase {e['ph']!r}"
    dangling = [b["name"] for st in opens.values() for b in st]
    assert not dangling, f"unclosed spans: {dangling}"
    lanes = [e["args"]["name"] for e in events if e["ph"] == "M"]
    assert "main" in lanes, lanes
    print(f"trace-smoke: chrome schema ok ({len(events)} records, "
          f"lanes={lanes})")


def check_sums(reg, srv) -> None:
    reg.collect()

    def label_sum(name, label):
        return sum(v for key, v in reg.series(name).items()
                   if any(k == label for k, _ in key))

    for f in IO_FIELDS:
        raw = int(getattr(srv.device.stats, f))
        got = label_sum(f"io.{f}", "tag")
        assert got == raw, f"io.{f}: Σtags {got} != ledger {raw}"
    for rel, cache in srv.caches.items():
        for f in CACHE_FIELDS:
            raw = int(getattr(cache, f))
            got = sum(v for key, v in reg.series(f"cache.{f}").items()
                      if dict(key).get("relation") == rel
                      and any(k == "tenant" for k, _ in key))
            assert got == raw, \
                f"cache.{f}{{relation={rel}}}: Σtenants {got} != {raw}"
    print("trace-smoke: registry sums match the raw ledgers exactly")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace_smoke.json", metavar="PATH",
                    help="where to write the validated Chrome trace")
    args = ap.parse_args()

    from repro.obs import MetricsRegistry, Tracer

    base_count, base_reads, _ = run_query()
    tracer, reg = Tracer(), MetricsRegistry()
    count, reads, srv = run_query(tracer=tracer, metrics=reg)

    assert count == base_count, \
        f"traced count {count} != untraced {base_count}"
    assert reads == base_reads, \
        f"traced block_reads {reads} != untraced {base_reads}"
    print(f"trace-smoke: byte-identity ok "
          f"(count={count}, block_reads={reads})")

    check_taxonomy(tracer)
    check_chrome(tracer.to_chrome())
    check_sums(reg, srv)

    tracer.export_chrome(args.out)
    print(f"trace-smoke: wrote {args.out} "
          f"({len(tracer.snapshot())} buffered events, "
          f"{tracer.dropped} dropped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
