"""Out-of-core pipeline: measured block I/Os vs Thm. 10, bounded ingest,
slice-cache hit rates.

Three measurements per graph:

1. **ingest** — the graph is streamed into the chunked-CSR store through
   ``EdgeStoreWriter`` under a word budget smaller than the edge list;
   ``tracemalloc`` records the peak ingest allocations. The ~2x-budget
   envelope holds above the writer's fixed floors (O(V) index, minimum
   buffer/batch sizes); at this benchmark's deliberately tiny smoke
   budgets those floors dominate, so read peak_bytes against
   budget_bytes + the O(V) term, not the budget alone
   (tests/test_ingest.py enforces the envelope at a scale where the
   budget dominates).
2. **I/O vs Thm. 10** — the store-backed ``TriangleEngine`` runs cold (no
   cache) at several memory budgets; measured block reads from the attached
   ``BlockDevice`` are compared against the Thm. 10 prediction
   O(|E|²/(MB) + |E|/B).
3. **slice cache** — the same workload re-runs with an LRU ``SliceCache``
   (budget = the same memory fraction): block reads must drop, counts must
   not change, and the hit rate is recorded.

derived: io=<blocks>;pred=<blocks>;ratio=<x>;boxes=<n>;count=<triangles>;
         max_slice=<words>;cached_io=<blocks>;hit_rate=<frac>
         (plus peak_bytes=/budget_bytes=/runs= on the ingest rows)

``python -m benchmarks.outofcore --smoke --json out.json`` runs the fast
sizes standalone and writes the emitted rows (hit rate included) as a JSON
artifact; via ``benchmarks.run --smoke`` the same rows land in the CI
record.
"""

from __future__ import annotations

import os
import tempfile
import time
import tracemalloc

from repro.core import BlockDevice, TriangleEngine
from repro.data.edgestore import EdgeStore, EdgeStoreWriter
from repro.data.graphs import random_graph, rmat_graph
from repro.data.pipeline import edge_batches

from .common import emit, fmt_util

B = 64
FRACS = (0.05, 0.10, 0.25)     # >= 3 memory budgets (acceptance)
INGEST_FRAC = 0.25             # ingest budget as a fraction of |E| words


def _ingest(path: str, src, dst, budget_words: int) -> dict:
    """Stream the edges into ``path`` under ``budget_words``, measuring
    wall time and peak Python allocations."""
    writer = EdgeStoreWriter(path, chunk_rows=256, align_words=B,
                             budget_words=budget_words)
    # batch size scales with the budget: per-edge batch processing costs
    # ~40 transient bytes (filter + orient + key), so budget/8 edges keeps
    # the batch overhead within the ~2x-budget peak envelope
    batch = max(256, budget_words // 8)
    tracemalloc.start()
    t0 = time.perf_counter()
    with writer:
        for s, d in edge_batches(src, dst, batch_edges=batch):
            writer.add_edges(s, d)
    us = (time.perf_counter() - t0) * 1e6
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return {"us": us, "peak_bytes": peak,
            "budget_bytes": 4 * budget_words,
            "runs": writer.n_spill_runs}


def main(fast: bool = False) -> None:
    size = 8000 if fast else 30000
    nv = 1 << 10 if fast else 1 << 11
    graphs = {"RMAT": rmat_graph(nv, size, seed=0),
              "RAND": random_graph(nv, size, seed=0)}
    if fast:
        graphs.pop("RAND")
    with tempfile.TemporaryDirectory() as td:
        for gname, (src, dst) in graphs.items():
            path = os.path.join(td, f"{gname}.csr")
            budget = max(8 * B, int(len(src) * INGEST_FRAC))
            ing = _ingest(path, src, dst, budget)
            emit(f"ooc/{gname}/ingest", ing["us"],
                 f"peak_bytes={ing['peak_bytes']};"
                 f"budget_bytes={ing['budget_bytes']};runs={ing['runs']}")
            words = EdgeStore(path).words()
            for frac in FRACS:
                mem = max(8 * B, int(words * frac))
                dev = BlockDevice(block_words=B,
                                  cache_blocks=max(2, mem // B))
                eng = TriangleEngine(store=path, device=dev, mem_words=mem)
                # ONE cold pass: the Thm. 10 comparison needs the I/O of a
                # run starting with empty LRU frames — warmup/repeat passes
                # would leave the buffer cache hot and understate the ratio
                t0 = time.perf_counter()
                cnt = eng.count()
                us = (time.perf_counter() - t0) * 1e6
                io = eng.stats.block_reads
                pred = words * words / (mem * B) + words / B
                # same plan + budget with the slice cache on: adjacent
                # boxes re-serve shared row blocks from host memory, so
                # block reads must drop while the count stays identical
                dev_c = BlockDevice(block_words=B,
                                    cache_blocks=max(2, mem // B))
                eng_c = TriangleEngine(store=path, device=dev_c,
                                       mem_words=mem, cache_words=mem)
                cnt_c = eng_c.count()
                assert cnt_c == cnt, (cnt_c, cnt)
                # async scheduler cross-check: a cold workers=2 run must
                # reproduce the count AND the serial run's measured word
                # reads (the determinism contract of the parallel queue)
                dev_p = BlockDevice(block_words=B,
                                    cache_blocks=max(2, mem // B))
                eng_p = TriangleEngine(store=path, device=dev_p,
                                       mem_words=mem, workers=2)
                cnt_p = eng_p.count()
                assert cnt_p == cnt, (cnt_p, cnt)
                assert eng_p.stats.block_reads == io, \
                    (eng_p.stats.block_reads, io)
                emit(f"ooc/{gname}/m{int(frac * 100)}", us,
                     f"io={io};pred={pred:.0f};ratio={io / max(1.0, pred):.2f};"
                     f"boxes={eng.stats.n_boxes};count={cnt};"
                     f"max_slice={eng.stats.max_slice_words};"
                     f"cached_io={eng_c.stats.block_reads};"
                     f"hit_rate={eng_c.stats.cache_hit_rate:.2f};"
                     f"par_io={eng_p.stats.block_reads};"
                     f"par_util={fmt_util(eng_p.stats.worker_utilization)}")


if __name__ == "__main__":
    import argparse
    import json

    from .common import collected_rows, reset_rows

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast sizes (the CI gate's configuration)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows (incl. hit_rate) as JSON")
    args = ap.parse_args()
    reset_rows()
    print("name,us_per_call,derived")
    main(fast=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": ["ooc"], "fast": bool(args.smoke),
                       "rows": collected_rows()}, f, indent=2)
        print(f"# wrote {args.json}")
