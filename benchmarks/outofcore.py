"""Out-of-core streaming engine: measured block I/Os vs the Thm. 10 bound.

Writes the graph to a chunked-CSR edge store in a tempdir, then runs the
store-backed ``TriangleEngine`` at several memory budgets. Per budget we
emit the *measured* block reads from the attached ``BlockDevice`` next to
the Thm. 10 prediction O(|E|²/(MB) + |E|/B), so the ratio tracks how close
the streaming executor runs to the paper's bound as the budget shrinks.

derived: io=<blocks>;pred=<blocks>;ratio=<x>;boxes=<n>;count=<triangles>;
         max_slice=<words>
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.core import BlockDevice, TriangleEngine
from repro.data.edgestore import EdgeStore, write_edge_store
from repro.data.graphs import random_graph, rmat_graph

from .common import emit

B = 64
FRACS = (0.05, 0.10, 0.25)     # >= 3 memory budgets (acceptance)


def main(fast: bool = False) -> None:
    size = 8000 if fast else 30000
    nv = 1 << 10 if fast else 1 << 11
    graphs = {"RMAT": rmat_graph(nv, size, seed=0),
              "RAND": random_graph(nv, size, seed=0)}
    if fast:
        graphs.pop("RAND")
    with tempfile.TemporaryDirectory() as td:
        for gname, (src, dst) in graphs.items():
            path = write_edge_store(os.path.join(td, f"{gname}.csr"),
                                    src, dst, chunk_rows=256, align_words=B)
            words = EdgeStore(path).words()
            for frac in FRACS:
                mem = max(8 * B, int(words * frac))
                dev = BlockDevice(block_words=B,
                                  cache_blocks=max(2, mem // B))
                eng = TriangleEngine(store=path, device=dev, mem_words=mem)
                # ONE cold pass: the Thm. 10 comparison needs the I/O of a
                # run starting with empty LRU frames — warmup/repeat passes
                # would leave the buffer cache hot and understate the ratio
                t0 = time.perf_counter()
                cnt = eng.count()
                us = (time.perf_counter() - t0) * 1e6
                io = eng.stats.block_reads
                pred = words * words / (mem * B) + words / B
                emit(f"ooc/{gname}/m{int(frac * 100)}", us,
                     f"io={io};pred={pred:.0f};ratio={io / max(1.0, pred):.2f};"
                     f"boxes={eng.stats.n_boxes};count={cnt};"
                     f"max_slice={eng.stats.max_slice_words}")


if __name__ == "__main__":
    main()
