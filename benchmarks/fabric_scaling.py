"""Distributed box-fabric scaling: shard count vs wall time, balance and
shipped bytes — with the correctness gates asserted inline.

For mesh shapes {1, 2, 4, 8} over an RMAT graph, runs the triangle and
4-clique fabrics and enforces, per shape:

* **exactness** — the distributed count equals the single-host
  ``QueryEngine`` oracle;
* **ledger additivity** — the summed per-shard measured ``block_reads``
  equal the sum over solo oracle engines running the same restricted
  plans (distribution adds no hidden I/O).

Reported per run: wall time, LPT balance (max shard mass / mean nonzero
mass), total shipped words, and the summed shard block reads.

CI runs ``python -m benchmarks.fabric_scaling --smoke --json
fabric-scaling.json`` and uploads the record.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

from .common import emit

SHARD_COUNTS = (1, 2, 4, 8)


def run_fabric(pattern: str, graph, *, n_shards: int, mem_words: int,
               label: str) -> dict:
    from repro.parallel.fabric import Fabric
    from repro.query.executor import QueryEngine
    from repro.query.patterns import PATTERNS

    src, dst = graph
    solo = QueryEngine.from_graph(PATTERNS[pattern](), src, dst,
                                  mem_words=mem_words)
    want = solo.count()

    fab = Fabric.from_graph(PATTERNS[pattern](), src, dst,
                            n_shards=n_shards, mem_words=mem_words,
                            io_block_words=64)
    t0 = time.perf_counter()
    got = fab.count()
    wall = time.perf_counter() - t0
    assert got == want, (label, got, want)

    oracle_reads = 0
    for s in range(n_shards):
        orc = fab.oracle_engine(s)
        orc.run_boxes("count")
        oracle_reads += orc.stats.block_reads
    assert fab.stats.sum_block_reads == oracle_reads, \
        (label, fab.stats.sum_block_reads, oracle_reads)

    out = {
        "label": label, "pattern": pattern, "n_shards": n_shards,
        "count": int(got), "wall_s": round(wall, 4),
        "balance": round(fab.stats.balance, 3),
        "shipped_words": int(sum(fab.stats.shipped_words)),
        "sum_block_reads": int(fab.stats.sum_block_reads),
        "n_boxes": int(fab.stats.n_boxes),
    }
    emit(f"{label}/count", 1e6 * wall,
         f"n={got} shards={n_shards} boxes={out['n_boxes']} "
         f"balance={out['balance']}")
    emit(f"{label}/io", 1e6 * wall,
         f"sum_block_reads={out['sum_block_reads']}==solo_sum "
         f"shipped_words={out['shipped_words']}")
    return out


def main(fast: bool = False, smoke: bool = False,
         json_path: str | None = None) -> None:
    from repro.data.graphs import rmat_graph

    if smoke or fast:
        # budget below the input size so the plan actually boxes and the
        # LPT schedule has real work to balance
        graph = rmat_graph(256, 2500, seed=17)
        mem_words = 1 << 10
        shapes: List[int] = [1, 4]
    else:
        graph = rmat_graph(1024, 20000, seed=17)
        mem_words = 1 << 12
        shapes = list(SHARD_COUNTS)

    results = []
    for pattern in ("triangle", "four_clique"):
        for n in shapes:
            results.append(run_fabric(
                pattern, graph, n_shards=n, mem_words=mem_words,
                label=f"fabric_{pattern}_s{n}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"runs": results}, f, indent=2)
        print(f"# wrote {json_path} ({len(results)} runs)", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="the CI gate: shapes {1, 4} at fast sizes, "
                         "exactness + ledger additivity asserted")
    ap.add_argument("--json", default=None, metavar="PATH")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=a.fast, smoke=a.smoke, json_path=a.json)
