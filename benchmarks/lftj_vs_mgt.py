"""Paper Fig. 11: boxed LFTJ vs the specialized MGT, limited memory.

Wall-clock on CPU (both implementations share the vectorized intersection
primitive, so the comparison isolates the *algorithmic* difference) plus
modeled block I/Os at the paper's 10% / 25% memory fractions. The paper
finds single-threaded LFTJ within ~3x of MGT; the box-parallel LFTJ
(here: the vectorized per-box engine) closes the gap.

derived: io=<blocks>;count=<triangles>
"""

from __future__ import annotations

import numpy as np

from repro.core import (BlockDevice, TriangleEngine, TrieArray,
                        boxed_triangle_count, count_triangles,
                        mgt_triangle_count, orient_edges)
from repro.data.graphs import random_graph, rmat_graph

from .common import emit, timeit

B = 64


def main(fast: bool = False) -> None:
    size = 20000 if fast else 60000
    graphs = {"RMAT": rmat_graph(1 << 12, size, seed=0),
              "RAND": random_graph(1 << 12, size, seed=0)}
    fracs = (0.10,) if fast else (0.10, 0.25)
    for gname, (src, dst) in graphs.items():
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        words = ta.words()
        for frac in fracs:
            mem = int(words * frac)
            # MGT (specialized competitor)
            dev = BlockDevice(block_words=B, cache_blocks=max(2, mem // B))
            cnt_m, info = mgt_triangle_count(src, dst, mem, device=dev)
            us_m = timeit(lambda: mgt_triangle_count(src, dst, mem)[0],
                          repeats=1)
            emit(f"fig11_mgt/{gname}/m{int(frac*100)}", us_m,
                 f"io={dev.stats.block_reads};count={cnt_m};"
                 f"chunks={info['n_chunks']}")
            # boxed LFTJ, faithful sequential engine
            dev2 = BlockDevice(block_words=B, cache_blocks=max(2, mem // B))
            dev2.register_triearray(ta)
            cnt_l, _ = boxed_triangle_count(ta, mem, block_words=B,
                                            device=dev2)
            us_l = timeit(lambda: boxed_triangle_count(ta, mem)[0], repeats=1)
            emit(f"fig11_lftj_seq/{gname}/m{int(frac*100)}", us_l,
                 f"io={dev2.stats.block_reads};count={cnt_l}")
            # boxed LFTJ via the unified engine (box sharding engages on
            # multi-device hosts; backend dispatch per box density)
            eng = TriangleEngine(src, dst, mem_words=mem)
            us_v = timeit(lambda: eng.count(), repeats=1)
            cnt_v = eng.count()
            emit(f"fig11_lftj_engine/{gname}/m{int(frac*100)}", us_v,
                 f"count={cnt_v};boxes={eng.stats.n_boxes};"
                 f"dense={eng.stats.n_dense_boxes};"
                 f"shards={eng.stats.n_shards};"
                 f"ratio_vs_mgt={us_v/max(1e-9,us_m):.2f}")
            assert cnt_m == cnt_l == cnt_v


if __name__ == "__main__":
    main()
