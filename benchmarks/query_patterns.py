"""General query patterns: 4-clique + diamond throughput and measured
block I/O vs the Thm. 13 rank-r envelope.

For each pattern (4-clique rank 3, diamond rank 3 in its store-consistent
order, triangle rank 2 as the anchor) the store-backed ``QueryEngine``
runs cold at ≥ 2 memory budgets on an RMAT graph; measured block reads
from the attached ``BlockDevice`` are compared against

    pred = |I|^r / (M^{r-1} B) + K/B        (Thm. 13)

with K = result tuples × arity words. The boxed engine must stay *within*
the envelope (ratio ≤ 1 up to the bound's constant; the emitted ratio is
the figure of merit CI tracks). Cross-checks per budget:

* a ``workers=2`` run reproduces the count and the serial block reads
  (the shared scheduler's determinism contract on the generic engine);
* counts match the scalar LFTJ reference (``run_query``) once per graph.

derived: io=<blocks>;pred=<blocks>;ratio=<x>;rank=<r>;boxes=<n>;
         count=<results>;par_io=<blocks>;kernel_boxes=<n>

``python -m benchmarks.query_patterns --smoke --json out.json`` runs the
fast sizes standalone (the CI ``query`` job's configuration); via
``benchmarks.run --smoke`` the same rows land in the main CI record.
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.core import BlockDevice, TrieArray, orient_edges, run_query
from repro.data.edgestore import EdgeStore, write_edge_store
from repro.data.graphs import rmat_graph
from repro.query import QueryEngine, patterns, thm13_io_bound

from .common import emit

B = 64
FRACS = (0.25, 0.50)           # >= 2 memory budgets (acceptance)
# Thm. 13 is asymptotic — the envelope constant absorbs the per-dimension
# slice re-reads the bound's O(·) hides. Measured ratios sit near 1 for
# rank 2 and 2-3 for rank 3 on the smoke workload; 8x is the regression
# tripwire, not a tight fit.
ENVELOPE = 8.0

# pattern name -> (query factory, store-consistent variable order)
CASES = {
    "triangle": (patterns.triangle, ("x", "y", "z")),
    "four_clique": (patterns.four_clique, None),
    "diamond": (patterns.diamond, ("x", "y", "z", "w")),
}


def main(fast: bool = False) -> None:
    nv = 256 if fast else 768
    ne = 2600 if fast else 9000
    src, dst = rmat_graph(nv, ne, seed=0)
    a, b = orient_edges(src, dst)
    ta = TrieArray.from_edges(a, b)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "g.csr")
        write_edge_store(path, src, dst, chunk_rows=64, align_words=B)
        words = EdgeStore(path).words()
        for name, (factory, order) in CASES.items():
            q = factory()
            ref = run_query(q, q.head, {"E": ta})
            for frac in FRACS:
                mem = max(4 * B, int(words * frac))
                eng = QueryEngine(q, store=path, order=order, mem_words=mem,
                                  io_block_words=B)
                # ONE cold pass (Thm. 13 compares against empty LRU frames)
                t0 = time.perf_counter()
                cnt = eng.count()
                us = (time.perf_counter() - t0) * 1e6
                assert cnt == ref, (name, cnt, ref)
                io = eng.stats.block_reads
                r = eng.stats.rank
                pred = thm13_io_bound(words, mem, B, r,
                                      output_words=cnt * len(q.head))
                assert io <= ENVELOPE * pred, \
                    (name, frac, io, pred)   # the Thm. 13 envelope gate
                # generic-engine determinism contract: a parallel cold run
                # reproduces the count and the measured block reads
                eng_p = QueryEngine(q, store=path, order=order,
                                    mem_words=mem, io_block_words=B,
                                    workers=2)
                cnt_p = eng_p.count()
                assert cnt_p == cnt, (name, cnt_p, cnt)
                assert eng_p.stats.block_reads == io, \
                    (name, eng_p.stats.block_reads, io)
                emit(f"query/{name}/m{int(frac * 100)}", us,
                     f"io={io};pred={pred:.0f};"
                     f"ratio={io / max(1.0, pred):.3f};rank={r};"
                     f"boxes={eng.stats.n_boxes};count={cnt};"
                     f"par_io={eng_p.stats.block_reads};"
                     f"kernel_boxes={eng.stats.n_kernel_boxes}")


if __name__ == "__main__":
    import argparse
    import json

    from .common import collected_rows, reset_rows

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast sizes (the CI query job's configuration)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON")
    args = ap.parse_args()
    reset_rows()
    print("name,us_per_call,derived")
    main(fast=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": ["query"], "fast": bool(args.smoke),
                       "rows": collected_rows()}, f, indent=2)
        print(f"# wrote {args.json}")
