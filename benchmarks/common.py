"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the benchmark-specific figure of merit: I/O counts, box counts, ratios...).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

# every emit() lands here too, so the harness can dump a machine-readable
# run record (CI uploads it as a build artifact to track perf per PR)
_ROWS: List[Dict[str, str]] = []


def collected_rows() -> List[Dict[str, str]]:
    return list(_ROWS)


def reset_rows() -> None:
    _ROWS.clear()


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str = "") -> None:
    _ROWS.append({"name": name, "us_per_call": f"{us:.1f}",
                  "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def fmt_util(u: Optional[float]) -> str:
    """Render ``worker_utilization``: ``None`` (run too short to measure)
    prints as ``n/a`` instead of crashing a ``:.2f`` format."""
    return "n/a" if u is None else f"{u:.2f}"
