"""Paper Fig. 7: boxing CPU overhead + box counts vs memory fraction.

Rows 1-2 of Fig. 7 measure probing / provisioning / full-join time as the
memory budget sweeps 5%..200% of the input size; row 3 reports #boxes and
provisioned bytes (as a multiple of the input). We reproduce all three
curves on RAND and RMAT graphs (scaled to CPU) using the same three
variants the paper runs: probe-only, probe+provision, full boxed join.

derived column: boxes=<n>;prov_x=<provisioned/input>;spills=<n>
"""

from __future__ import annotations

import numpy as np

from repro.core import TrieArray, boxed_triangle_count, orient_edges
from repro.core.boxing import BoxedLFTJ, BoxingConfig, plan_boxes
from repro.core.leapfrog import triangle_query_atoms
from repro.data.graphs import random_graph, rmat_graph

from .common import emit, timeit

FRACTIONS = (0.05, 0.10, 0.25, 0.50, 1.00, 2.00)


def probe_only(ta: TrieArray, mem: int) -> int:
    return len(plan_boxes(ta, mem))


def probe_and_provision(ta: TrieArray, mem: int):
    """Run Algorithm 2 but skip the in-box LFTJ (paper variant (b))."""
    cfg = BoxingConfig(mem_words=mem, dim_ratio={"x": 4.0, "y": 1.0})
    bj = BoxedLFTJ(triangle_query_atoms(), ["x", "y", "z"], {"E": ta}, cfg)
    # disable the join itself but keep the box count honest
    bj._run_box = lambda lh, sl: setattr(
        bj.stats, "n_boxes", bj.stats.n_boxes + 1)
    bj.run()
    return bj.stats


def main(fast: bool = False) -> None:
    graphs = {
        "RAND": random_graph(1 << 11, 24000, seed=0),
        "RMAT": rmat_graph(1 << 11, 24000, seed=0),
    }
    fracs = FRACTIONS if not fast else (0.10, 0.50)
    for gname, (src, dst) in graphs.items():
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        words = ta.words()
        for frac in fracs:
            mem = max(32, int(words * frac))
            us_probe = timeit(lambda: probe_only(ta, mem), repeats=1)
            st = probe_and_provision(ta, mem)
            us_prov = timeit(lambda: probe_and_provision(ta, mem), repeats=1)
            us_full = timeit(
                lambda: boxed_triangle_count(ta, mem), repeats=1)
            prov_x = st.provisioned_words / max(1, words)
            emit(f"fig7_probe/{gname}/m{int(frac*100)}", us_probe,
                 f"boxes={st.n_boxes}")
            emit(f"fig7_provision/{gname}/m{int(frac*100)}", us_prov,
                 f"prov_x={prov_x:.2f}")
            emit(f"fig7_full/{gname}/m{int(frac*100)}", us_full,
                 f"boxes={st.n_boxes};prov_x={prov_x:.2f};"
                 f"spills={st.n_spills}")


if __name__ == "__main__":
    main()
