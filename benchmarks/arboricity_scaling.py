"""Paper Thm. 17: LFTJ-Δ runs in O(|E| · α(G) · log|E|).

We hold |E| ~ constant and sweep arboricity via planted cliques of growing
size k (α(K_k) = ceil(k/2), Lemma 21): work should scale ~linearly in α.
The measured proxy is the exact level-z intersection work Σ min(d_x, d_y)
(the Chiba-Nishizeki term the proof bounds by 2α|E|) plus wall time of the
faithful LFTJ.

derived: alpha=<k/2>;edges=<m>;cn_work=<sum_min_deg>;work_per_edge=<..>
"""

from __future__ import annotations

import numpy as np

from repro.core import TrieArray, lftj_triangle_count, orient_edges
from repro.core.lftj_jax import csr_from_edges
from repro.data.graphs import clustered_graph

from .common import emit, timeit


def cn_work(src, dst) -> int:
    """Σ_{(x,y) in E} min(d_x, d_y) over the DAG orientation."""
    a, b = orient_edges(src, dst)
    n = int(max(a.max(), b.max())) + 1
    deg = np.bincount(a, minlength=n)
    return int(np.minimum(deg[a], deg[b]).sum())


def main(fast: bool = False) -> None:
    target_edges = 12000 if fast else 30000
    ks = (4, 8, 16, 32) if fast else (4, 8, 16, 32, 64)
    for k in ks:
        per_clique = k * (k - 1) // 2
        n_cliques = max(1, target_edges // per_clique)
        src, dst = clustered_graph(n_cliques, k, p_in=1.0)
        m = len(src)
        w = cn_work(src, dst)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        us = timeit(lambda: lftj_triangle_count(ta), repeats=1)
        emit(f"thm17_alpha{k//2}", us,
             f"alpha={k//2};edges={m};cn_work={w};"
             f"work_per_edge={w/m:.2f}")


if __name__ == "__main__":
    main()
