"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

Wall time in interpret mode is NOT TPU performance (the kernel body runs
in python); the figure of merit here is (a) correctness at benchmark
shapes and (b) the jnp-reference throughput, which IS executed by XLA CPU
and scales with the same arithmetic the TPU kernel performs.

derived: checks kernel==ref; reports elements/s of the jnp path.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.intersect.ref import SENTINEL, intersect_count_ref
from repro.kernels.triangle_dense.ref import triangle_count_ref
from repro.kernels.intersect.ops import intersect_count
from repro.kernels.triangle_dense.ops import triangle_count

from .common import emit, timeit

RNG = np.random.default_rng(0)


def main(fast: bool = False) -> None:
    # triangle_dense
    n, d = (256, 1024) if fast else (512, 2048)
    a = (RNG.random((n, d)) < 0.05).astype(np.float32)
    m = np.ones((n, n), np.float32)
    aj = jnp.asarray(a)
    mj = jnp.asarray(m)
    want = float(triangle_count_ref(aj, aj, mj))
    got = float(triangle_count(a, a, m, use_pallas=True))
    us = timeit(lambda: triangle_count_ref(aj, aj, mj).block_until_ready())
    flops = 2 * n * n * d
    emit("kernel_triangle_dense", us,
         f"match={abs(got-want)<1e-2};gflops_ref={flops/us/1e3:.2f}")

    # intersect
    e, k = (2048, 128) if fast else (8192, 256)
    def rows():
        out = np.full((e, k), SENTINEL, np.int32)
        for i in range(e):
            nn = RNG.integers(0, k)
            out[i, :nn] = np.sort(RNG.choice(k * 4, nn, replace=False))
        return out
    A, Bm = rows(), rows()
    Aj, Bj = jnp.asarray(A), jnp.asarray(Bm)
    got = np.asarray(intersect_count(A, Bm, use_pallas=True))
    want = np.asarray(intersect_count_ref(Aj, Bj))
    us = timeit(lambda: intersect_count_ref(Aj, Bj).block_until_ready())
    emit("kernel_intersect", us,
         f"match={bool((got==want).all())};rows_per_s={e/us*1e6:.0f}")

    # embedding_bag
    v, dd, b, l = (20000, 64, 1024, 8) if fast else (100000, 128, 4096, 8)
    tab = RNG.standard_normal((v, dd)).astype(np.float32)
    idx = RNG.integers(0, v, (b, l)).astype(np.int32)
    tj, ij = jnp.asarray(tab), jnp.asarray(idx)
    us = timeit(lambda: embedding_bag_ref(tj, ij).block_until_ready())
    emit("kernel_embedding_bag", us,
         f"lookups_per_s={b*l/us*1e6:.0f}")


if __name__ == "__main__":
    main()
