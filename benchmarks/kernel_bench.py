"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

Wall time in interpret mode is NOT TPU performance (the kernel body runs
in python); the figure of merit here is (a) correctness at benchmark
shapes and (b) the jnp-reference throughput, which IS executed by XLA CPU
and scales with the same arithmetic the TPU kernel performs.

Two structural rows back the PR-7 fused-megakernel claims:

* ``kernel_lftj_fused`` — the fused-vs-staged device-invocation A/B on a
  hub box: both lanes answer the same whole-box triangle join at the same
  VMEM footprint (the staged chunk is sized to the fused kernel's
  measured residency), launches counted by ``repro.kernels.ledger``. The
  >=10x launch reduction is asserted, not just reported — it is shape
  math, not timing, so it is deterministic in CI.
* ``kernel_jit_cache`` — compiled-program cache sizes after the sweep
  (pow2-bucketed shapes keep them logarithmic in input variety).

derived: checks kernel==ref; reports elements/s of the jnp path.

Runs standalone too: ``python -m benchmarks.kernel_bench --smoke --json
kernel-bench.json`` (the CI kernels job).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import ledger
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.intersect.ref import SENTINEL, intersect_count_ref
from repro.kernels.triangle_dense.ref import triangle_count_ref
from repro.kernels.intersect.ops import (intersect_count,
                                         intersect_count_rows,
                                         jit_cache_info)
from repro.kernels.lftj_fused.ops import (_pow2, _vmem_bytes,
                                          fused_cache_info, fused_count)
from repro.kernels.lftj_fused.ref import fused_ref
from repro.kernels.triangle_dense.ops import triangle_count

from .common import emit, timeit

RNG = np.random.default_rng(0)

TRIANGLE_DIMS = ((0, 1), (0, 2), (1, 2))


def _hub_box(h: int = 64, m: int = 64, link: int = 16):
    """A heavy/light hub box as a compact CSR: ``h`` hubs all adjacent to
    the same ``m`` mid vertices, each mid linked to its next ``link``
    mids — dense hub rows over a sparse tail, the shape the planner's
    heavy_light lane routes to the fused kernel."""
    src, dst = [], []
    for hub in range(h):
        src += [hub] * m
        dst += list(range(h, h + m))
    for mid in range(m):
        stop = min(mid + 1 + link, m)
        src += [h + mid] * (stop - mid - 1)
        dst += list(range(h + mid + 1, h + stop))
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    keys, counts = np.unique(src, return_counts=True)
    off = np.concatenate([np.zeros(1, np.int64),
                          np.cumsum(counts, dtype=np.int64)])
    return (keys, off, dst.astype(np.int32)), src, dst


def measure_fused_vs_staged(fast: bool = False) -> dict:
    """Device invocations per hub box: fused megakernel vs the staged
    per-chunk intersect lane at equal VMEM footprint.

    The staged chunk is ``fused_vmem_words / (2 * K)`` rows — exactly the
    rows that fit the VMEM the fused kernel actually holds resident — so
    the launch count compares lanes at the same memory budget. Both lanes
    are counted by the kernel ledger and must agree on the exact count.
    """
    csr, src, dst = _hub_box()
    keys, off, vals = csr
    csrs = [csr] * 3

    with ledger.attach() as kl_fused:
        us_fused = timeit(lambda: fused_count(TRIANGLE_DIMS, csrs, 3,
                                              interpret=True), repeats=1)
        total_fused = fused_count(TRIANGLE_DIMS, csrs, 3, interpret=True)

    deg = np.diff(off)
    r = _pow2(len(keys), lo=8)
    k = _pow2(int(deg.max(initial=1)), lo=8)
    vmem = _vmem_bytes(((r, k),) * 3, (), 3, TRIANGLE_DIMS, bt=8)
    chunk = max(256, (vmem // 4) // (2 * k))
    pos_a = np.searchsorted(keys, src)
    pos_b = np.searchsorted(keys, dst)
    ok = keys[np.minimum(pos_b, len(keys) - 1)] == dst
    with ledger.attach() as kl_staged:
        us_staged = timeit(
            lambda: intersect_count_rows(off, vals, pos_a[ok],
                                         off, vals, pos_b[ok],
                                         use_pallas=False, chunk=chunk),
            repeats=1)
        total_staged = intersect_count_rows(off, vals, pos_a[ok],
                                            off, vals, pos_b[ok],
                                            use_pallas=False, chunk=chunk)

    # per-measurement invocation counts (timeit ran warmup + 1 repeat +
    # the checked call = 3 passes through each lane)
    fused_launches = kl_fused.invocations // 3
    staged_launches = kl_staged.invocations // 3
    ratio = staged_launches / max(1, fused_launches)
    assert total_fused == total_staged, (total_fused, total_staged)
    assert ratio >= 10, (
        f"fused lane must cut per-box device invocations >=10x: "
        f"staged={staged_launches} fused={fused_launches}")
    return {
        "match": total_fused == total_staged,
        "fused_launches": fused_launches,
        "staged_launches": staged_launches,
        "launch_ratio": ratio,
        "fused_transfer_bytes": kl_fused.transfer_bytes // 3,
        "staged_transfer_bytes": kl_staged.transfer_bytes // 3,
        "us_fused": us_fused,
        "us_staged": us_staged,
    }


def main(fast: bool = False) -> None:
    # triangle_dense
    n, d = (256, 1024) if fast else (512, 2048)
    a = (RNG.random((n, d)) < 0.05).astype(np.float32)
    m = np.ones((n, n), np.float32)
    aj = jnp.asarray(a)
    mj = jnp.asarray(m)
    want = float(triangle_count_ref(aj, aj, mj))
    got = float(triangle_count(a, a, m, use_pallas=True))
    us = timeit(lambda: triangle_count_ref(aj, aj, mj).block_until_ready())
    flops = 2 * n * n * d
    emit("kernel_triangle_dense", us,
         f"match={abs(got-want)<1e-2};gflops_ref={flops/us/1e3:.2f}")

    # intersect
    e, k = (2048, 128) if fast else (8192, 256)
    def rows():
        out = np.full((e, k), SENTINEL, np.int32)
        for i in range(e):
            nn = RNG.integers(0, k)
            out[i, :nn] = np.sort(RNG.choice(k * 4, nn, replace=False))
        return out
    A, Bm = rows(), rows()
    Aj, Bj = jnp.asarray(A), jnp.asarray(Bm)
    got = np.asarray(intersect_count(A, Bm, use_pallas=True))
    want = np.asarray(intersect_count_ref(Aj, Bj))
    us = timeit(lambda: intersect_count_ref(Aj, Bj).block_until_ready())
    emit("kernel_intersect", us,
         f"match={bool((got==want).all())};rows_per_s={e/us*1e6:.0f}")

    # fused LFTJ megakernel: correctness at a benchmark shape vs the
    # scalar oracle, then the launch-count A/B vs the staged lane
    csr, _, _ = _hub_box(h=16, m=32, link=8)
    want_n, _ = fused_ref(TRIANGLE_DIMS, [csr] * 3, 3)
    got_n = fused_count(TRIANGLE_DIMS, [csr] * 3, 3, interpret=True)
    us = timeit(lambda: fused_count(TRIANGLE_DIMS, [csr] * 3, 3,
                                    interpret=True))
    emit("kernel_lftj_fused_ref", us, f"match={got_n == want_n}")
    ab = measure_fused_vs_staged(fast)
    emit("kernel_lftj_fused", ab["us_fused"],
         f"match={ab['match']};fused_launches={ab['fused_launches']};"
         f"staged_launches={ab['staged_launches']};"
         f"launch_ratio={ab['launch_ratio']:.1f}")

    # compiled-program cache growth after the sweep above (pow2-bucketed
    # shapes: a handful of programs, not one per input shape)
    fc = fused_cache_info()
    emit("kernel_jit_cache", 0.0,
         f"intersect_signatures={jit_cache_info()};"
         f"fused_count_programs={fc['count_programs']};"
         f"fused_list_programs={fc['list_programs']}")

    # embedding_bag
    v, dd, b, l = (20000, 64, 1024, 8) if fast else (100000, 128, 4096, 8)
    tab = RNG.standard_normal((v, dd)).astype(np.float32)
    idx = RNG.integers(0, v, (b, l)).astype(np.int32)
    tj, ij = jnp.asarray(tab), jnp.asarray(idx)
    us = timeit(lambda: embedding_bag_ref(tj, ij).block_until_ready())
    emit("kernel_embedding_bag", us,
         f"lookups_per_s={b*l/us*1e6:.0f}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: --fast sizes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as a JSON run record")
    args = ap.parse_args()

    from .common import collected_rows, reset_rows

    reset_rows()
    print("name,us_per_call,derived")
    main(fast=args.fast or args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": ["kernels"], "fast": True,
                       "rows": collected_rows()}, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
