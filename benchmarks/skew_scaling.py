"""Skew scaling: heavy/light box planning vs the uniform planner on RMAT.

An RMAT graph's degree distribution is heavy-tailed, so uniform boxes mix
hub rows with light rows: every padded neighbor matrix is sized by the hub
degree and the light rows ride along as padding. The heavy/light planner
(``skew="heavy_light"``) breaks cuts at class transitions and routes each
box by lane — hub boxes to the dense/MXU lane, light and mixed boxes to the
host searchsorted lane — so padded neighbor matrices are only ever built
where they pay off.

Per memory budget the A/B measures, at equal ``mem_words``:

* ``padded``/``actual`` words for both planners and the reduction factor
  (``padded_uniform / padded_heavy_light``; the gate asserts >= 2x on RMAT,
  with padded_hl == 0 treated as infinite reduction and reported as the
  uniform padding count),
* box + lane mix of the heavy/light plan,
* worker utilization at workers={1,4} under the mass-based LPT schedule,
* the triangle count, pinned to the uniform planner's (which is itself
  pinned to the unboxed oracle) — a planner that changes answers fails
  here, not in a downstream dashboard.

derived: count=<triangles>;padded_uni=<w>;padded_hl=<w>;actual=<w>;
         reduction=<x>;boxes_uni=<n>;boxes_hl=<n>;hub=<n>;light=<n>;
         mixed=<n>;util_w1=<frac>;util_w4=<frac>

``python -m benchmarks.skew_scaling --smoke --json skew-scaling.json``
runs the fast sizes standalone and writes the rows as the CI artifact.
"""

from __future__ import annotations

import time

from repro.core import TriangleEngine
from repro.data.graphs import rmat_graph

from .common import emit, fmt_util

FRACS = (0.05, 0.15)        # memory budgets as fractions of |E| words
MIN_REDUCTION = 2.0         # acceptance gate: >= 2x padded-words reduction


def _run(src, dst, mem_words, skew, workers=1):
    eng = TriangleEngine(src, dst, mem_words=mem_words, skew=skew,
                         workers=workers)
    t0 = time.perf_counter()
    cnt = eng.count()
    us = (time.perf_counter() - t0) * 1e6
    return cnt, eng.stats, us


def main(fast: bool = False) -> None:
    nv, ne = ((1 << 9, 6000) if fast else (1 << 12, 60000))
    src, dst = rmat_graph(nv, ne, seed=7)
    oracle = TriangleEngine(src, dst, mem_words=None).count()
    words = 2 * len(src)
    for frac in FRACS:
        mem = max(512, int(words * frac))
        cnt_u, st_u, us_u = _run(src, dst, mem, "uniform")
        cnt_h, st_h, us_h = _run(src, dst, mem, "heavy_light")
        assert cnt_u == oracle, (cnt_u, oracle)
        assert cnt_h == oracle, (cnt_h, oracle)
        # the tentpole gate: heavy/light must cut materialized padding by
        # >= MIN_REDUCTION on a skewed graph at the same memory budget
        assert st_h.padded_words * MIN_REDUCTION <= st_u.padded_words, \
            (st_h.padded_words, st_u.padded_words)
        red = (st_u.padded_words / st_h.padded_words
               if st_h.padded_words else float(st_u.padded_words))
        cnt_h4, st_h4, _ = _run(src, dst, mem, "heavy_light", workers=4)
        assert cnt_h4 == oracle, (cnt_h4, oracle)
        emit(f"skew/RMAT/m{int(frac * 100)}", us_h,
             f"count={cnt_h};padded_uni={st_u.padded_words};"
             f"padded_hl={st_h.padded_words};actual={st_h.actual_words};"
             f"reduction={red:.1f};boxes_uni={st_u.n_boxes};"
             f"boxes_hl={st_h.n_boxes};hub={st_h.n_hub_boxes};"
             f"light={st_h.n_light_boxes};mixed={st_h.n_mixed_boxes};"
             f"util_w1={fmt_util(st_h.worker_utilization)};"
             f"util_w4={fmt_util(st_h4.worker_utilization)}")


if __name__ == "__main__":
    import argparse
    import json

    from .common import collected_rows, reset_rows

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast sizes (the CI gate's configuration)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON")
    args = ap.parse_args()
    reset_rows()
    print("name,us_per_call,derived")
    main(fast=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": ["skew"], "fast": bool(args.smoke),
                       "rows": collected_rows()}, f, indent=2)
        print(f"# wrote {args.json}")
