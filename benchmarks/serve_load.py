"""Serving-layer load benchmark: latency/throughput under concurrency.

N client threads fire mixed triangle / 4-clique / path traffic at one
``repro.serve.Server`` holding a fixed TOTAL ``mem_words`` — the scenario
the admission controller exists for. Measures per-query latency
percentiles (p50/p90/p99) and aggregate throughput, and enforces the two
serving-layer acceptance gates:

* **exactness** — every served result is byte-identical to a serial
  one-query-at-a-time run of the same query at the same admitted budget
  (counts equal; listings equal row for row in plan order);
* **I/O envelope** — the server's aggregate measured ``block_reads``
  stays within ``ENVELOPE_FACTOR`` (2x) of the SUM of per-query solo
  envelopes at the partitioned budgets ``m_i`` — i.e. concurrency +
  sharing never costs more than running the queries alone in their
  partitions, up to a constant (usually it costs *less*: the shared
  cache turns overlapping traffic into hits).

CI runs ``python -m benchmarks.serve_load --smoke --json serve-load.json``
(4 concurrent mixed queries at fast sizes) and uploads the record.
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np

from .common import emit

ENVELOPE_FACTOR = 2.0

MIX = [("triangle", "count"), ("four_clique", "count"),
       ("path3", "count"), ("triangle", "list")]


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _serial_oracle(graph, name: str, mode: str, m_words: int,
                   cache: Dict[tuple, object]):
    """Serial per-query reference at budget ``m_words`` (memoized: the
    exactness gate replays it per admitted-budget value)."""
    from repro.query import QueryEngine
    from repro.query.patterns import PATTERNS
    key = (name, mode, m_words)
    if key not in cache:
        src, dst = graph
        eng = QueryEngine.from_graph(PATTERNS[name](), src, dst,
                                     mem_words=m_words,
                                     use_pallas_kernels=False)
        cache[key] = eng.count() if mode == "count" else eng.list()
    return cache[key]


def run_load(graph, *, mem_words: int, n_clients: int,
             queries_per_client: int, workers_per_query: int = 1,
             label: str = "serve") -> Dict[str, object]:
    from repro.serve import Server

    src, dst = graph
    srv = Server.from_graph(src, dst, mem_words=mem_words,
                            max_active=n_clients,
                            queue_depth=4 * n_clients,
                            workers_per_query=workers_per_query,
                            use_pallas_kernels=False)
    records: List[dict] = []
    errors: List[BaseException] = []
    rec_lock = threading.Lock()
    start_gate = threading.Event()

    def client(cid: int) -> None:
        try:
            start_gate.wait()
            for k in range(queries_per_client):
                name, mode = MIX[(cid + k) % len(MIX)]
                t0 = time.perf_counter()
                h = srv.submit(name, mode, timeout=600)
                result = h.result(timeout=600)
                lat = time.perf_counter() - t0
                with rec_lock:
                    records.append({
                        "client": cid, "name": name, "mode": mode,
                        "latency_s": lat, "m_words": h.admitted_words,
                        "block_reads": h.stats.block_reads,
                        "cache_hits": h.stats.cache_hits,
                        "result": result})
        except BaseException as e:              # noqa: BLE001 — reported
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    t_start = time.perf_counter()
    start_gate.set()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    if errors:
        raise errors[0]

    # -- gate 1: byte-identical to serial per-query runs ------------------
    oracle_cache: Dict[tuple, object] = {}
    for r in records:
        want = _serial_oracle(graph, r["name"], r["mode"], r["m_words"],
                              oracle_cache)
        if r["mode"] == "count":
            assert r["result"] == want, \
                (r["name"], r["m_words"], r["result"], want)
        else:
            got = np.asarray(r["result"])
            assert got.shape == want.shape \
                and got.tobytes() == want.tobytes(), \
                (r["name"], r["m_words"], got.shape, want.shape)

    # -- gate 2: aggregate I/O within 2x the summed solo envelopes --------
    solo_cache: Dict[tuple, int] = {}
    solo_sum = 0
    for r in records:
        key = (r["name"], r["mode"], r["m_words"])
        if key not in solo_cache:
            _, stats = srv.solo_run(r["name"], r["mode"],
                                    words=r["m_words"])
            solo_cache[key] = stats.block_reads
        solo_sum += solo_cache[key]
    aggregate = srv.device.stats.block_reads
    assert aggregate <= ENVELOPE_FACTOR * max(1, solo_sum), \
        f"aggregate block_reads {aggregate} > " \
        f"{ENVELOPE_FACTOR}x solo sum {solo_sum}"

    lats = [r["latency_s"] for r in records]
    out = {
        "label": label,
        "n_clients": n_clients,
        "queries": len(records),
        "mem_words": mem_words,
        "workers_per_query": workers_per_query,
        "wall_s": round(wall, 4),
        "throughput_qps": round(len(records) / wall, 2) if wall else 0.0,
        "p50_ms": round(1e3 * _pct(lats, 50), 3),
        "p90_ms": round(1e3 * _pct(lats, 90), 3),
        "p99_ms": round(1e3 * _pct(lats, 99), 3),
        "aggregate_block_reads": int(aggregate),
        "solo_envelope_sum": int(solo_sum),
        "envelope_ratio": round(aggregate / max(1, solo_sum), 3),
        "plan_hits": srv.plan_hits,
        "plan_misses": srv.plan_misses,
        "peak_reserved_words": srv.admission.peak_reserved,
        "n_queued": srv.admission.n_queued,
    }
    srv.close()
    assert out["peak_reserved_words"] <= mem_words
    emit(f"{label}/p50_latency", 1e6 * _pct(lats, 50),
         f"p90_ms={out['p90_ms']} p99_ms={out['p99_ms']} "
         f"qps={out['throughput_qps']}")
    emit(f"{label}/io_envelope", 1e6 * wall,
         f"aggregate={aggregate} solo_sum={solo_sum} "
         f"ratio={out['envelope_ratio']}<= {ENVELOPE_FACTOR}")
    return out


def main(fast: bool = False, smoke: bool = False,
         json_path: str | None = None) -> None:
    from repro.data.graphs import rmat_graph

    results = []
    if smoke or fast:
        # the CI gate: 4 concurrent mixed queries against one partitioned
        # budget, exactness + 2x-envelope asserted inside run_load
        graph = rmat_graph(512, 6000, seed=21)
        results.append(run_load(graph, mem_words=1 << 15, n_clients=4,
                                queries_per_client=2,
                                label="serve_smoke"))
    else:
        graph = rmat_graph(1024, 20000, seed=21)
        for n_clients in (2, 4, 8):
            results.append(run_load(
                graph, mem_words=1 << 17, n_clients=n_clients,
                queries_per_client=3,
                label=f"serve_c{n_clients}"))
        results.append(run_load(
            graph, mem_words=1 << 17, n_clients=4, queries_per_client=3,
            workers_per_query=2, label="serve_c4_w2"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"runs": results}, f, indent=2)
        print(f"# wrote {json_path} ({len(results)} runs)", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="the CI gate: 4 concurrent mixed queries, "
                         "exactness + 2x I/O envelope asserted")
    ap.add_argument("--json", default=None, metavar="PATH")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=a.fast, smoke=a.smoke, json_path=a.json)
