"""Benchmark harness: one module per paper table/figure (DESIGN.md §6).

  fig7   boxing CPU overhead + box counts      (benchmarks.boxing_overhead)
  fig9   vanilla vs boxed block I/Os + Prop.4  (benchmarks.vanilla_vs_boxed)
  fig11  boxed LFTJ vs specialized MGT         (benchmarks.lftj_vs_mgt)
  thm17  arboricity scaling of LFTJ-Δ          (benchmarks.arboricity_scaling)
  ooc    out-of-core engine I/O vs Thm. 10     (benchmarks.outofcore)
  query  general patterns I/O vs Thm. 13       (benchmarks.query_patterns)
  pscale async scheduler speedup vs workers    (benchmarks.parallel_scaling)
  skew   heavy/light vs uniform planner A/B    (benchmarks.skew_scaling)
  kernels Pallas kernels vs references          (benchmarks.kernel_bench)
  roofline per-cell roofline terms from dry-run (benchmarks.roofline)
  serve   concurrent serving latency + envelope (benchmarks.serve_load)
  fabric  distributed box-fabric shard scaling  (benchmarks.fabric_scaling)

Prints ``name,us_per_call,derived`` CSV. ``--fast`` shrinks sizes;
``--only fig9`` runs a single suite; ``--smoke`` is the CI gate — the
cheapest suite subset at fast sizes, exercising the engine + I/O model
(including the mmap edge store) end to end. ``--json PATH`` additionally
writes the emitted rows as JSON (CI uploads it as a build artifact so the
perf trajectory is tracked per PR).

``--summary PATH`` writes a consolidated ``bench_summary.json``: one
record per suite with its name, wall seconds, the gate rows it emitted,
and a snapshot of the process-wide metrics registry (``repro.obs``) —
the ``box.*`` queue telemetry every engine run folds into the default
registry while a suite executes.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke pass: fig9 + fig11 + ooc + query + skew "
                         "+ serve at --fast sizes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as a JSON run record")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="write a consolidated per-suite summary (name, "
                         "wall seconds, gate rows, metrics-registry "
                         "snapshot) as JSON")
    args = ap.parse_args()
    if args.smoke:
        args.fast = True

    from . import (arboricity_scaling, boxing_overhead, fabric_scaling,
                   kernel_bench, lftj_vs_mgt, outofcore, parallel_scaling,
                   query_patterns, roofline, serve_load, skew_scaling,
                   vanilla_vs_boxed)
    from .common import collected_rows, reset_rows

    suites = {
        "fig7": boxing_overhead.main,
        "fig9": vanilla_vs_boxed.main,
        "fig11": lftj_vs_mgt.main,
        "thm17": arboricity_scaling.main,
        "ooc": outofcore.main,
        "query": query_patterns.main,
        "pscale": parallel_scaling.main,
        "skew": skew_scaling.main,
        "kernels": kernel_bench.main,
        "roofline": roofline.main,
        "serve": serve_load.main,
        "fabric": fabric_scaling.main,
    }
    if args.only:
        names = [args.only]
    elif args.smoke:
        names = ["fig9", "fig11", "ooc", "query", "skew", "serve"]
    else:
        names = list(suites)
    reset_rows()
    timings = {}
    summary = []
    print("name,us_per_call,derived")
    for n in names:
        # one fresh default registry per suite: instrumented code the
        # suite constructs (engines, servers) folds its queue telemetry
        # into it without any benchmark signature changing
        reg = None
        if args.summary:
            from repro.obs import MetricsRegistry, set_default_registry
            reg = MetricsRegistry()
            set_default_registry(reg)
        rows_before = len(collected_rows())
        t0 = time.time()
        print(f"# --- {n} ---", flush=True)
        suites[n](fast=args.fast)
        timings[n] = time.time() - t0
        print(f"# {n} done in {timings[n]:.1f}s", flush=True)
        if reg is not None:
            from repro.obs import set_default_registry
            set_default_registry(None)
            summary.append({
                "name": n,
                "wall_s": round(timings[n], 3),
                "rows": collected_rows()[rows_before:],
                "metrics": reg.snapshot(),
            })
    if args.summary:
        with open(args.summary, "w") as f:
            json.dump({"suites": summary, "fast": bool(args.fast),
                       "python": platform.python_version()}, f, indent=2)
        print(f"# wrote {args.summary} ({len(summary)} suites)",
              flush=True)
    if args.json:
        record = {
            "suites": names,
            "fast": bool(args.fast),
            "python": platform.python_version(),
            "suite_seconds": {k: round(v, 2) for k, v in timings.items()},
            "rows": collected_rows(),
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json} ({len(record['rows'])} rows)",
              flush=True)


if __name__ == '__main__':
    main()
