"""Roofline report: reads results/dryrun/*.json, prints the §Roofline table.

Per (arch × shape × single-pod mesh): the three terms in seconds, the
bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device peak bytes. Also emits
one CSV row per cell (name,us_per_call,derived) where us_per_call is the
dominant term (the projected step time if the dominant resource were the
only cost — the roofline lower bound).

Run after ``python -m repro.launch.dryrun --all``.

Independent of the dry-run artifacts, one measured row compares the fused
LFTJ megakernel against the staged per-chunk lane on a hub box
(``roofline/lftj_fused/hub_box``): the launch-bound term — device
invocations × a fixed per-launch overhead — is what the fused kernel
collapses, and the ratio is measured by the kernel ledger
(``benchmarks.kernel_bench.measure_fused_vs_staged``).
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DEFAULT_DIR = Path("results/dryrun")


def load(dry_dir: Path = DEFAULT_DIR, mesh: str = "single"):
    rows = []
    for p in sorted(dry_dir.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("ok"):
            rows.append(r)
    return rows


def fmt_table(rows) -> str:
    hdr = (f"{'arch':26s} {'shape':14s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
           f"{'t_coll(s)':>10s} {'bound':>6s} {'useful':>7s} {'GB/dev':>7s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        gb = (r.get("temp_size_in_bytes", 0) +
              r.get("argument_size_in_bytes", 0)) / 2**30
        out.append(
            f"{r['arch']:26s} {r['shape']:14s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['bottleneck'][:6]:>6s} "
            f"{min(9.99, r.get('useful_flops_ratio', 0)):7.3f} {gb:7.2f}")
    return "\n".join(out)


def main(fast: bool = False, dry_dir: Path = DEFAULT_DIR) -> None:
    # fused-vs-staged launch roofline: needs no dry-run artifacts. At a
    # typical ~10 us host->device dispatch overhead, per-box launch cost
    # is proportional to the measured invocation counts — the term the
    # fused megakernel removes.
    from .kernel_bench import measure_fused_vs_staged

    ab = measure_fused_vs_staged(fast)
    emit("roofline/lftj_fused/hub_box", ab["us_fused"],
         f"bound=launch;staged_launches={ab['staged_launches']};"
         f"fused_launches={ab['fused_launches']};"
         f"launch_ratio={ab['launch_ratio']:.1f};"
         f"fused_mb_in={ab['fused_transfer_bytes']/2**20:.2f}")

    rows = load(dry_dir)
    if not rows:
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(fmt_table(rows))
    for r in rows:
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}", dom * 1e6,
             f"bound={r['bottleneck']};useful={r.get('useful_flops_ratio',0):.3f};"
             f"coll_gb={r['collective_bytes_per_device']/2**30:.2f}")


if __name__ == "__main__":
    main()
