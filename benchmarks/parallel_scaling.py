"""Parallel box scheduler: count throughput vs worker count.

The paper's experiments note that boxed LFTJ's constant-factor penalty vs
the specialized MGT "can be alleviated by parallelization" — this benchmark
measures that axis for the async scheduler: the same store-backed smoke
workload runs at ``workers ∈ {1, 2, 4, ...}`` and reports wall time,
speedup over the sequential oracle, and the scheduler telemetry
(queue-wait / utilization). Counts must be identical at every worker
count, and the listing output is verified byte-identical across worker
counts before any timing is reported.

The measured lane is ``backend="host"`` (the pure-numpy binary-search
count): numpy's searchsorted/compare kernels release the GIL, so worker
threads genuinely scale on CPU hosts. The jax device lanes are reported
for one worker pair too, but XLA's CPU client serializes concurrent
executions, so on CPU containers they only overlap with slice builds (on
TPU the device dispatch is async and the host-side build is the
bottleneck the worker pool hides).

derived: speedup=<x vs workers=1>;count=<triangles>;boxes=<n>;
         util=<frac>;wait_s=<s>;overlap_s=<s>;backend=<lane>

``python -m benchmarks.parallel_scaling --smoke --json out.json`` runs the
fast configuration standalone and writes the emitted rows as a JSON
artifact (the CI ``parallel`` job uploads it next to the out-of-core
record).
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.core import TriangleEngine
from repro.data.edgestore import write_edge_store

from .common import emit, fmt_util

B = 64


ROUNDS = 7


def main(fast: bool = False) -> None:
    from repro.data.graphs import random_graph, rmat_graph

    nv, ne = (1 << 12, 160_000) if fast else (1 << 13, 480_000)
    worker_counts = (1, 2, 4) if fast else (1, 2, 4, 8)
    src, dst = rmat_graph(nv, ne, seed=0)
    with tempfile.TemporaryDirectory() as td:
        path = write_edge_store(os.path.join(td, "g.csr"), src, dst,
                                chunk_rows=256, align_words=B)
        mem = max(1024, len(src) // 2)

        # correctness gate first: identical counts across every tested
        # worker count on the timed workload, and identical *listing*
        # output across worker counts on a triangle-sparse companion
        # workload (the hub-heavy timed graph has millions of triangles —
        # listing it would dwarf the measurement; per-graph listing
        # byte-identity is property-tested in
        # tests/test_parallel_executor.py)
        base_eng = TriangleEngine(store=path, mem_words=mem, workers=1)
        base_n = base_eng.count()
        for w in worker_counts[1:]:
            eng = TriangleEngine(store=path, mem_words=mem, workers=w)
            assert eng.count() == base_n, (w, base_n)
        ls, ld = random_graph(nv, ne // 2, seed=1)
        lpath = write_edge_store(os.path.join(td, "l.csr"), ls, ld,
                                 chunk_rows=256, align_words=B)
        lref = TriangleEngine(store=lpath, mem_words=mem, workers=1)
        base_tris = lref.list()
        assert lref.count() == len(base_tris)
        for w in worker_counts[1:]:
            tris = TriangleEngine(store=lpath, mem_words=mem,
                                  workers=w).list()
            assert tris.shape == base_tris.shape \
                and (tris == base_tris).all(), f"listing diverged at w={w}"

        # host lane: the thread-scalable backend (see module docstring).
        # Timed rounds interleave the worker counts so slow phases of a
        # shared/burstable host hit every configuration evenly.
        engines = {w: TriangleEngine(store=path, mem_words=mem,
                                     backend="host", workers=w)
                   for w in worker_counts}
        for eng in engines.values():
            assert eng.count() == base_n          # warm + correctness
        best = {w: float("inf") for w in worker_counts}
        for _ in range(ROUNDS):
            for w, eng in engines.items():
                t0 = time.perf_counter()
                eng.count()
                best[w] = min(best[w], time.perf_counter() - t0)
        for w in worker_counts:
            s = engines[w].stats
            emit(f"pscale/host/w{w}", best[w] * 1e6,
                 f"speedup={best[1] / best[w]:.2f};count={base_n};"
                 f"boxes={s.n_boxes};util={fmt_util(s.worker_utilization)};"
                 f"wait_s={s.queue_wait_s:.2f};"
                 f"overlap_s={s.overlap_s:.2f};backend=host")

        # device (auto) lane at the pool's edge, for the record: on CPU
        # XLA serializes executions, so this mostly shows build overlap
        dev = {w: TriangleEngine(store=path, mem_words=mem, workers=w)
               for w in (1, worker_counts[-1])}
        for eng in dev.values():
            assert eng.count() == base_n
        best_d = {w: float("inf") for w in dev}
        for _ in range(2):
            for w, eng in dev.items():
                t0 = time.perf_counter()
                eng.count()
                best_d[w] = min(best_d[w], time.perf_counter() - t0)
        for w, eng in dev.items():
            s = eng.stats
            emit(f"pscale/auto/w{w}", best_d[w] * 1e6,
                 f"speedup={best_d[1] / best_d[w]:.2f};count={base_n};"
                 f"boxes={s.n_boxes};util={fmt_util(s.worker_utilization)};"
                 f"wait_s={s.queue_wait_s:.2f};"
                 f"overlap_s={s.overlap_s:.2f};backend=auto")


if __name__ == "__main__":
    import argparse
    import json

    from .common import collected_rows, reset_rows

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast sizes (the CI parallel job's configuration)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON")
    args = ap.parse_args()
    reset_rows()
    print("name,us_per_call,derived")
    main(fast=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": ["pscale"], "fast": bool(args.smoke),
                       "rows": collected_rows()}, f, indent=2)
        print(f"# wrote {args.json}")
