"""Paper Fig. 9 + Prop. 4: vanilla vs boxed LFTJ block I/Os under LRU.

The container has no disk pressure, so the comparison runs in the paper's
own cost model (core.iomodel): block size B, M/B LRU frames, one unit per
block fetch. Three instances:

  * G_N (Prop. 4 adversarial): vanilla must pay >= |E| I/Os;
  * RMAT at 10% / 25% / 35% memory (the paper's Fig. 9 fractions);
  * RAND at the same fractions.

derived: vanilla=<io>;boxed=<io>;ratio=<x>;thm13_bound=<io>
"""

from __future__ import annotations

import numpy as np

from repro.core import (BlockDevice, TriangleEngine, TrieArray,
                        adversarial_graph, boxed_triangle_count,
                        count_triangles, orient_edges)
from repro.data.graphs import random_graph, rmat_graph

from .common import emit, timeit

B = 64


def measure(src, dst, frac: float):
    a, b = orient_edges(src, dst)
    ta = TrieArray.from_edges(a, b)
    words = ta.words()
    m = max(B * 4, int(words * frac))
    dev = BlockDevice(block_words=B, cache_blocks=max(2, m // B))
    count_triangles(src, dst, method="faithful", device=dev)
    vanilla = dev.stats.block_reads
    dev2 = BlockDevice(block_words=B, cache_blocks=max(2, m // B))
    dev2.register_triearray(ta)
    _, st = boxed_triangle_count(ta, m, block_words=B, device=dev2)
    boxed = dev2.stats.block_reads
    bound = words * words / (m * B) + words / B
    return vanilla, boxed, bound, st


def main(fast: bool = False) -> None:
    # Prop. 4 adversarial instance
    m_adv = 400
    src, dst = adversarial_graph(1600, m_adv, 16)
    dev = BlockDevice(block_words=16, cache_blocks=m_adv // 16)
    us = timeit(lambda: count_triangles(src, dst, method="faithful",
                                        device=dev), repeats=1)
    emit("prop4_adversarial_vanilla", us,
         f"io={dev.stats.block_reads};edges={len(src)};"
         f"io_per_edge={dev.stats.block_reads/len(src):.2f}")

    size = 14000 if fast else 40000
    graphs = {"RMAT": rmat_graph(1 << 11, size, seed=0),
              "RAND": random_graph(1 << 11, size, seed=0)}
    fracs = (0.10,) if fast else (0.10, 0.25, 0.35)
    for gname, (s, d) in graphs.items():
        for frac in fracs:
            van, box, bound, st = measure(s, d, frac)
            emit(f"fig9/{gname}/m{int(frac*100)}", 0.0,
                 f"vanilla={van};boxed={box};ratio={van/max(1,box):.2f};"
                 f"thm13_bound={bound:.0f};boxes={st.n_boxes}")
            # wall-clock of the same budget through the unified engine
            # (in-memory execution of the identical box plan)
            a2, b2 = orient_edges(s, d)
            m = max(B * 4, int(TrieArray.from_edges(a2, b2).words() * frac))
            eng = TriangleEngine(s, d, mem_words=m)
            us_e = timeit(lambda: eng.count(), repeats=1)
            emit(f"fig9_engine/{gname}/m{int(frac*100)}", us_e,
                 f"count={eng.count()};boxes={eng.stats.n_boxes};"
                 f"dense={eng.stats.n_dense_boxes}")


if __name__ == "__main__":
    main()
