"""QueryEngine: the triangle query pinned to TriangleEngine, general
patterns pinned to independent brute-force references.

Headline acceptance (ISSUE 5):

* QueryEngine(triangle) matches ``TriangleEngine`` counts AND listings
  across graphs x orientations x workers {1, 4} x cache on/off, and — for
  store-backed runs — the *measured* ``block_reads`` are equal under the
  same ``mem_words`` budget (the planner/fetcher reproduce the triangle
  executor's read stream exactly).
* QueryEngine(4-clique / diamond / 3-path) matches nested-loop brute-force
  references exactly, boxed and unboxed, at workers {1, 4}.
* planner invariants: boxes cover the domain, triangle plan == the
  triangle planner's plan, rank values per Def. 12.
"""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TriangleEngine, TrieArray, best_order, orient_edges,
                        rank, run_query, validate)
from repro.core.boxing import plan_boxes_from_degrees
from repro.core.lftj_jax import csr_from_edges
from repro.core.queries import Query, reordered_index
from repro.core.leapfrog import Atom, lftj_query_count
from repro.data.edgestore import write_edge_store
from repro.data.graphs import clustered_graph, random_graph, rmat_graph
from repro.query import QueryEngine, patterns, plan_query_boxes, \
    thm13_io_bound

WORKERS = (1, 4)


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def oriented_trie(src, dst, orientation="minmax"):
    a, b = orient_edges(src, dst, orientation)
    return TrieArray.from_edges(a, b)


def canonical(rows: np.ndarray) -> np.ndarray:
    rows = np.asarray(rows, dtype=np.int64)
    if len(rows) == 0:
        return rows.reshape(0, rows.shape[1] if rows.ndim == 2 else 0)
    order = np.lexsort(tuple(rows[:, c] for c in range(rows.shape[1] - 1,
                                                       -1, -1)))
    return rows[order]


def brute_force(q: Query, src, dst, orientation="minmax"):
    """Independent nested-loop reference: recursive enumeration over the
    oriented adjacency with eager atom checks (no LFTJ machinery)."""
    a, b = orient_edges(src, dst, orientation)
    edges = set(zip(a.tolist(), b.tolist()))
    succ = {}
    for u, v in edges:
        succ.setdefault(u, []).append(v)
    domain = sorted({x for e in edges for x in e})
    vs = q.variables()
    rows = []

    def rec(i, binding):
        if i == len(vs):
            rows.append(tuple(binding[h] for h in q.head))
            return
        var = vs[i]
        for val in domain:
            binding[var] = val
            ok = True
            for atom in q.atoms:
                if all(w in binding for w in atom.vars):
                    if (binding[atom.vars[0]],
                            binding[atom.vars[1]]) not in edges:
                        ok = False
                        break
            if ok:
                rec(i + 1, binding)
        del binding[var]

    rec(0, {})
    return len(rows), canonical(np.asarray(rows, np.int64).reshape(
        -1, len(q.head)))


class TestTrianglePinnedToEngine:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from(["minmax", "degree"]),
           st.sampled_from(WORKERS), st.sampled_from([0, 256]))
    def test_count_and_listing_match(self, seed, orientation, workers,
                                     cache_words):
        src, dst = er_graph(56, 0.18, seed % 997)
        te = TriangleEngine(src, dst, orientation=orientation,
                            mem_words=400, shard=False)
        qe = QueryEngine.from_graph(patterns.triangle(), src, dst,
                                    orientation=orientation, mem_words=400,
                                    workers=workers, cache_words=cache_words)
        assert te.count() == qe.count()
        tl = te.list()
        ql = canonical(np.sort(qe.list(), axis=1))
        assert np.array_equal(tl, ql)

    @pytest.mark.parametrize("workers", WORKERS)
    @pytest.mark.parametrize("cache_words", [0, 512])
    @pytest.mark.parametrize("mem_words", [300, 1500])
    def test_store_backed_block_read_parity(self, tmp_path, workers,
                                            cache_words, mem_words):
        """The acceptance pin: same counts AND same measured block reads
        as TriangleEngine under the same budget, any worker count, cache
        on or off."""
        src, dst = rmat_graph(192, 2200, seed=11)
        path = os.path.join(tmp_path, "g.csr")
        write_edge_store(path, src, dst, chunk_rows=64, align_words=64)
        te = TriangleEngine(store=path, mem_words=mem_words,
                            workers=workers, cache_words=cache_words,
                            io_block_words=64, shard=False)
        tc = te.count()
        qe = QueryEngine(patterns.triangle(), store=path,
                         mem_words=mem_words, workers=workers,
                         cache_words=cache_words, io_block_words=64)
        qc = qe.count()
        assert tc == qc
        assert qe.stats.block_reads == te.stats.block_reads
        assert qe.stats.cache_hits == te.stats.cache_hits
        assert qe.stats.rank == 2
        if cache_words:
            # the cache serves repeat row-blocks in both engines alike
            assert qe.stats.cache_hit_words == te.stats.cache_hit_words

    def test_store_plan_matches_triangle_planner(self, tmp_path):
        src, dst = rmat_graph(128, 1500, seed=7)
        path = os.path.join(tmp_path, "g.csr")
        write_edge_store(path, src, dst, chunk_rows=64, align_words=64)
        te = TriangleEngine(store=path, mem_words=500, shard=False)
        qe = QueryEngine(patterns.triangle(), store=path, mem_words=500)
        tri_boxes = te.plan()
        q_boxes = qe.plan().boxes
        assert len(tri_boxes) == len(q_boxes)
        for (lx, hx, ly, hy), qb in zip(tri_boxes, q_boxes):
            assert qb[0] == (lx, hx) and qb[1] == (ly, hy)


class TestPatternGolden:
    """4-clique / diamond / 3-path pinned to brute force on fixtures."""

    FIXTURES = [
        lambda: er_graph(20, 0.35, 3),
        lambda: clustered_graph(3, 7, seed=1, p_in=0.7),
        lambda: random_graph(24, 90, seed=5),
    ]

    @pytest.mark.parametrize("fix", range(len(FIXTURES)))
    @pytest.mark.parametrize("pattern", ["four_clique", "diamond", "path3"])
    def test_counts_and_listings_vs_brute_force(self, fix, pattern):
        src, dst = self.FIXTURES[fix]()
        q = patterns.PATTERNS[pattern]()
        want, want_rows = brute_force(q, src, dst)
        for mem in (None, 200):
            for workers in WORKERS:
                qe = QueryEngine.from_graph(q, src, dst, mem_words=mem,
                                            workers=workers)
                assert qe.count() == want, (pattern, fix, mem, workers)
                got_rows = canonical(qe.list())
                assert np.array_equal(got_rows, want_rows)

    @pytest.mark.parametrize("pattern", ["four_clique", "diamond", "path3",
                                         "cycle4"])
    def test_matches_scalar_lftj(self, pattern):
        """Cross-check against the faithful scalar reference on a larger
        graph than brute force can handle."""
        src, dst = rmat_graph(96, 900, seed=23)
        q = patterns.PATTERNS[pattern]()
        ta = oriented_trie(src, dst)
        want = run_query(q, q.head, {"E": ta})
        got = QueryEngine.from_graph(q, src, dst, mem_words=400).count()
        assert got == want

    def test_pallas_lane_matches_host(self):
        src, dst = er_graph(32, 0.3, 9)
        q = patterns.diamond()
        host = QueryEngine.from_graph(q, src, dst, backend="host",
                                      mem_words=150)
        kern = QueryEngine.from_graph(q, src, dst, backend="pallas",
                                      mem_words=150)
        assert host.count() == kern.count()
        assert kern.stats.n_kernel_boxes > 0
        assert host.stats.n_kernel_boxes == 0

    def test_parallel_listing_deterministic(self):
        src, dst = rmat_graph(96, 900, seed=31)
        q = patterns.diamond()
        l1 = QueryEngine.from_graph(q, src, dst, mem_words=300,
                                    workers=1).list()
        l4 = QueryEngine.from_graph(q, src, dst, mem_words=300,
                                    workers=4).list()
        assert np.array_equal(l1, l4)

    def test_empty_and_degenerate(self):
        e = np.zeros(0, np.int64)
        assert QueryEngine.from_graph(patterns.triangle(), e, e).count() == 0
        assert QueryEngine.from_graph(patterns.four_clique(),
                                      np.array([0]),
                                      np.array([1])).count() == 0


class TestPlannerInvariants:
    def test_triangle_plan_equals_triangle_planner(self):
        src, dst = rmat_graph(128, 1500, seed=13)
        a, b = orient_edges(src, dst)
        nv = int(max(a.max(), b.max())) + 1
        indptr, _ = csr_from_edges(a, b, n_nodes=nv)
        for mem in (200, 800, 5000):
            want = plan_boxes_from_degrees(indptr, mem)
            q = patterns.triangle()
            atoms = [Atom("E", t.vars) for t in q.atoms]
            plan = plan_query_boxes(atoms, ("x", "y", "z"), {"E": indptr},
                                    mem, directions={0: 1, 1: 1, 2: 1})
            assert len(plan.boxes) == len(want)
            for (lx, hx, ly, hy), qb in zip(want, plan.boxes):
                assert qb[:2] == ((lx, hx), (ly, hy))
                assert qb[2] == (0, nv - 1)         # z unowned: full span

    def test_boxes_cover_domain(self):
        src, dst = rmat_graph(96, 1100, seed=17)
        q = patterns.four_clique()
        qe = QueryEngine.from_graph(q, src, dst, mem_words=300)
        plan = qe.plan()
        assert plan.rank == 3
        # every owned dim's cuts tile [0, nv) without gaps or overlaps
        for d in plan.owned_dims:
            cuts = sorted({b[d] for b in plan.boxes})
            # cuts may be pruned at the box level; reconstruct from the
            # unpruned projection: starts must chain lo=prev_hi+1
            lo = cuts[0][0]
            assert lo == 0
            for (a_, b_), (c_, d_) in zip(cuts, cuts[1:]):
                assert c_ == b_ + 1
            assert cuts[-1][1] == qe._nv_all - 1

    def test_rank_values(self):
        assert rank(patterns.triangle()) == 2
        assert rank(patterns.four_clique(), patterns.four_clique().head) == 3
        assert rank(patterns.diamond(), patterns.diamond().head) == 3
        # reordered indexes buy rank 2 for the diamond and the 3-path
        assert rank(patterns.diamond()) == 2
        assert rank(patterns.path(3)) == 2

    def test_thm13_bound_shape(self):
        # rank 2 at |I|=1000, M=100, B=10: 1000^2/(100*10) + K/B
        assert thm13_io_bound(1000, 100, 10, 2) == pytest.approx(1000.0)
        assert thm13_io_bound(1000, 100, 10, 2, output_words=100) \
            == pytest.approx(1010.0)

    def test_validate_and_errors(self):
        q = patterns.triangle()
        assert validate(q) == ("x", "y", "z")
        with pytest.raises(ValueError):
            validate(q, ("x", "y"))                 # not a permutation
        with pytest.raises(ValueError):
            validate(Query(head=("q",), atoms=q.atoms))  # head not in body
        r, order = best_order(patterns.path(3), allow_reorder=False)
        assert r == 3                                # consistent orders only

    def test_engine_rejects_nonbinary_and_unknown_relation(self):
        bad = Query(head=("x", "y", "z"),
                    atoms=[Atom("R", ("x", "y", "z"))])
        with pytest.raises(ValueError, match="binary"):
            QueryEngine(bad, relations={"R": (np.zeros(0), np.zeros(0))})
        with pytest.raises(ValueError, match="no source"):
            QueryEngine(patterns.triangle(), relations={})

    def test_store_rejects_inconsistent_order(self, tmp_path):
        src, dst = random_graph(24, 60, seed=2)
        path = os.path.join(tmp_path, "g.csr")
        write_edge_store(path, src, dst)
        # the diamond's best *consistent* order is its natural one; forcing
        # an order that needs reversed indexes must fail loudly on a store
        with pytest.raises(ValueError, match="reordered index"):
            QueryEngine(patterns.diamond(), store=path,
                        order=("w", "x", "y", "z"))
        # while the consistent natural order runs fine
        n = QueryEngine(patterns.diamond(), store=path,
                        order=("x", "y", "z", "w")).count()
        assert n == QueryEngine.from_graph(patterns.diamond(),
                                           src, dst).count()


class TestRelationSources:
    def test_duplicate_tuples_deduplicated(self):
        """A (src, dst) relation source follows set semantics — duplicate
        pairs must not duplicate bindings (the TrieArray reference path
        dedups, so the engine has to as well)."""
        q = Query(head=("x", "y", "z"),
                  atoms=[Atom("R", ("x", "y")), Atom("R", ("y", "z"))])
        src = np.array([0, 0, 1])     # (0,1) twice
        dst = np.array([1, 1, 2])
        eng = QueryEngine(q, relations={"R": (src, dst)})
        assert eng.count() == 1       # (0, 1, 2) once
        ta = TrieArray.from_edges(src, dst)
        assert eng.count() == run_query(q, q.head, {"R": ta})

    def test_device_charges_tuple_sources_and_reversed_alike(self):
        """A user device= must charge forward AND reversed-index reads of
        tuple-built in-memory relations — no asymmetric ledger."""
        from repro.core import BlockDevice

        q = Query(head=("x", "y"), atoms=[Atom("R", ("x", "y"))])
        dev = BlockDevice(block_words=8, cache_blocks=2)
        eng = QueryEngine(q, relations={"R": (np.array([0, 1]),
                                              np.array([1, 2]))},
                          device=dev)
        assert eng.count() == 2
        assert eng.stats.word_reads > 0       # forward reads charged
        # reversed order: the reversed index's reads are charged on the
        # same device, so the ledger stays symmetric
        dev2 = BlockDevice(block_words=8, cache_blocks=2)
        eng2 = QueryEngine(q, relations={"R": (np.array([0, 1]),
                                               np.array([1, 2]))},
                           order=("y", "x"), device=dev2)
        assert eng2.count() == 2
        assert eng2.stats.word_reads > 0


class TestReorderedIndexCache:
    def test_shared_relation_builds_each_permutation_once(self):
        src, dst = random_graph(30, 120, seed=4)
        ta = oriented_trie(src, dst)
        r1 = reordered_index(ta, (1, 0))
        r2 = reordered_index(ta, (1, 0))
        assert r1 is r2
        # a different relation object gets its own cache
        tb = oriented_trie(src, dst)
        assert reordered_index(tb, (1, 0)) is not r1

    def test_run_query_reuses_cached_index(self):
        src, dst = random_graph(30, 120, seed=4)
        ta = oriented_trie(src, dst)
        q = patterns.diamond()
        order = ("w", "x", "y", "z")  # E(y,w) and E(z,w) both need (w, .)
        n1 = run_query(q, order, {"E": ta})
        cache = ta._reorder_cache
        assert len(cache) == 1        # one permutation, shared by 2 atoms
        before = {k: id(v) for k, v in cache.items()}
        n2 = run_query(q, order, {"E": ta})
        assert n1 == n2
        assert {k: id(v) for k, v in ta._reorder_cache.items()} == before

    def test_engine_reversed_csr_cached_on_source(self):
        src, dst = random_graph(30, 120, seed=6)
        q = patterns.diamond()
        e1 = QueryEngine.from_graph(q, src, dst, order=("w", "x", "y", "z"))
        rel_src = e1._raw["E"]
        csr1 = rel_src._reverse_csr
        e2 = QueryEngine(q, relations={"E": rel_src},
                         order=("w", "x", "y", "z"))
        assert rel_src._reverse_csr is csr1
        assert e1.count() == e2.count()


class TestScalarDeviceHook:
    def test_lftj_query_count_charges_device(self):
        from repro.core import BlockDevice

        src, dst = random_graph(40, 200, seed=8)
        ta = oriented_trie(src, dst)
        q = patterns.triangle()
        dev = BlockDevice(block_words=16, cache_blocks=4)
        n = lftj_query_count(q.atoms, q.head, {"E": ta}, device=dev)
        assert n == run_query(q, q.head, {"E": ta})
        assert dev.stats.block_reads > 0
