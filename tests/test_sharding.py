"""Model-based tests for the box-sharding layer (``parallel.sharding``):
interval bookkeeping, box cost pricing, LPT scheduling, the queue-order
regression contract, and the two slice-shipping planners (the triangle
engine's renumbered local slices and the fabric's rank-r byte ranges).

Everything is pinned against tiny brute-force models — a cost is "the
words a fetch reads" computed by literally enumerating rows; a schedule
is "an exact partition"; a shipped range list is "sorted, disjoint, and
covering exactly the rows some assigned box touches".
"""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lftj_jax import SENTINEL, csr_from_edges, orient_edges
from repro.data.graphs import random_graph
from repro.parallel.sharding import (balanced_box_schedule, box_mass_costs,
                                     box_mass_costs_nd, box_queue_order,
                                     interval_gaps, lpt_order,
                                     merge_interval, shard_local_slices,
                                     shard_shipped_ranges)
from repro.query.executor import QueryEngine
from repro.query.patterns import PATTERNS


def small_csr(seed=0, nv=48, ne=160):
    src, dst = random_graph(nv, ne, seed=seed)
    a, b = orient_edges(src, dst)
    n = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
    ip, _ix = csr_from_edges(a, b, n_nodes=n)
    return np.asarray(ip, np.int64)


# ---------------------------------------------------------------------------
# interval bookkeeping
# ---------------------------------------------------------------------------

class TestIntervals:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 60)),
                    max_size=8),
           st.integers(0, 60), st.integers(0, 60))
    def test_merge_and_gaps_match_set_model(self, raw, qlo, qhi):
        covered = []
        model = set()
        for a, b in raw:
            lo, hi = min(a, b), max(a, b)
            covered = merge_interval(covered, lo, hi)
            model |= set(range(lo, hi + 1))
        # merged list is sorted, disjoint, non-adjacent, and == the model
        for (a, b), (c, d) in zip(covered, covered[1:]):
            assert b + 1 < c
        got = set()
        for a, b in covered:
            assert a <= b
            got |= set(range(a, b + 1))
        assert got == model
        # gaps of [qlo, qhi] are exactly the uncovered points in it
        qlo, qhi = min(qlo, qhi), max(qlo, qhi)
        gap_pts = set()
        for a, b in interval_gaps(covered, qlo, qhi):
            assert qlo <= a <= b <= qhi
            gap_pts |= set(range(a, b + 1))
        assert gap_pts == set(range(qlo, qhi + 1)) - model


# ---------------------------------------------------------------------------
# box cost pricing
# ---------------------------------------------------------------------------

class TestBoxMassCosts:
    def _brute(self, ip, box):
        """Literal words-read model: x-slab rows plus y-range rows, each
        distinct row counted once."""
        lx, hx, ly, hy = box
        nv = len(ip) - 1
        rows = set(range(max(0, lx), min(hx, nv - 1) + 1)) \
            | set(range(max(0, ly), min(hy, nv - 1) + 1))
        return sum(int(ip[r + 1] - ip[r]) for r in rows)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000),
           st.lists(st.tuples(st.integers(0, 47), st.integers(0, 47),
                              st.integers(0, 47), st.integers(0, 47)),
                    min_size=1, max_size=6))
    def test_matches_brute_force(self, seed, raw):
        ip = small_csr(seed % 97)
        boxes = [(min(a, b), max(a, b), min(c, d), max(c, d))
                 for a, b, c, d in raw]
        got = box_mass_costs(ip, boxes)
        assert got == [self._brute(ip, box) for box in boxes]

    def test_monotone_in_box_growth(self):
        """Growing a box never lowers its mass (the LPT input must be a
        monotone size proxy or balancing is meaningless)."""
        ip = small_csr(3)
        nv = len(ip) - 1
        for lx, hx, ly, hy in [(0, 10, 0, 10), (5, 20, 2, 8),
                               (0, nv - 1, 0, 0)]:
            base = box_mass_costs(ip, [(lx, hx, ly, hy)])[0]
            for grown in [(lx, min(hx + 5, nv - 1), ly, hy),
                          (max(0, lx - 3), hx, ly, hy),
                          (lx, hx, ly, min(hy + 7, nv - 1)),
                          (lx, hx, max(0, ly - 2), hy)]:
                assert box_mass_costs(ip, [grown])[0] >= base

    @pytest.mark.parametrize("pattern", ["triangle", "diamond", "path3"])
    def test_nd_costs_equal_engine_fetch_estimate(self, pattern):
        """``box_mass_costs_nd`` prices a plan box at exactly the raw
        words the engine's fetch will read (``_est_box_words``) — the
        fabric schedules on true fetch mass, for every rank."""
        src, dst = random_graph(96, 400, seed=11)
        eng = QueryEngine.from_graph(PATTERNS[pattern](), src, dst,
                                     mem_words=1 << 11)
        plan = eng.plan()
        dim_keys = eng.owned_dim_keys()
        ips = {}
        for _d, keys in dim_keys:
            for k in keys:
                ips[k] = np.asarray(eng.source_for(k).indptr)
        got = box_mass_costs_nd(plan.boxes, dim_keys, ips)
        assert got == [eng._est_box_words(box) for box in plan.boxes]

    def test_nd_reproduces_triangle_costs(self):
        """On a single-relation rank-2 plan the n-d pricing degrades to
        the triangle ``box_mass_costs`` row for row."""
        src, dst = random_graph(96, 400, seed=5)
        eng = QueryEngine.from_graph(PATTERNS["triangle"](), src, dst,
                                     mem_words=1 << 11)
        plan = eng.plan()
        key = eng.source_keys()[0]
        ip = np.asarray(eng.source_for(key).indptr)
        flat = [(b[0][0], b[0][1], b[1][0], b[1][1]) for b in plan.boxes]
        assert box_mass_costs_nd(plan.boxes, eng.owned_dim_keys(),
                                 {key: ip}) == box_mass_costs(ip, flat)


# ---------------------------------------------------------------------------
# queue order + schedule
# ---------------------------------------------------------------------------

class TestScheduling:
    def test_box_queue_order_regression(self):
        """Regression contract (PR 9): with a ledger attached the drain
        order is PLAN order — even for a workers=1 caller, where LPT would
        be equally safe — so measured I/O is a function of configuration
        alone and a fabric shard replays byte-identically at any worker
        count. Without a ledger it is LPT."""
        costs = [3.0, 9.0, 1.0, 9.0, 4.0]
        assert box_queue_order(costs, ledger_sensitive=True) == \
            list(range(len(costs)))
        assert box_queue_order(costs, ledger_sensitive=False) == \
            lpt_order(costs)
        assert lpt_order(costs) == [1, 3, 4, 0, 2]  # ties by index

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 1000), min_size=1, max_size=40),
           st.integers(1, 8))
    def test_balanced_schedule_is_exact_partition(self, costs, n_shards):
        sched = balanced_box_schedule(costs, n_shards)
        assert len(sched) == n_shards
        flat = [b for s in sched for b in s]
        assert sorted(flat) == list(range(len(costs)))
        # greedy LPT: no shard exceeds mean + max cost (the 4/3-OPT
        # argument's slack term)
        loads = [sum(costs[b] for b in s) for s in sched]
        if costs:
            assert max(loads) <= sum(costs) / n_shards + max(costs)


# ---------------------------------------------------------------------------
# shipping planners
# ---------------------------------------------------------------------------

class TestShardShippedRanges:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 6))
    def test_ranges_cover_exactly_the_touched_rows(self, seed, n_shards):
        src, dst = random_graph(96, 400, seed=seed % 97)
        eng = QueryEngine.from_graph(PATTERNS["diamond"](), src, dst,
                                     mem_words=1 << 10)
        plan = eng.plan()
        dim_keys = eng.owned_dim_keys()
        nv = {k: eng.source_for(k).n_nodes
              for _d, keys in dim_keys for k in keys}
        costs = box_mass_costs_nd(
            plan.boxes, dim_keys,
            {k: np.asarray(eng.source_for(k).indptr) for k in nv})
        sched = balanced_box_schedule(costs, n_shards)
        shipped = shard_shipped_ranges(plan.boxes, sched, dim_keys, nv)
        assert len(shipped) == n_shards

        def touched(box_ids):
            rows = {k: set() for k in nv}
            for b in box_ids:
                for d, keys in dim_keys:
                    lo, hi = plan.boxes[b][d]
                    for k in keys:
                        lo_, hi_ = max(int(lo), 0), min(int(hi), nv[k] - 1)
                        rows[k] |= set(range(lo_, hi_ + 1))
            return rows

        union = {k: set() for k in nv}
        for box_ids, ranges in zip(sched, shipped):
            model = touched(box_ids)
            for k in nv:
                ivals = ranges.get(k, [])
                # sorted, disjoint, non-adjacent
                for (a, b), (c, d) in zip(ivals, ivals[1:]):
                    assert b + 1 < c
                got = set()
                for a, b in ivals:
                    got |= set(range(a, b + 1))
                # nothing replicated: exactly the touched rows, no more
                assert got == model[k]
                union[k] |= got
        # the union over shards covers every row any box touches
        assert union == touched(range(len(plan.boxes)))


class TestShardLocalSlices:
    def _edges_and_gather(self, seed=2):
        src, dst = random_graph(48, 180, seed=seed)
        a, b = orient_edges(src, dst)
        n = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        ip, ix = csr_from_edges(a, b, n_nodes=n)
        ip, ix = np.asarray(ip, np.int64), np.asarray(ix, np.int64)
        edge_lists = []
        for lo in range(0, n, 12):
            hi = min(lo + 11, n - 1)
            mask = (a >= lo) & (a <= hi)
            edge_lists.append((a[mask].astype(np.int64),
                               b[mask].astype(np.int64)))

        def gather(rows):
            deg = np.diff(ip)[rows] if len(rows) else np.zeros(0, np.int64)
            vals = np.concatenate([ix[ip[r]:ip[r + 1]] for r in rows]) \
                if len(rows) else np.zeros(0, np.int64)
            return deg, vals

        return edge_lists, ip, ix, gather

    @pytest.mark.parametrize("pad_multiple", [1, 8])
    def test_local_slices_renumber_and_cover(self, pad_multiple):
        edge_lists, ip, ix, gather = self._edges_and_gather()
        sched = balanced_box_schedule(
            [len(eu) for eu, _ in edge_lists], 3)
        eu_s, ev_s, ok_s, npad_s, rows_s = shard_local_slices(
            edge_lists, sched, gather, pad_multiple=pad_multiple)
        assert eu_s.shape == ev_s.shape == ok_s.shape
        assert eu_s.shape[1] % pad_multiple == 0
        for s, boxes in enumerate(sched):
            want_eu = np.concatenate(
                [edge_lists[b][0] for b in boxes]) if boxes else \
                np.zeros(0, np.int64)
            want_ev = np.concatenate(
                [edge_lists[b][1] for b in boxes]) if boxes else \
                np.zeros(0, np.int64)
            n_valid = int(ok_s[s].sum())
            assert n_valid == len(want_eu)
            rows = rows_s[s]
            # valid slots decode (via the shard's row map) to the exact
            # global edges; pad slots reference the all-SENTINEL pad row
            np.testing.assert_array_equal(rows[eu_s[s, :n_valid]], want_eu)
            np.testing.assert_array_equal(rows[ev_s[s, :n_valid]], want_ev)
            pad_row = int((rows >= 0).sum())
            assert (eu_s[s, n_valid:] == pad_row).all()
            assert (npad_s[s, pad_row] == SENTINEL).all()
            # each referenced row's local neighbor list is the global one
            for local, g in enumerate(rows):
                if g < 0:
                    break
                d = int(ip[g + 1] - ip[g])
                np.testing.assert_array_equal(npad_s[s, local, :d],
                                              ix[ip[g]:ip[g + 1]])
                assert (npad_s[s, local, d:] == SENTINEL).all()
            # nothing replicated: only rows its boxes reference appear
            referenced = set(want_eu) | set(want_ev)
            assert set(rows[rows >= 0]) == referenced
