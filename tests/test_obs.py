"""Observability layer (PR 10): tracer spans + Chrome export, the
cross-layer metrics registry, and the contracts the instrumentation must
keep.

Two properties anchor everything:

* **Traced-off identity.** Attaching a ``Tracer``/``MetricsRegistry``
  must never change execution: counts, listings and measured
  ``block_reads`` are byte-identical traced-on vs traced-off.
* **Exact-sum adoption.** The registry mirrors the existing ledgers; the
  per-tag ``io.*`` series (including the ``_untagged`` residual) must
  sum to the raw ``BlockDevice`` globals field by field, and the
  per-tenant ``cache.*`` series (including ``_shared``) to the raw
  ``SharedSliceCache`` globals — property-checked over random served
  query mixes.
"""

import json
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import EngineStats, TriangleEngine
from repro.core.executor import merge_queue_telemetry
from repro.data.graphs import random_graph, rmat_graph
from repro.obs import (MetricsRegistry, Tracer, default_registry,
                       set_default_registry, wrap_stage)
from repro.query import QueryEngine
from repro.query.patterns import PATTERNS
from repro.serve import Server

GRAPH = rmat_graph(512, 6000, seed=21)
SMALL = random_graph(200, 1500, seed=7)

IO_FIELDS = ("block_reads", "block_writes", "word_reads", "probes",
             "cache_served_words")
CACHE_FIELDS = ("hits", "misses", "hit_words", "miss_words",
                "passthrough_words")


def canon(rows: np.ndarray) -> np.ndarray:
    if len(rows) == 0:
        return rows
    return rows[np.lexsort(rows.T[::-1])]


def serve_server(graph=GRAPH, **kw):
    kw.setdefault("mem_words", 1 << 15)
    kw.setdefault("use_pallas_kernels", False)
    src, dst = graph
    return Server.from_graph(src, dst, **kw)


def labeled_sum(reg, name, label):
    """Sum of every series of ``name`` carrying ``label`` (any value)."""
    return sum(v for key, v in reg.series(name).items()
               if any(k == label for k, _ in key))


def unlabeled_value(reg, name, label):
    """The one series of ``name`` with no ``label`` label (the global)."""
    vals = [v for key, v in reg.series(name).items()
            if not any(k == label for k, _ in key)]
    assert len(vals) == 1, (name, vals)
    return vals[0]


# ---------------------------------------------------------------------------
# tracer unit behaviour
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nesting_records_parent_chain(self):
        tr = Tracer()
        with tr.span("outer", n=1):
            with tr.span("inner"):
                tr.event("leaf", k=3)
        ev = tr.snapshot()
        begins = {e["name"]: e for e in ev if e["ph"] == "B"}
        assert begins["outer"]["parent"] is None
        assert begins["inner"]["parent"] == begins["outer"]["sid"]
        leaf = next(e for e in ev if e["ph"] == "i")
        assert leaf["parent"] == begins["inner"]["sid"]
        assert begins["outer"]["args"] == {"n": 1}
        # two ends, popping innermost first
        ends = [e for e in ev if e["ph"] == "E"]
        assert [e["sid"] for e in ends] == [begins["inner"]["sid"],
                                            begins["outer"]["sid"]]

    def test_span_names_in_order(self):
        tr = Tracer()
        with tr.span("a"):
            pass
        with tr.span("b"):
            with tr.span("a"):
                pass
        assert tr.span_names() == ["a", "b"]

    def test_ring_buffer_bounds_memory_and_counts_dropped(self):
        tr = Tracer(capacity=16)
        for i in range(50):
            tr.event("tick", i=i)
        assert len(tr.snapshot()) == 16
        assert tr.dropped == 34
        # the surviving window is the most recent one
        assert [e["args"]["i"] for e in tr.snapshot()] == list(range(34, 50))
        tr.clear()
        assert tr.snapshot() == [] and tr.dropped == 0

    def test_exception_unwinds_span_stack(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("outer"):
                with tr.span("inner"):
                    raise RuntimeError("boom")
        with tr.span("after"):
            pass
        after = next(e for e in tr.snapshot()
                     if e["ph"] == "B" and e["name"] == "after")
        assert after["parent"] is None

    def test_threads_get_independent_stacks(self):
        tr = Tracer()
        seen = {}

        def worker():
            with tr.span("child"):
                seen["parent"] = next(
                    e["parent"] for e in reversed(tr.snapshot())
                    if e["ph"] == "B" and e["name"] == "child")

        with tr.span("main-span"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        # the other thread's span must NOT parent under this thread's
        assert seen["parent"] is None

    def test_to_chrome_is_valid_and_balanced(self, tmp_path):
        tr = Tracer()
        with tr.lane("shard0"), tr.span("fabric.shard", shard=0):
            tr.event("cache.hit", words=8)
        with tr.span("engine.count"):
            pass
        doc = tr.to_chrome()
        json.loads(json.dumps(doc))       # round-trips
        ev = doc["traceEvents"]
        assert sum(1 for e in ev if e["ph"] == "B") \
            == sum(1 for e in ev if e["ph"] == "E")
        for e in ev:
            assert {"ph", "pid", "tid", "name"} <= set(e)
        lanes = {e["args"]["name"] for e in ev if e["ph"] == "M"}
        assert lanes == {"main", "shard0"}
        # lane events live in their own pid row
        pid_of = {e["args"]["name"]: e["pid"] for e in ev if e["ph"] == "M"}
        shard_b = next(e for e in ev
                       if e["ph"] == "B" and e["name"] == "fabric.shard")
        assert shard_b["pid"] == pid_of["shard0"]
        path = tr.export_chrome(str(tmp_path / "trace.json"))
        with open(path) as f:
            assert json.load(f)["traceEvents"]

    def test_to_chrome_drops_orphaned_ends(self):
        tr = Tracer(capacity=16)
        with tr.span("long"):
            for i in range(40):          # evicts the "long" begin
                tr.event("tick", i=i)
        ev = tr.to_chrome()["traceEvents"]
        assert sum(1 for e in ev if e["ph"] == "B") \
            == sum(1 for e in ev if e["ph"] == "E")

    def test_args_degrade_to_jsonable(self):
        tr = Tracer()
        tr.event("k", arr=np.int32(7), obj=object(), s="x", none=None)
        ev = tr.to_chrome()["traceEvents"]
        rec = next(e for e in ev if e["ph"] == "i")
        json.dumps(rec)
        assert rec["args"]["arr"] == 7
        assert isinstance(rec["args"]["obj"], str)

    def test_wrap_stage_is_identity_when_off(self):
        def fn(x):
            return x + 1
        assert wrap_stage(None, "box.fetch", fn) is fn
        tr = Tracer()
        wrapped = wrap_stage(tr, "box.fetch", fn)
        assert wrapped(1) == 2
        assert tr.span_names() == ["box.fetch"]


# ---------------------------------------------------------------------------
# metrics registry unit behaviour
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counters_gauges_histograms(self):
        reg = MetricsRegistry()
        reg.inc("kernel.invocations", 2, op="staged")
        reg.inc("kernel.invocations", 3, op="staged")
        reg.inc("kernel.invocations", 5, op="fused")
        reg.set("box.pool", 4, lane="all")
        for v in (1.0, 2.0, 10.0):
            reg.observe("serve.latency_s", v, mode="count")
        assert reg.get("kernel.invocations", op="staged") == 5
        assert reg.get("box.pool", lane="all") == 4
        assert reg.get("missing") is None
        assert sum(reg.series("kernel.invocations").values()) == 10
        assert reg.quantile("serve.latency_s", 0.5, mode="count") == 2.0
        assert reg.quantile("serve.latency_s", 1.0, mode="count") == 10.0
        assert reg.quantile("serve.latency_s", 0.5, mode="list") is None

    def test_snapshot_and_prom_text(self):
        reg = MetricsRegistry()
        reg.inc("io.block_reads", 7, tag="q0")
        reg.set("engine.n_boxes", 3.0)
        reg.observe("serve.latency_s", 0.25, mode="count")
        snap = reg.snapshot()
        assert snap["counters"]["io.block_reads"]['{tag="q0"}'] == 7
        assert snap["gauges"]["engine.n_boxes"][""] == 3.0
        h = snap["histograms"]["serve.latency_s"]['{mode="count"}']
        assert h["count"] == 1 and h["sum"] == 0.25
        text = reg.to_prom_text()
        assert '# TYPE io_block_reads counter' in text
        assert 'io_block_reads{tag="q0"} 7' in text
        assert 'serve_latency_s_count{mode="count"} 1' in text
        assert 'quantile="0.50"' in text

    def test_publish_stats_only_numeric_fields(self):
        reg = MetricsRegistry()
        stats = EngineStats()
        stats.n_boxes = 9
        reg.publish_stats(stats, "engine", mode="count")
        assert reg.get("engine.n_boxes", mode="count") == 9.0
        # non-numeric dataclass fields (lists, strings, None) are skipped
        for key in reg.series("engine.backend"):
            raise AssertionError(f"non-numeric field published: {key}")

    def test_default_registry_opt_in(self):
        assert default_registry() is None
        reg = MetricsRegistry()
        set_default_registry(reg)
        try:
            assert default_registry() is reg
        finally:
            set_default_registry(None)
        assert default_registry() is None


# ---------------------------------------------------------------------------
# queue-telemetry folding + the worker_utilization guard
# ---------------------------------------------------------------------------

def _tele(**kw):
    tele = dict(wait=0.0, build=0.0, compute=0.0, wall=0.0, pool=1,
                hi_boxes=0, hi_words=0)
    tele.update(kw)
    return tele


class TestQueueTelemetry:
    def test_zero_wall_reports_none(self):
        stats = EngineStats()
        merge_queue_telemetry(stats, _tele(pool=4), threading.Lock(), 2)
        assert stats.worker_utilization is None

    def test_zero_pool_reports_none(self):
        stats = EngineStats()
        merge_queue_telemetry(stats, _tele(wall=1.0, pool=0),
                              threading.Lock(), 2)
        assert stats.worker_utilization is None

    def test_regular_ratio(self):
        stats = EngineStats()
        merge_queue_telemetry(stats, _tele(build=1.0, compute=1.0,
                                           wall=1.0, pool=4),
                              threading.Lock(), 2)
        assert stats.worker_utilization == pytest.approx(0.5)

    def test_folds_into_registry(self):
        stats = EngineStats()
        reg = MetricsRegistry()
        merge_queue_telemetry(stats, _tele(build=0.5, wall=1.0, pool=3),
                              threading.Lock(), 2, metrics=reg,
                              lane="shard1")
        assert reg.get("box.pool", lane="shard1") == 3
        assert reg.get("box.build_s", lane="shard1") == pytest.approx(0.5)

    def test_folds_into_default_registry(self):
        stats = EngineStats()
        reg = MetricsRegistry()
        set_default_registry(reg)
        try:
            merge_queue_telemetry(stats, _tele(wall=1.0), threading.Lock(), 2)
        finally:
            set_default_registry(None)
        assert reg.get("box.pool", lane="all") == 1


# ---------------------------------------------------------------------------
# traced-off identity: tracing must never change execution
# ---------------------------------------------------------------------------

class TestTracedIdentity:
    def test_triangle_engine_byte_identical(self):
        src, dst = SMALL
        base = TriangleEngine(src, dst, mem_words=4096)
        want = base.count()
        want_reads = base.stats.block_reads

        tr = Tracer()
        reg = MetricsRegistry()
        eng = TriangleEngine(src, dst, mem_words=4096, tracer=tr,
                             metrics=reg)
        assert eng.count() == want
        assert eng.stats.block_reads == want_reads
        names = tr.span_names()
        assert "engine.count" in names
        assert "box.fetch" in names and "box.compute" in names
        assert reg.get("engine.n_boxes", mode="count") == eng.stats.n_boxes

    def test_triangle_engine_list_identical(self):
        src, dst = SMALL
        want = canon(TriangleEngine(src, dst, mem_words=4096).list())
        tr = Tracer()
        eng = TriangleEngine(src, dst, mem_words=4096, tracer=tr)
        np.testing.assert_array_equal(canon(eng.list()), want)
        assert "engine.list" in tr.span_names()

    def test_query_engine_byte_identical_and_kernel_events(self):
        src, dst = SMALL
        q = PATTERNS["triangle"]()
        base = QueryEngine.from_graph(q, src, dst, mem_words=1 << 14,
                                      backend="pallas")
        want = base.count()
        want_reads = base.stats.block_reads

        tr = Tracer()
        reg = MetricsRegistry()
        eng = QueryEngine.from_graph(q, src, dst, mem_words=1 << 14,
                                     backend="pallas", tracer=tr,
                                     metrics=reg)
        assert eng.count() == want
        assert eng.stats.block_reads == want_reads
        names = tr.span_names()
        assert "query.plan" in names and "query.boxes" in names
        launches = [e for e in tr.snapshot()
                    if e["ph"] == "i" and e["name"] == "kernel.launch"]
        assert launches, "pallas-lane run recorded no kernel launches"
        assert sum(reg.series("kernel.invocations").values()) > 0


# ---------------------------------------------------------------------------
# served runs: span taxonomy + registry/ledger exact-sum invariants
# ---------------------------------------------------------------------------

class TestServeObservability:
    def test_span_taxonomy_and_latency_histogram(self):
        """One served run produces the full acceptance taxonomy:
        admission, planning, per-box fetch/compute, a cache event, and a
        kernel launch (pallas lane, interpret on CPU)."""
        tr = Tracer()
        reg = MetricsRegistry()
        with serve_server(graph=SMALL, backend="pallas", tracer=tr,
                          metrics=reg) as srv:
            h = srv.submit("triangle", "count")
            got = h.result(timeout=300)
        src, dst = SMALL
        want = QueryEngine.from_graph(PATTERNS["triangle"](), src, dst,
                                      mem_words=1 << 14).count()
        assert got == want
        names = tr.span_names()
        for required in ("serve.admission", "serve.query", "query.plan",
                         "box.fetch", "box.compute"):
            assert required in names, (required, names)
        events = {e["name"] for e in tr.snapshot() if e["ph"] == "i"}
        assert any(n.startswith("cache.") for n in events), events
        assert "kernel.launch" in events, events
        assert reg.quantile("serve.latency_s", 0.5, mode="count",
                            status="done") is not None

    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.sampled_from(["triangle", "path3", "four_clique"]),
                    min_size=1, max_size=3),
           st.sampled_from(["count", "list"]))
    def test_registry_sums_match_raw_ledgers(self, names, mode):
        reg = MetricsRegistry()
        with serve_server(graph=SMALL, metrics=reg) as srv:
            handles = [srv.submit(n, mode) for n in names]
            for h in handles:
                h.result(timeout=300)
            reg.collect()

            # io.*: per-tag series (including the _untagged residual)
            # sum to the raw BlockDevice globals, field by field
            for f in IO_FIELDS:
                raw = int(getattr(srv.device.stats, f))
                assert unlabeled_value(reg, f"io.{f}", "tag") == raw
                assert labeled_sum(reg, f"io.{f}", "tag") == raw, f
            # every tag partition got its own series plus the residual
            tags = {dict(k).get("tag")
                    for k in reg.series("io.block_reads") if k}
            assert "_untagged" in tags

            # cache.*: per-tenant series (including _shared) sum to the
            # raw SharedSliceCache globals
            for rel, cache in srv.caches.items():
                for f in CACHE_FIELDS:
                    raw = int(getattr(cache, f))
                    series = {k: v for k, v in
                              reg.series(f"cache.{f}").items()
                              if dict(k).get("relation") == rel}
                    tenant_sum = sum(
                        v for k, v in series.items()
                        if any(lk == "tenant" for lk, _ in k))
                    assert tenant_sum == raw, (rel, f)
                tenants = {dict(k).get("tenant")
                           for k in reg.series("cache.hits")
                           if dict(k).get("relation") == rel}
                assert "_shared" in tenants

    def test_departed_tenants_keep_summing(self):
        """Queries that finished (tenant unregistered) must stay in the
        per-tenant sum — `_gone` ledgers are part of the invariant."""
        reg = MetricsRegistry()
        with serve_server(graph=SMALL, metrics=reg) as srv:
            for _ in range(2):
                srv.submit("triangle", "count").result(timeout=300)
            reg.collect()
            cache = srv.caches["E"]
            hits = int(cache.hits)
            assert labeled_sum(reg, "cache.hits", "tenant") == hits
