"""Crossover calibration cache: concurrency + persistence regressions.

The measured density-crossover (``core.engine``) persists per
``<backend>:<device_kind>`` under ``$REPRO_CACHE_DIR/crossover.json``.
Three historical hazards pinned here:

* the cache dir override (``REPRO_CACHE_DIR``) must be honoured — CI and
  multi-user machines can't share ``~/.cache``;
* stores are atomic (tmp + ``os.replace``): a reader never observes a
  half-written JSON file;
* **the lost-update race** (the PR-8 fix): two processes that measure
  concurrently each do load → merge → store; without the ``flock`` held
  across the whole read-modify-write, the slower process clobbers the
  faster one's freshly-persisted keys. The two-process test constructs
  exactly that interleaving deterministically: process A holds the lock
  with its (stale) load in hand while process B runs a full
  ``_cached_crossover`` — with the fix B serializes behind A and both
  keys survive; without it B's entry is lost.
"""

import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import engine as eng

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    d = tmp_path / "repro-cache"
    monkeypatch.setenv("REPRO_CACHE_DIR", str(d))
    # the process-level memo would shadow the file under test
    monkeypatch.setattr(eng, "_crossover_memo", {})
    return d


def _load(cache_dir) -> dict:
    with open(cache_dir / "crossover.json") as f:
        return json.load(f)


class TestCacheFile:
    def test_cache_dir_override_is_honoured(self, cache_dir):
        assert eng._crossover_cache_file() == \
            str(cache_dir / "crossover.json")
        calls = []
        v = eng._cached_crossover(":t_override", 64,
                                  lambda: calls.append(1) or 0.25)
        assert v == 0.25 and calls == [1]
        data = _load(cache_dir)
        assert any(k.endswith(":t_override") for k in data), data

    def test_file_hit_skips_measure(self, cache_dir):
        eng._cached_crossover(":t_hit", 64, lambda: 0.25)
        eng._crossover_memo.clear()          # simulate a fresh process
        v = eng._cached_crossover(
            ":t_hit", 64,
            lambda: pytest.fail("measure ran despite a cached value"))
        assert v == 0.25

    def test_corrupt_file_degrades_to_remeasure(self, cache_dir):
        os.makedirs(cache_dir, exist_ok=True)
        with open(cache_dir / "crossover.json", "w") as f:
            f.write('{"trunca')             # a torn write without os.replace
        assert eng._crossover_load() == {}
        assert eng._cached_crossover(":t_corrupt", 64, lambda: 0.5) == 0.5
        assert any(k.endswith(":t_corrupt") for k in _load(cache_dir))

    def test_store_leaves_no_tmp_droppings(self, cache_dir):
        eng._crossover_store({"a": 0.5})
        eng._crossover_store({"a": 0.5, "b": 0.25})
        assert _load(cache_dir) == {"a": 0.5, "b": 0.25}
        assert [p for p in os.listdir(cache_dir)
                if p.endswith(".tmp")] == []


class TestConcurrentRemeasure:
    def test_threads_measuring_distinct_keys_all_persist(self, cache_dir):
        """In-process concurrency: every thread's freshly-measured key
        survives into the JSON file (each RMW holds the file lock, even
        across threads — flock fds are per-open-file-description)."""
        errs = []

        def measure(i):
            try:
                eng._cached_crossover(f":t_thr{i}", 64, lambda: 0.25)
            except Exception as e:               # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=measure, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not errs
        data = _load(cache_dir)
        for i in range(8):
            assert any(k.endswith(f":t_thr{i}") for k in data), (i, data)

    def test_two_process_lost_update_race(self, cache_dir, tmp_path):
        """The regression: process A holds the file lock across its whole
        read-modify-write (having loaded BEFORE B stores anything) while
        process B runs a complete ``_cached_crossover``. B must serialize
        behind A; afterwards the file contains BOTH keys. Without the
        lock, B's store lands inside A's window and A's store erases it."""
        a_ready = tmp_path / "a_ready"
        b_started = tmp_path / "b_started"
        env = {**os.environ, "REPRO_CACHE_DIR": str(cache_dir),
               "JAX_PLATFORMS": "cpu", "PYTHONPATH": SRC}

        proc_a = subprocess.Popen([sys.executable, "-c", f"""
import os, time
from repro.core import engine as eng
with eng._crossover_file_lock():
    data = eng._crossover_load()          # stale view, pre-B
    open({str(a_ready)!r}, "w").close()
    deadline = time.monotonic() + 30
    while not os.path.exists({str(b_started)!r}) \\
            and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.5)      # B is now inside _cached_crossover, blocked
    data["procA:manual"] = 0.5
    eng._crossover_store(data)
"""], env=env)

        deadline = time.monotonic() + 60
        while not a_ready.exists() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert a_ready.exists(), "process A never took the lock"

        proc_b = subprocess.Popen([sys.executable, "-c", f"""
import os
open({str(b_started)!r}, "w").close()
from repro.core import engine as eng
eng._cached_crossover(":t_raceB", 64, lambda: 0.25)
"""], env=env)
        assert proc_b.wait(timeout=120) == 0
        assert proc_a.wait(timeout=120) == 0

        data = _load(cache_dir)
        assert "procA:manual" in data, data
        assert any(k.endswith(":t_raceB") for k in data), \
            f"B's entry was clobbered by A's store (lost update): {data}"

    def test_remeasure_clears_only_active_backend(self, cache_dir):
        """``REPRO_CROSSOVER_REMEASURE=1`` in a fresh process drops the
        active backend's entries and re-measures, but a foreign backend's
        calibrations in the shared file survive."""
        os.makedirs(cache_dir, exist_ok=True)
        prefix = eng._active_prefix()
        with open(cache_dir / "crossover.json", "w") as f:
            json.dump({f"{prefix}:nv64:t_rm": 0.9,
                       "tpu:TPU v4:nv64:t_rm": 0.125}, f)
        env = {**os.environ, "REPRO_CACHE_DIR": str(cache_dir),
               "JAX_PLATFORMS": "cpu", "PYTHONPATH": SRC,
               "REPRO_CROSSOVER_REMEASURE": "1"}
        out = subprocess.run([sys.executable, "-c", """
from repro.core import engine as eng
print(eng._cached_crossover(":t_rm", 64, lambda: 0.25))
"""], env=env, capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        assert out.stdout.strip().endswith("0.25")
        data = _load(cache_dir)
        assert data["tpu:TPU v4:nv64:t_rm"] == 0.125   # foreign survives
        assert data[f"{prefix}:nv64:t_rm"] == 0.25     # remeasured
