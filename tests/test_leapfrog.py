"""Faithful LFTJ: generic queries vs set-oracle (hypothesis), iterators."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Atom, LeapfrogTriejoin, Query, TrieArray,
                        best_rank, brute_force_count, lftj_triangle_count,
                        orient_edges, rank_for_order, run_query)
from repro.core.leapfrog import TrieIterator


def rel(max_val=10, max_rows=40):
    return st.lists(st.tuples(st.integers(0, max_val), st.integers(0, max_val)),
                    min_size=0, max_size=max_rows)


class TestTrieIterator:
    def test_navigation_example20(self):
        """Paper Example 20 navigation sequence."""
        tuples = [(1, 1, 3), (1, 1, 4), (1, 1, 5), (2, 1, 1), (2, 3, 8),
                  (2, 3, 9)]
        ta = TrieArray.from_tuples(np.asarray(tuples))
        it = TrieIterator(ta)
        it.open()
        assert it.value() == 1
        it.next()
        assert it.value() == 2
        it.open()
        assert it.value() == 1
        it.next()
        assert it.value() == 3
        it.close()
        assert it.value() == 2

    def test_seek_galloping(self):
        ta = TrieArray.from_tuples(np.arange(0, 1000, 7).reshape(-1, 1))
        it = TrieIterator(ta)
        it.open()
        it.seek(350)
        assert it.value() == 350  # 350 = 7*50
        it.seek(351)
        assert it.value() == 357
        it.seek(2000)
        assert it.at_end()


class TestTriangles:
    @settings(max_examples=25, deadline=None)
    @given(rel(max_val=15, max_rows=60))
    def test_lftj_matches_bruteforce(self, edges):
        if not edges:
            return
        e = np.asarray(edges)
        src, dst = e[:, 0], e[:, 1]
        want = brute_force_count(src, dst)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        assert lftj_triangle_count(ta) == want

    def test_triangle_listing_valid(self):
        rng = np.random.default_rng(2)
        src = rng.integers(0, 30, 300)
        dst = rng.integers(0, 30, 300)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        out = []
        lftj_triangle_count(ta, emit=out.append)
        es = set(zip(a.tolist(), b.tolist()))
        for (x, y, z) in out:
            assert x < y < z
            assert (x, y) in es and (x, z) in es and (y, z) in es
        assert len(set(out)) == len(out)  # no duplicates


class TestGenericQueries:
    @settings(max_examples=20, deadline=None)
    @given(rel(8, 30), rel(8, 30))
    def test_two_way_join(self, r, s):
        rels = {"R": TrieArray.from_tuples(np.asarray(r).reshape(-1, 2)),
                "S": TrieArray.from_tuples(np.asarray(s).reshape(-1, 2))}
        q = Query(("x", "y", "z"),
                  [Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
        got = run_query(q, ["x", "y", "z"], rels)
        rs = set(map(tuple, np.unique(np.asarray(r).reshape(-1, 2), axis=0)))
        ss = set(map(tuple, np.unique(np.asarray(s).reshape(-1, 2), axis=0)))
        want = sum(1 for (x, y) in rs for (y2, z) in ss if y2 == y)
        assert got == want

    @settings(max_examples=15, deadline=None)
    @given(rel(6, 20), rel(6, 20))
    def test_boxed_equals_inmemory(self, r, s):
        rels = {"R": TrieArray.from_tuples(np.asarray(r).reshape(-1, 2)),
                "S": TrieArray.from_tuples(np.asarray(s).reshape(-1, 2))}
        q = Query(("x", "y", "z"),
                  [Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
        full = run_query(q, ["x", "y", "z"], rels)
        for mem in (16, 48, 200):
            assert run_query(q, ["x", "y", "z"], rels, mem_words=mem) == full

    def test_cross_product(self):
        rels = {"A": TrieArray.from_tuples(np.arange(7).reshape(-1, 1)),
                "B": TrieArray.from_tuples(np.arange(5).reshape(-1, 1))}
        q = Query(("x", "y"), [Atom("A", ("x",)), Atom("B", ("y",))])
        assert run_query(q, ["x", "y"], rels) == 35
        assert run_query(q, ["x", "y"], rels, mem_words=6) == 35

    def test_unary_intersection(self):
        rels = {"A": TrieArray.from_tuples(np.arange(0, 40, 2).reshape(-1, 1)),
                "B": TrieArray.from_tuples(np.arange(0, 40, 3).reshape(-1, 1))}
        q = Query(("x",), [Atom("A", ("x",)), Atom("B", ("x",))])
        assert run_query(q, ["x"], rels) == 7   # multiples of 6 in [0, 40)

    def test_rank(self):
        q = Query(("x", "y", "z"),
                  [Atom("E", ("x", "y")), Atom("E2", ("x", "z")),
                   Atom("E3", ("y", "z"))])
        assert rank_for_order(q, ["x", "y", "z"]) == 2   # paper: r(Δ) = 2
        r, _ = best_rank(q)
        assert r == 2

    def test_repeated_var_rejected(self):
        with pytest.raises(ValueError):
            Atom("R", ("x", "x"))
