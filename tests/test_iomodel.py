"""Block-I/O cost model: LRU semantics, view aliasing, MGT I/O sanity.

The cost model is the measurement instrument for every out-of-core claim in
the repo (Thm. 10 / Thm. 13 benchmarks, the edge-store engine stats), so its
own semantics need direct coverage: exact LRU eviction order, view/base
aliasing in ``register()`` (a slice of a registered buffer must charge the
same device blocks as the base), and an end-to-end sanity check that MGT's
measured block reads stay within a constant factor of its
O(|E|²/(MB) + |E|/B) bound.
"""

import numpy as np

from repro.core import BlockDevice, mgt_triangle_count, orient_edges
from repro.core.iomodel import _nd_base
from repro.data.graphs import rmat_graph


class TestLRU:
    def test_eviction_order_is_lru(self):
        dev = BlockDevice(block_words=1, cache_blocks=2)
        arr = np.arange(8, dtype=np.int64)
        dev.register(arr)
        dev.touch(arr, 0)            # miss: cache [0]
        dev.touch(arr, 1)            # miss: cache [0, 1]
        assert dev.stats.block_reads == 2
        dev.touch(arr, 0)            # hit, 0 becomes MRU: cache [1, 0]
        assert dev.stats.block_reads == 2
        dev.touch(arr, 2)            # miss, evicts LRU block 1: cache [0, 2]
        assert dev.stats.block_reads == 3
        dev.touch(arr, 0)            # still cached
        assert dev.stats.block_reads == 3
        dev.touch(arr, 1)            # was evicted -> miss
        assert dev.stats.block_reads == 4

    def test_capacity_never_exceeded(self):
        dev = BlockDevice(block_words=1, cache_blocks=3)
        arr = np.arange(32, dtype=np.int64)
        dev.register(arr)
        for i in range(32):
            dev.touch(arr, i)
        assert len(dev._cache) == 3
        assert dev.stats.block_reads == 32

    def test_sequential_read_range_counts_blocks_once(self):
        dev = BlockDevice(block_words=4, cache_blocks=64)
        arr = np.arange(40, dtype=np.int64)
        dev.register(arr)
        dev.read_range(arr, 0, 40)
        assert dev.stats.block_reads == 10   # ceil(40 / 4)
        dev.read_range(arr, 0, 40)           # fully cached
        assert dev.stats.block_reads == 10
        assert dev.stats.word_reads == 80


class TestRegisterAliasing:
    def test_view_charges_base_blocks(self):
        """Registering (a view of) an array maps the *base* buffer, so any
        other view over the same memory addresses the same device blocks —
        the TrieArraySlice-aliases-the-TrieArray property."""
        dev = BlockDevice(block_words=4, cache_blocks=64)
        base = np.arange(64, dtype=np.int64)
        dev.register(base[8:32])             # registering a view == base
        assert len(dev._regions) == 1
        dev.touch(base, 20)                  # block 5
        r = dev.stats.block_reads
        view = base[16:]
        dev.touch(view, 4)                   # same word 20 -> same block
        assert dev.stats.block_reads == r    # cache hit, no new I/O
        dev.touch(base[20:], 0)              # word 20 again, third view
        assert dev.stats.block_reads == r

    def test_register_base_is_idempotent(self):
        dev = BlockDevice()
        base = np.arange(16, dtype=np.int64)
        dev.register(base)
        dev.register(base[4:])
        dev.register(base[:8])
        assert len(dev._regions) == 1

    def test_distinct_arrays_get_distinct_regions(self):
        dev = BlockDevice(block_words=4)
        a = np.arange(10, dtype=np.int64)
        b = np.arange(10, dtype=np.int64)
        dev.register(a)
        dev.register(b)
        assert len(dev._regions) == 2
        # regions are block-aligned: word 0 of b is in a different block
        dev.touch(a, 0)
        dev.touch(b, 0)
        assert dev.stats.block_reads == 2

    def test_nd_base_resolves_memmap_views(self, tmp_path):
        p = tmp_path / "m.bin"
        np.arange(32, dtype=np.int32).tofile(p)
        mm = np.memmap(p, dtype=np.int32, mode="r")
        assert _nd_base(mm) is mm            # base chain ends in mmap.mmap
        assert _nd_base(mm[4:]) is mm
        dev = BlockDevice(block_words=4)
        dev.register(mm)
        dev.touch(mm[8:], 0)                 # word 8 of the mapped region
        dev.touch(mm, 8)
        assert dev.stats.block_reads == 1


class TestMGTIOBound:
    def test_mgt_block_reads_within_constant_of_bound(self):
        """MGT's measured I/Os on a small RMAT graph stay within a constant
        factor of the O(|E|²/(MB) + |E|/B) bound (plus the output term,
        which the model charges as writes)."""
        src, dst = rmat_graph(256, 3000, seed=0)
        a, b = orient_edges(src, dst)
        e = len(a)
        B = 16
        for frac in (0.10, 0.25):
            mem = max(4 * B, int(e * frac))
            dev = BlockDevice(block_words=B, cache_blocks=max(2, mem // B))
            cnt, info = mgt_triangle_count(src, dst, mem, device=dev)
            assert cnt > 0 and info["n_chunks"] >= 1
            bound = e * e / (mem * B) + e / B
            assert dev.stats.block_reads <= 8 * bound + 64, \
                (frac, dev.stats.block_reads, bound)
            # and the bound is not vacuous: measured I/O is the same order
            assert dev.stats.block_reads >= e / B / 8
