"""Heavy/light (skew-resistant) box planning: plan invariants by property
test, engine/query dispatch by oracle pinning.

Three layers, matching the skew="heavy_light" design:

* ``class_cuts`` / ``plan_boxes_heavy_light`` structure — every cut tiles
  the domain, respects the mass budget (single pinned hubs excepted), and
  never mixes heavy and light rows in one range (hypothesis).
* ``TriangleEngine(skew="heavy_light")`` — counts and listings byte-equal
  to the uniform planner (itself pinned to the scalar LFTJ reference) on
  RMAT / star / Erdős–Rényi graphs, across workers {1, 4} and slice-cache
  on/off, with lane telemetry recorded and the padded-words ledger
  strictly improving on the skewed graph.
* the three ISSUE-6 bugfix oracles — store-backed ``degree_bins`` staged
  for real (no warning), sharded binned listing (no silent unbinned
  fallback), and ``QueryEngine.list()`` through the bounded buffer with
  overflow→rescan.
"""

import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TriangleEngine, TrieArray, class_cuts, classify_heavy,
                        heavy_threshold_default, lftj_triangle_count,
                        orient_edges, plan_boxes_heavy_light)
from repro.data.edgestore import write_edge_store
from repro.data.graphs import rmat_graph
from repro.query import QueryEngine, patterns


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def star_graph(n_leaves=120):
    """One hub plus a few leaf-leaf edges: the canonical skew adversary."""
    hub = np.zeros(n_leaves, dtype=int)
    leaves = np.arange(1, n_leaves + 1)
    src = np.concatenate([hub, [1, 1, 2, 5, 5, 6]])
    dst = np.concatenate([leaves, [2, 3, 3, 6, 7, 7]])
    return src, dst


def reference_count(src, dst):
    a, b = orient_edges(src, dst)
    return lftj_triangle_count(TrieArray.from_edges(a, b))


GRAPHS = {
    "rmat": rmat_graph(256, 3000, seed=3),
    "star": star_graph(),
    "er": er_graph(60, 0.2, seed=5),
}


# ---------------------------------------------------------------------------
# plan structure (hypothesis)
# ---------------------------------------------------------------------------

def degree_seqs(max_n=60, max_deg=50):
    return st.lists(st.integers(0, max_deg), min_size=1, max_size=max_n)


class TestClassCuts:
    @settings(max_examples=50, deadline=None)
    @given(degree_seqs(), st.integers(4, 200))
    def test_cuts_tile_budget_and_pure_class(self, degs, budget):
        deg = np.asarray(degs, dtype=np.int64)
        cost = np.where(deg > 0, deg + 2, 0)
        heavy = deg >= heavy_threshold_default(int(deg.sum()))
        cuts = class_cuts(cost, budget, heavy)
        # tiling: contiguous, disjoint, covering [0, n)
        assert cuts[0][0] == 0 and cuts[-1][1] == len(deg) - 1
        for (l1, h1, _), (l2, h2, _) in zip(cuts, cuts[1:]):
            assert l2 == h1 + 1
        for lo, hi, cls in cuts:
            assert lo <= hi
            real = np.flatnonzero(cost[lo:hi + 1] > 0)
            # budget: a range either fits or is a single pinned (spilled) row
            assert cost[lo:hi + 1].sum() <= budget or len(real) == 1
            # purity: every costed row in the range shares the range's class
            assert all(bool(heavy[lo + r]) == cls for r in real)

    def test_zero_cost_rows_are_class_wildcards(self):
        """Absent rows between two hubs must not fragment the hub run or
        flip its class."""
        cost = np.array([90, 0, 0, 90, 1, 1], dtype=np.int64)
        heavy = np.array([True, False, False, True, False, False])
        cuts = class_cuts(cost, 200, heavy)
        # first range is the hub run (wildcards absorbed), then the lights
        assert cuts[0][:1] == (0,) and cuts[0][2] is True
        assert cuts[-1][2] is False


class TestHeavyLightPlan:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 40)),
                    min_size=1, max_size=200),
           st.integers(24, 400))
    def test_plan_covers_domain_with_lanes(self, edges, mem):
        e = np.asarray(edges)
        a, b = orient_edges(e[:, 0], e[:, 1])
        if len(a) == 0:
            return
        nv = int(max(a.max(), b.max())) + 1
        indptr = np.zeros(nv + 1, dtype=np.int64)
        np.add.at(indptr, a + 1, 1)
        indptr = np.cumsum(indptr)
        plan = plan_boxes_heavy_light(indptr, mem)
        assert len(plan.lanes) == len(plan.boxes)
        assert set(plan.lanes) <= {"hub", "light", "mixed"}
        assert plan.threshold >= 2
        heavy, _ = classify_heavy(indptr, plan.threshold)
        deg = np.diff(indptr)
        xs = sorted({(lx, hx) for (lx, hx, _, _) in plan.boxes})
        for (l1, h1), (l2, h2) in zip(xs, xs[1:]):
            assert h1 < l2                          # disjoint x-intervals
        for v in np.flatnonzero(deg > 0):           # full coverage
            assert any(l <= v <= h for (l, h) in xs)
        # lane faithfulness: a "hub" box has only heavy costed x-rows,
        # a "light" box only light ones
        for box, lane in zip(plan.boxes, plan.lanes):
            lx, hx = box[0], box[1]
            real = np.flatnonzero(deg[lx:hx + 1] > 0) + lx
            if lane == "hub":
                assert heavy[real].all()
            elif lane == "light":
                assert not heavy[real].any()

    def test_more_memory_fewer_boxes(self):
        src, dst = GRAPHS["rmat"]
        a, b = orient_edges(src, dst)
        nv = int(max(a.max(), b.max())) + 1
        indptr = np.zeros(nv + 1, dtype=np.int64)
        np.add.at(indptr, a + 1, 1)
        indptr = np.cumsum(indptr)
        counts = [len(plan_boxes_heavy_light(indptr, m).boxes)
                  for m in (200, 800, 3200, None)]
        assert counts[0] >= counts[1] >= counts[2] >= counts[3] == 1


# ---------------------------------------------------------------------------
# engine dispatch: heavy_light == uniform == scalar reference
# ---------------------------------------------------------------------------

class TestEngineHeavyLightOracle:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("workers", [1, 4])
    def test_count_and_list_match_uniform(self, gname, workers):
        src, dst = GRAPHS[gname]
        want = reference_count(src, dst)
        uni = TriangleEngine(src, dst, mem_words=300, workers=workers)
        assert uni.count() == want
        ref_rows = uni.list()
        hl = TriangleEngine(src, dst, mem_words=300, workers=workers,
                            skew="heavy_light")
        assert hl.count() == want
        np.testing.assert_array_equal(hl.list(), ref_rows)
        s = hl.stats
        assert s.skew == "heavy_light" and s.heavy_threshold >= 2
        assert s.n_hub_boxes + s.n_light_boxes + s.n_mixed_boxes == s.n_boxes

    def test_padded_words_improve_on_rmat(self):
        """The tentpole gate at test scale: >= 2x fewer materialized
        padded-matrix words than the uniform planner, same answer."""
        src, dst = GRAPHS["rmat"]
        uni = TriangleEngine(src, dst, mem_words=300)
        hl = TriangleEngine(src, dst, mem_words=300, skew="heavy_light")
        assert uni.count() == hl.count()
        assert 2 * hl.stats.padded_words <= uni.stats.padded_words
        assert uni.stats.padded_words > 0
        assert hl.stats.actual_words > 0

    @pytest.mark.parametrize("cache_words", [0, 4096])
    def test_store_backed_heavy_light(self, tmp_path, cache_words):
        """heavy_light plans from the resident degree index alone, so the
        store-backed engine takes the same skew-aware plan — cache on and
        off, counts pinned to the in-memory uniform run."""
        src, dst = GRAPHS["rmat"]
        want = reference_count(src, dst)
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        eng = TriangleEngine(store=path, mem_words=300, skew="heavy_light",
                             cache_words=cache_words)
        assert eng.count() == want
        assert eng.stats.skew == "heavy_light"
        assert eng.stats.n_hub_boxes + eng.stats.n_light_boxes \
            + eng.stats.n_mixed_boxes == eng.stats.n_boxes

    def test_explicit_threshold_knob(self):
        """heavy_threshold overrides the √(2E) default; an absurdly high
        threshold degenerates to an all-light plan with uniform's answer."""
        src, dst = GRAPHS["rmat"]
        eng = TriangleEngine(src, dst, mem_words=300, skew="heavy_light",
                             heavy_threshold=1 << 30)
        assert eng.count() == reference_count(src, dst)
        assert eng.stats.n_hub_boxes == 0

    def test_invalid_skew_rejected(self):
        src, dst = GRAPHS["er"]
        with pytest.raises(ValueError, match="skew"):
            TriangleEngine(src, dst, skew="nope")


# ---------------------------------------------------------------------------
# ISSUE-6 bugfix oracles
# ---------------------------------------------------------------------------

class TestBugfixOracles:
    def test_store_backed_degree_bins_no_warning(self, tmp_path):
        """Bugfix 1: degree_bins on a store-backed engine stages per-box
        binned layouts instead of warn-and-drop."""
        src, dst = GRAPHS["rmat"]
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            eng = TriangleEngine(store=path, mem_words=200, degree_bins=True)
            n = eng.count()
            tris = eng.list()
        assert n == reference_count(src, dst)
        assert len(tris) == n

    @pytest.mark.parametrize("gname", ["rmat", "star"])
    def test_sharded_binned_listing_no_fallback(self, gname):
        """Bugfix 2: shard=True + degree_bins=True listing runs the binned
        per-bin-pair kernels and matches the unsharded oracle."""
        src, dst = GRAPHS[gname]
        ref = TriangleEngine(src, dst, mem_words=200)
        ref_rows = ref.list()
        eng = TriangleEngine(src, dst, mem_words=200, shard=True,
                             degree_bins=True)
        np.testing.assert_array_equal(eng.list(), ref_rows)

    def test_query_listing_bounded_with_rescan(self):
        """Bugfix 3: QueryEngine.list() materializes at most ``capacity``
        rows per box pass, detects overflow by exact count, and rescans at
        doubled capacity — results identical, rescans recorded."""
        src, dst = GRAPHS["rmat"]
        q = patterns.triangle()
        ref = QueryEngine.from_graph(q, src, dst, mem_words=400)
        rows_ref = ref.list()
        rows_ref = rows_ref[np.lexsort(rows_ref.T[::-1])]
        eng = QueryEngine.from_graph(q, src, dst, mem_words=400)
        rows = eng.list(capacity=4)
        rows = rows[np.lexsort(rows.T[::-1])]
        np.testing.assert_array_equal(rows, rows_ref)
        assert eng.stats.n_rescans > 0

    def test_query_default_capacity_from_mem_words(self):
        """With no explicit capacity the per-box buffer derives from
        mem_words — results still complete under a tiny budget."""
        src, dst = GRAPHS["rmat"]
        q = patterns.triangle()
        full = QueryEngine.from_graph(q, src, dst).list()
        full = full[np.lexsort(full.T[::-1])]
        eng = QueryEngine.from_graph(q, src, dst, mem_words=900)
        rows = eng.list()
        rows = rows[np.lexsort(rows.T[::-1])]
        np.testing.assert_array_equal(rows, full)


class TestQueryHeavyLight:
    @pytest.mark.parametrize("workers", [1, 4])
    def test_triangle_pattern_matches_uniform(self, workers):
        src, dst = GRAPHS["rmat"]
        q = patterns.triangle()
        uni = QueryEngine.from_graph(q, src, dst, mem_words=400,
                                     workers=workers)
        want = uni.count()
        hl = QueryEngine.from_graph(q, src, dst, mem_words=400,
                                    workers=workers, skew="heavy_light")
        assert hl.count() == want
        s = hl.stats
        assert s.skew == "heavy_light" and s.heavy_threshold >= 2
        assert s.n_hub_boxes + s.n_light_boxes + s.n_mixed_boxes == s.n_boxes
        rows_u = uni.list()
        rows_h = hl.list()
        np.testing.assert_array_equal(
            rows_h[np.lexsort(rows_h.T[::-1])],
            rows_u[np.lexsort(rows_u.T[::-1])])

    def test_four_clique_matches_uniform(self):
        src, dst = GRAPHS["er"]
        q = patterns.four_clique()
        want = QueryEngine.from_graph(q, src, dst).count()
        hl = QueryEngine.from_graph(q, src, dst, mem_words=500,
                                    skew="heavy_light")
        assert hl.count() == want
