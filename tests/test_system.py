"""End-to-end behaviour tests for the paper's system.

The headline property: every altitude of the implementation — faithful
sequential LFTJ, boxed LFTJ under arbitrary memory budgets, the
vectorized JAX engine, the dense MXU formulation, box-parallel execution
via the straggler scheduler, and the MGT competitor — agrees with brute
force on the triangle count.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TrieArray, boxed_triangle_count, brute_force_count,
                        count_triangles, orient_edges, plan_boxes,
                        triangle_count_boxed_vectorized)
from repro.data.graphs import clustered_graph, random_graph, rmat_graph
from repro.runtime.straggler import BoxScheduler


ALL_METHODS = ["faithful", "boxed", "vectorized", "boxed_vec", "dense", "mgt"]


class TestAllAltitudesAgree:
    @pytest.mark.parametrize("gen,kw", [
        (random_graph, dict(n_nodes=80, n_edges=600)),
        (rmat_graph, dict(n_nodes=64, n_edges=600)),
        (clustered_graph, dict(n_clusters=4, cluster_size=12, p_in=0.8)),
    ])
    def test_methods_agree(self, gen, kw):
        src, dst = gen(**kw, seed=7)
        want = brute_force_count(src, dst)
        for m in ALL_METHODS:
            got = count_triangles(src, dst, method=m, mem_words=128)
            assert got == want, (m, got, want)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 200), st.integers(0, 10))
    def test_random_sizes(self, n_edges, seed):
        rng = np.random.default_rng(seed)
        src = rng.integers(0, 30, n_edges)
        dst = rng.integers(0, 30, n_edges)
        want = brute_force_count(src, dst)
        assert count_triangles(src, dst, method="vectorized") == want
        assert count_triangles(src, dst, method="boxed", mem_words=40) == want

    def test_orientation_invariance(self):
        """minmax and degree orientations count the same triangles."""
        src, dst = rmat_graph(128, 1500, seed=3)
        a = count_triangles(src, dst, method="vectorized",
                            orientation="minmax")
        b = count_triangles(src, dst, method="vectorized",
                            orientation="degree")
        assert a == b


class TestBoxParallelExecution:
    def test_boxes_via_scheduler_with_failures(self):
        """Box-parallel triangle counting survives a worker death and a
        straggler steal, and still produces the exact count — the paper's
        §5 parallelization lifted to the fault-tolerant scheduler."""
        src, dst = rmat_graph(256, 4000, seed=5)
        want = count_triangles(src, dst, method="vectorized")

        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        boxes = plan_boxes(ta, mem_words=ta.words() // 6)
        assert len(boxes) >= 4

        from repro.core.lftj_jax import (csr_from_edges, pad_neighbors,
                                         _count_chunked)
        import jax.numpy as jnp
        indptr, indices = csr_from_edges(a, b)
        npad = jnp.asarray(pad_neighbors(indptr, indices))

        def solve(box):
            lx, hx, ly, hy = box
            lx_, hx_ = max(lx, 0), min(hx, len(indptr) - 2)
            eu = np.repeat(np.arange(lx_, hx_ + 1),
                           np.diff(indptr[lx_:hx_ + 2]))
            ev = indices[indptr[lx_]:indptr[hx_ + 1]].astype(np.int64)
            sel = (ev >= ly) & (ev <= hy)
            if not sel.any():
                return 0
            return int(_count_chunked(npad, jnp.asarray(eu[sel], jnp.int32),
                                      jnp.asarray(ev[sel], jnp.int32),
                                      chunk=512))

        sched = BoxScheduler(boxes, n_workers=3, steal_after_s=0.0)
        # worker 0 takes two boxes then dies
        sched.next_for(0, now=0.0)
        sched.next_for(0, now=0.0)
        from repro.runtime.straggler import fail_worker
        fail_worker(sched, 0)
        while not sched.all_done():
            for w in (1, 2):
                t = sched.next_for(w, now=100.0)
                if t is not None:
                    sched.complete(w, t.box_id, solve(t.payload))
        assert sum(sched.results()) == want

    def test_boxed_vec_matches(self):
        src, dst = rmat_graph(200, 3000, seed=9)
        want = count_triangles(src, dst, method="vectorized")
        got, info = triangle_count_boxed_vectorized(src, dst, mem_words=400)
        assert got == want
        assert info["n_boxes"] >= 1


class TestEndToEndTraining:
    def test_lm_loss_decreases(self):
        from repro.launch.train import main
        losses = main(["--arch", "qwen2-7b", "--smoke", "--steps", "15",
                       "--batch", "4", "--seq", "64", "--log-every", "100"])
        assert losses[-1] < losses[0]

    def test_dlrm_loss_decreases(self):
        from repro.launch.train import main
        losses = main(["--arch", "dlrm-mlperf", "--smoke", "--steps", "30",
                       "--batch", "64", "--log-every", "100"])
        assert losses[-1] < losses[0]

    def test_int8_compressed_training_converges(self):
        from repro.launch.train import main
        losses = main(["--arch", "gcn-cora", "--smoke", "--steps", "25",
                       "--compress", "int8", "--log-every", "100"])
        assert losses[-1] < losses[0]
