import os
import sys

try:  # pragma: no cover - prefer the real package when installed
    import hypothesis  # noqa: F401
except ImportError:  # fall back to the vendored shim (requirements-dev.txt)
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_compat as _shim

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import jax.numpy as jnp
import pytest

from repro.models import layers as L


@pytest.fixture(autouse=True, scope="session")
def _cpu_dtypes():
    # CPU backend cannot execute some bf16 dot shapes; tests run f32.
    # (The dry-run keeps bf16 — it only compiles.)
    L.set_dtypes(jnp.float32, jnp.float32)
    yield
