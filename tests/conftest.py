import jax.numpy as jnp
import pytest

from repro.models import layers as L


@pytest.fixture(autouse=True, scope="session")
def _cpu_dtypes():
    # CPU backend cannot execute some bf16 dot shapes; tests run f32.
    # (The dry-run keeps bf16 — it only compiles.)
    L.set_dtypes(jnp.float32, jnp.float32)
    yield
