import os
import sys

try:  # pragma: no cover - prefer the real package when installed
    import hypothesis  # noqa: F401
except ImportError:  # fall back to the vendored shim (requirements-dev.txt)
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_compat as _shim

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import threading
import time

import jax.numpy as jnp
import pytest

from repro.models import layers as L


@pytest.fixture(autouse=True, scope="session")
def _cpu_dtypes():
    # CPU backend cannot execute some bf16 dot shapes; tests run f32.
    # (The dry-run keeps bf16 — it only compiles.)
    L.set_dtypes(jnp.float32, jnp.float32)
    yield


class ThreadGuard:
    """Identity-based thread-leak detector shared by the concurrency
    suites (serve, parallel executor, fabric).

    Snapshots the idents of the threads alive at construction; ``leaked``
    is any *new* live thread. Unlike ``threading.active_count()`` deltas,
    this stays correct under ``-p no:randomly`` reordering when an
    unrelated earlier test's worker happens to die mid-test (the count
    would balance out and mask a real leak, or underflow and flake)."""

    def __init__(self):
        self._before = {t.ident for t in threading.enumerate()}

    def leaked(self):
        return [t for t in threading.enumerate()
                if t.ident not in self._before and t.is_alive()]

    def assert_clean(self, timeout: float = 10.0) -> None:
        deadline = time.monotonic() + timeout
        while self.leaked() and time.monotonic() < deadline:
            time.sleep(0.01)
        left = self.leaked()
        assert not left, \
            f"leaked thread(s): {sorted(t.name for t in left)}"


@pytest.fixture
def thread_guard():
    """Fails the test if it leaves any thread it started running."""
    g = ThreadGuard()
    yield g
    g.assert_clean()
