"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py
oracle vs numpy ground truth."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.triangle_dense.ops import triangle_count
from repro.kernels.triangle_dense.ref import triangle_count_ref
from repro.kernels.intersect.ops import intersect_count
from repro.kernels.intersect.ref import SENTINEL, intersect_count_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

RNG = np.random.default_rng(0)


class TestTriangleDense:
    @pytest.mark.parametrize("nx,ny,d", [(64, 64, 128), (100, 140, 300),
                                         (128, 128, 512), (1, 7, 64),
                                         (257, 129, 640)])
    def test_shapes(self, nx, ny, d):
        a = (RNG.random((nx, d)) < 0.15).astype(np.float32)
        b = (RNG.random((ny, d)) < 0.15).astype(np.float32)
        m = (RNG.random((nx, ny)) < 0.3).astype(np.float32)
        got = float(triangle_count(a, b, m, use_pallas=True))
        want = float(np.sum(m * (a @ b.T)))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.int32, bool])
    def test_dtypes(self, dtype):
        a = (RNG.random((64, 128)) < 0.2).astype(dtype)
        b = (RNG.random((64, 128)) < 0.2).astype(dtype)
        m = (RNG.random((64, 64)) < 0.3).astype(dtype)
        got = float(triangle_count(a, b, m))
        want = float(np.sum(m.astype(np.float64) *
                            (a.astype(np.float64) @ b.astype(np.float64).T)))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_against_ref_module(self):
        a = (RNG.random((96, 256)) < 0.1).astype(np.float32)
        b = (RNG.random((96, 256)) < 0.1).astype(np.float32)
        m = np.ones((96, 96), np.float32)
        got = float(triangle_count(a, b, m, use_pallas=True))
        ref = float(triangle_count_ref(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(m)))
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    def test_block_size_sweep(self):
        a = (RNG.random((256, 512)) < 0.1).astype(np.float32)
        m = np.ones((256, 256), np.float32)
        want = float(np.sum(m * (a @ a.T)))
        for bm, bn, bk in [(128, 128, 512), (128, 128, 128), (256, 128, 256)]:
            got = float(triangle_count(a, a, m, bm=bm, bn=bn, bk=bk))
            np.testing.assert_allclose(got, want, rtol=1e-5)


def sorted_rows(e, k, hi, rng):
    out = np.full((e, k), SENTINEL, np.int32)
    for i in range(e):
        n = rng.integers(0, min(k, hi) + 1)
        out[i, :n] = np.sort(rng.choice(hi, size=n, replace=False))
    return out


class TestIntersect:
    @pytest.mark.parametrize("e,k,hi", [(10, 8, 50), (50, 40, 200),
                                        (256, 128, 500), (3, 130, 1000)])
    def test_counts(self, e, k, hi):
        rng = np.random.default_rng(e * k)
        a = sorted_rows(e, k, hi, rng)
        b = sorted_rows(e, k, hi, rng)
        got = np.asarray(intersect_count(a, b, use_pallas=True))
        want = np.asarray([len(set(a[i][a[i] != SENTINEL]) &
                               set(b[i][b[i] != SENTINEL]))
                           for i in range(e)])
        np.testing.assert_array_equal(got, want)

    def test_ref_agrees(self):
        rng = np.random.default_rng(7)
        a = sorted_rows(64, 32, 100, rng)
        b = sorted_rows(64, 32, 100, rng)
        got = np.asarray(intersect_count(a, b, use_pallas=True))
        ref = np.asarray(intersect_count(a, b, use_pallas=False))
        np.testing.assert_array_equal(got, ref)

    def test_empty_rows(self):
        a = np.full((8, 16), SENTINEL, np.int32)
        b = np.full((8, 16), SENTINEL, np.int32)
        got = np.asarray(intersect_count(a, b))
        np.testing.assert_array_equal(got, np.zeros(8, np.int32))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 40), st.integers(1, 33))
    def test_property_random_shapes(self, e, k):
        rng = np.random.default_rng(e * 31 + k)
        a = sorted_rows(e, k, 60, rng)
        b = sorted_rows(e, k, 60, rng)
        got = np.asarray(intersect_count(a, b))
        want = np.asarray([len(set(a[i][a[i] != SENTINEL]) &
                               set(b[i][b[i] != SENTINEL]))
                           for i in range(e)])
        np.testing.assert_array_equal(got, want)

    def test_jit_cache_stays_bucketed(self):
        """Pow2-bucketed pad shapes: a sweep of nearby (E, K) inputs must
        reuse a handful of compiled signatures, not one per exact shape —
        the unbounded-cache leak this bucketing closed."""
        from repro.kernels.intersect.ops import jit_cache_info
        rng = np.random.default_rng(3)
        before = jit_cache_info()
        for e in range(65, 97, 4):                 # all bucket to ep=128
            for k in (129, 140, 200, 255):         # all bucket to k=256
                a = sorted_rows(e, k, 500, rng)
                b = sorted_rows(e, k, 500, rng)
                got = np.asarray(intersect_count(a, b))
                want = np.asarray([len(set(a[i][a[i] != SENTINEL]) &
                                       set(b[i][b[i] != SENTINEL]))
                                   for i in range(e)])
                np.testing.assert_array_equal(got, want)
        assert jit_cache_info() - before <= 2


class TestEmbeddingBag:
    @pytest.mark.parametrize("mode", ["onehot", "dma"])
    @pytest.mark.parametrize("v,d,b,l", [(100, 16, 8, 3), (1000, 64, 32, 7),
                                         (512, 128, 16, 1)])
    def test_modes_shapes(self, mode, v, d, b, l):
        rng = np.random.default_rng(v + b)
        tab = rng.standard_normal((v, d)).astype(np.float32)
        idx = rng.integers(0, v + 1, (b, l)).astype(np.int32)  # v == PAD
        got = np.asarray(embedding_bag(tab, idx, mode=mode))
        want = np.asarray(embedding_bag_ref(jnp.asarray(tab), jnp.asarray(idx)))
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_all_pad(self):
        tab = RNG.standard_normal((50, 8)).astype(np.float32)
        idx = np.full((4, 5), 50, np.int32)
        got = np.asarray(embedding_bag(tab, idx, mode="onehot"))
        np.testing.assert_allclose(got, np.zeros((4, 8)), atol=0)

    def test_weighted_ref(self):
        tab = RNG.standard_normal((30, 4)).astype(np.float32)
        idx = RNG.integers(0, 30, (6, 3)).astype(np.int32)
        w = RNG.random((6, 3)).astype(np.float32)
        got = np.asarray(embedding_bag_ref(jnp.asarray(tab), jnp.asarray(idx),
                                           jnp.asarray(w)))
        want = np.einsum("bld,bl->bd", tab[idx], w)
        np.testing.assert_allclose(got, want, rtol=1e-5)
