"""Serving layer (PR 8): concurrency stress, admission invariants,
cancellation, fault injection, pagination.

Everything pins against the single-query engines as oracles: a served
query must return byte-identical results to a solo ``QueryEngine`` run,
under any interleaving the thread scheduler produces — concurrency may
change *timing*, never *results*. The admission invariants (reservations
partition ``mem_words``; a query's measured block reads stay within its
solo envelope at its admitted budget) are the serving layer's version of
the paper's Thm. 10/13 contract.
"""

import os
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.graphs import random_graph, rmat_graph
from repro.query import QueryEngine
from repro.query.patterns import PATTERNS
from repro.serve import (AdmissionController, AdmissionRejected,
                         AdmissionTimeout, QueryCancelled, QueryFailed,
                         Server, Session)

ENV_WORKERS = max(2, int(os.environ.get("REPRO_TEST_WORKERS", "2")))

GRAPH = rmat_graph(512, 6000, seed=21)
SMALL = random_graph(200, 1500, seed=7)

NAMES = ["triangle", "four_clique", "path3"]


def canon(rows: np.ndarray) -> np.ndarray:
    """Row-set canonical form (lexicographic sort) for order-insensitive
    listing comparison."""
    if len(rows) == 0:
        return rows
    return rows[np.lexsort(rows.T[::-1])]


_ORACLE = {}


def oracle(name: str, mode: str = "count", graph=GRAPH):
    key = (name, mode, id(graph))
    if key not in _ORACLE:
        src, dst = graph
        eng = QueryEngine.from_graph(PATTERNS[name](), src, dst,
                                     mem_words=1 << 14)
        _ORACLE[key] = eng.count() if mode == "count" else canon(eng.list())
    return _ORACLE[key]


def serve_server(graph=GRAPH, **kw):
    kw.setdefault("mem_words", 1 << 15)
    kw.setdefault("use_pallas_kernels", False)
    src, dst = graph
    return Server.from_graph(src, dst, **kw)


# identity-based leak detection (shared with the fabric suite): a count
# delta flakes under -p no:randomly reordering when an unrelated earlier
# test's worker dies mid-test; tracking thread idents does not
from conftest import ThreadGuard


# ---------------------------------------------------------------------------
# served results == solo-engine oracle
# ---------------------------------------------------------------------------

class TestServeMatchesOracle:
    @pytest.mark.parametrize("name", NAMES)
    @pytest.mark.parametrize("mode", ["count", "list"])
    def test_single_query(self, name, mode):
        with serve_server() as srv:
            h = srv.submit(name, mode)
            got = h.result(timeout=300)
            if mode == "count":
                assert got == oracle(name)
            else:
                np.testing.assert_array_equal(canon(got),
                                              oracle(name, "list"))
            assert h.status == "done"
            assert h.stats is not None and h.stats.n_boxes >= 1

    def test_session_facade(self):
        with serve_server() as srv, Session(srv) as ses:
            assert ses.count("triangle") == oracle("triangle")
            np.testing.assert_array_equal(canon(ses.list("path3")),
                                          oracle("path3", "list"))

    def test_repeated_shape_hits_plan_cache(self):
        with serve_server() as srv:
            for _ in range(3):
                assert srv.submit("triangle").result(300) == \
                    oracle("triangle")
            assert srv.plan_misses == 1
            assert srv.plan_hits == 2

    def test_unknown_pattern_and_relation_reject_at_submit(self):
        with serve_server() as srv:
            with pytest.raises(ValueError, match="unknown pattern"):
                srv.submit("pentagon")
            q = PATTERNS["triangle"]()
            bad = type(q)(head=q.head,
                          atoms=[type(a)("R", a.vars) for a in q.atoms])
            with pytest.raises(ValueError, match="unknown relation"):
                srv.submit(bad)


# ---------------------------------------------------------------------------
# hypothesis concurrency stress: random mixes from N threads
# ---------------------------------------------------------------------------

class TestConcurrencyStress:
    @settings(max_examples=4, deadline=None)
    @given(mix=st.lists(st.tuples(st.sampled_from(NAMES),
                                  st.sampled_from(["count", "list"])),
                        min_size=2, max_size=6),
           workers=st.sampled_from([1, ENV_WORKERS]),
           cache_on=st.booleans())
    def test_random_mix_from_threads(self, mix, workers, cache_on):
        guard = ThreadGuard()
        srv = serve_server(graph=SMALL, mem_words=1 << 15,
                           cache_words=(1 << 15) if cache_on else 0,
                           workers_per_query=workers, max_active=4,
                           queue_depth=16)
        errors, results = [], {}
        over = []        # admission-invariant violations seen by a sampler
        stop = threading.Event()

        def sampler():
            while not stop.is_set():
                r = srv.admission.reserved_words
                if r > srv.mem_words:
                    over.append(r)
                time.sleep(0.002)

        def client(i, name, mode):
            try:
                h = srv.submit(name, mode, timeout=120)
                results[i] = (name, mode, h.result(timeout=300),
                              h.admitted_words)
            except Exception as e:               # noqa: BLE001 — collected
                errors.append((i, name, mode, e))

        st_t = threading.Thread(target=sampler)
        st_t.start()
        threads = [threading.Thread(target=client, args=(i, nm, md))
                   for i, (nm, md) in enumerate(mix)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(300)
        stop.set()
        st_t.join(10)
        try:
            assert not errors, errors
            assert not over, over
            assert len(results) == len(mix)
            for i, (name, mode, got, m_i) in results.items():
                assert m_i >= srv.admission.min_words
                if mode == "count":
                    assert got == oracle(name, graph=SMALL), (name, got)
                else:
                    np.testing.assert_array_equal(
                        canon(got), oracle(name, "list", graph=SMALL))
            # every reservation returned to the pool
            assert srv.admission.reserved_words == 0
            assert srv.admission.active == 0
            assert srv.admission.peak_reserved <= srv.mem_words
        finally:
            srv.close()
        guard.assert_clean()


# ---------------------------------------------------------------------------
# admission invariants (per-query envelope + controller unit tests)
# ---------------------------------------------------------------------------

class TestSoloEnvelope:
    def test_block_reads_within_solo_envelope(self):
        """Serial served queries: each reads no more device blocks than
        its solo run at its admitted budget m_i — the shared warm stack
        (bigger shared cache, warm frames) only ever helps."""
        with serve_server(mem_words=1 << 15) as srv:
            for name in NAMES:
                h = srv.submit(name, "count")
                got = h.result(300)
                solo, solo_stats = srv.solo_run(name, "count",
                                                words=h.admitted_words)
                assert got == solo
                assert h.stats.block_reads <= solo_stats.block_reads, name

    def test_warm_cache_strictly_reduces_repeat_reads(self):
        with serve_server(mem_words=1 << 15) as srv:
            h1 = srv.submit("triangle")
            h1.result(300)
            h2 = srv.submit("triangle")
            h2.result(300)
            assert h2.stats.cache_hits > 0
            assert h2.stats.block_reads <= h1.stats.block_reads


class TestAdmissionController:
    def test_sum_of_reservations_bounded_under_hammering(self):
        ctrl = AdmissionController(1 << 16, min_words=1 << 10,
                                   queue_depth=64)
        over, errors = [], []

        def hammer(seed):
            rng = np.random.default_rng(seed)
            for _ in range(30):
                try:
                    res = ctrl.acquire(
                        int(rng.integers(1 << 10, 1 << 15)), timeout=30)
                except AdmissionTimeout:
                    continue
                except Exception as e:           # noqa: BLE001
                    errors.append(e)
                    return
                if ctrl.reserved_words > ctrl.total_words:
                    over.append(ctrl.reserved_words)
                time.sleep(0.001)
                res.release()

        threads = [threading.Thread(target=hammer, args=(s,))
                   for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors and not over
        assert ctrl.reserved_words == 0 and ctrl.active == 0
        assert ctrl.peak_reserved <= ctrl.total_words

    def test_fair_share_shrinks_under_contention(self):
        ctrl = AdmissionController(1 << 16, min_words=1 << 8)
        alone = ctrl.acquire()
        assert alone.words == 1 << 16       # alone: the whole budget
        alone.release()
        r1 = ctrl.acquire(want_words=1 << 14)
        assert r1.words == 1 << 14          # want caps the grant
        r2 = ctrl.acquire()                 # fair share: total // 2
        assert r2.words == 1 << 15
        r3 = ctrl.acquire()                 # total // 3, pow2, clipped
        assert r3.words == 1 << 14
        assert ctrl.reserved_words <= ctrl.total_words
        for r in (r1, r2, r3):
            r.release()
        assert ctrl.reserved_words == 0

    def test_nonblocking_reject_and_timeout(self):
        ctrl = AdmissionController(1 << 12, min_words=1 << 12)
        held = ctrl.acquire()
        with pytest.raises(AdmissionRejected):
            ctrl.acquire(block=False)
        with pytest.raises(AdmissionTimeout):
            ctrl.acquire(timeout=0.05)
        held.release()
        ctrl.acquire(block=False).release()   # capacity is back

    def test_queue_depth_bounds_waiters(self):
        ctrl = AdmissionController(1 << 12, min_words=1 << 12,
                                   queue_depth=1)
        held = ctrl.acquire()
        waiter_err = []

        def waiter():
            try:
                ctrl.acquire(timeout=5).release()
            except Exception as e:               # noqa: BLE001
                waiter_err.append(e)

        t = threading.Thread(target=waiter)
        t.start()
        deadline = time.monotonic() + 5
        while ctrl.waiting < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
        with pytest.raises(AdmissionRejected, match="queue full"):
            ctrl.acquire(timeout=5)
        held.release()
        t.join(10)
        assert not waiter_err

    def test_release_is_idempotent(self):
        ctrl = AdmissionController(1 << 12, min_words=1 << 10)
        res = ctrl.acquire()
        res.release()
        res.release()
        assert ctrl.reserved_words == 0 and ctrl.active == 0

    def test_min_words_above_total_rejected(self):
        with pytest.raises(ValueError, match="min_words"):
            AdmissionController(100, min_words=200)


class TestServerAdmission:
    def test_oversubscription_rejects_then_recovers(self):
        with serve_server(mem_words=1 << 14, min_words=1 << 14,
                          max_active=1, queue_depth=0) as srv:
            gate = threading.Event()
            srv.fault_hook = lambda stage, qid, i: gate.wait(30)
            slow = srv.submit("triangle")
            deadline = time.monotonic() + 10
            while srv.admission.active < 1 and time.monotonic() < deadline:
                time.sleep(0.005)
            with pytest.raises(AdmissionRejected):
                srv.submit("triangle", block=False)
            gate.set()
            srv.fault_hook = None
            assert slow.result(300) == oracle("triangle")
            assert srv.submit("triangle").result(300) == oracle("triangle")


# ---------------------------------------------------------------------------
# cancellation: mid-query, no leaks, neighbours unaffected
# ---------------------------------------------------------------------------

class TestCancellation:
    def test_cancel_mid_query_leaves_server_serving(self):
        guard = ThreadGuard()
        srv = serve_server(mem_words=1 << 13, max_active=4,
                          workers_per_query=ENV_WORKERS)
        try:
            boxes_seen = []
            gate = threading.Event()

            def slow_hook(stage, qid, i):
                if stage == "work" and qid == "q0":
                    boxes_seen.append(i)
                    gate.wait(0.05)

            srv.fault_hook = slow_hook
            victim = srv.submit("four_clique")
            deadline = time.monotonic() + 30
            while not boxes_seen and time.monotonic() < deadline:
                time.sleep(0.002)
            victim.cancel()
            assert victim.wait(60)
            srv.fault_hook = None
            assert victim.status == "cancelled"
            with pytest.raises(QueryCancelled):
                victim.result(5)
            # a cancelled query abandoned boxes mid-plan
            assert len(boxes_seen) < victim.stats.n_boxes \
                if victim.stats else True
            # the server is intact: admission drained, next query exact
            assert srv.admission.reserved_words == 0
            assert srv.submit("triangle").result(300) == oracle("triangle")
        finally:
            srv.close()
        guard.assert_clean()

    def test_close_cancels_everything_without_leaks(self):
        guard = ThreadGuard()
        srv = serve_server(mem_words=1 << 13, max_active=8)
        srv.fault_hook = lambda stage, qid, i: time.sleep(0.01)
        handles = [srv.submit("four_clique") for _ in range(3)]
        srv.close()
        for h in handles:
            assert h.done()
        guard.assert_clean()


# ---------------------------------------------------------------------------
# fault injection: flaky stages recover via re-queue; failures contained
# ---------------------------------------------------------------------------

class TestFaultInjection:
    @pytest.mark.parametrize("stage", ["fetch", "work"])
    def test_flaky_stage_recovers_with_exact_dedup(self, stage):
        """A box whose fetch (store read) / work (box worker) raises N
        times recovers through ``BoxScheduler.requeue``: the flaky box is
        re-attempted exactly N extra times, every other box runs once
        (dedup by box id), and the result is exact."""
        attempts = {}
        lock = threading.Lock()

        def flaky(stg, qid, i):
            if stg != stage:
                return
            with lock:
                attempts[i] = attempts.get(i, 0) + 1
                if i == 0 and attempts[i] <= 2:
                    raise OSError(f"injected {stage} fault #{attempts[i]}")

        with serve_server(mem_words=1 << 13, box_retries=2) as srv:
            srv.fault_hook = flaky
            h = srv.submit("triangle")
            assert h.result(300) == oracle("triangle")
            assert h.status == "done"
            assert h.retry_rounds >= 1
            assert attempts[0] == 3                  # 2 failures + success
            assert all(n == 1 for i, n in attempts.items() if i != 0), \
                attempts

    def test_flaky_listing_recovers(self):
        calls = {"n": 0}

        def flaky(stg, qid, i):
            if stg == "fetch" and i == 1:
                calls["n"] += 1
                if calls["n"] <= 2:
                    raise OSError("injected read fault")

        with serve_server(mem_words=1 << 13, box_retries=2) as srv:
            srv.fault_hook = flaky
            rows = srv.submit("path3", "list").result(300)
            np.testing.assert_array_equal(canon(rows),
                                          oracle("path3", "list"))

    def test_exhausted_retries_fail_cleanly_without_poisoning_cache(self):
        """A permanently failing query errors out per-query: the server
        keeps serving, admission drains, and the shared cache's contents
        are byte-identical before and after the failed run."""
        with serve_server(mem_words=1 << 14, cache_words=1 << 20,
                          box_retries=1) as srv:
            warm = srv.submit("triangle")
            assert warm.result(300) == oracle("triangle")
            before = {n: c.snapshot() for n, c in srv.caches.items()}
            assert any(before.values())      # the warm run cached blocks

            def always_fail(stg, qid, i):
                if stg == "fetch":
                    raise OSError("store is gone")

            srv.fault_hook = always_fail
            victim = srv.submit("triangle")
            with pytest.raises(QueryFailed, match="still failing"):
                victim.result(300)
            assert victim.status == "error"
            srv.fault_hook = None

            after = {n: c.snapshot() for n, c in srv.caches.items()}
            assert before == after           # byte-compared, no poisoning
            assert srv.admission.reserved_words == 0
            assert srv.submit("triangle").result(300) == oracle("triangle")

    def test_failure_does_not_disturb_concurrent_query(self):
        def fail_q(stg, qid, i):
            if qid == "q0" and stg == "work":
                raise RuntimeError("victim box explodes")

        with serve_server(mem_words=1 << 15, max_active=4,
                          box_retries=0) as srv:
            srv.fault_hook = fail_q
            victim = srv.submit("triangle")
            bystander = srv.submit("path3")
            with pytest.raises(QueryFailed):
                victim.result(300)
            assert bystander.result(300) == oracle("path3")


# ---------------------------------------------------------------------------
# streamed listing: plan-order pages through the bounded queue
# ---------------------------------------------------------------------------

class TestPagination:
    def test_pages_concatenate_to_exact_listing_in_plan_order(self):
        with serve_server(mem_words=1 << 14, page_rows=256,
                          page_queue_depth=2) as srv:
            plain = srv.submit("path3", "list").result(300)
            h = srv.submit("path3", "list", stream=True)
            pages = list(h.pages())
            assert all(len(p) <= 256 for p in pages)
            got = np.concatenate(pages) if pages \
                else np.zeros((0, plain.shape[1]), np.int64)
            # identical rows in identical (plan) order, not just as a set
            np.testing.assert_array_equal(got, plain)
            assert h.result(60) is not None    # full result still kept

    def test_slow_consumer_backpressure(self):
        with serve_server(mem_words=1 << 13, page_rows=64,
                          page_queue_depth=1) as srv:
            h = srv.submit("path3", "list", stream=True)
            total = 0
            for page in h.pages():
                total += len(page)
                time.sleep(0.002)              # consumer slower than pool
            assert total == len(oracle("path3", "list"))

    def test_cancel_mid_stream_raises_for_consumer(self):
        with serve_server(mem_words=1 << 12, page_rows=8,
                          page_queue_depth=1) as srv:
            srv.fault_hook = lambda stg, qid, i: time.sleep(0.005)
            h = srv.submit("path3", "list", stream=True)
            with pytest.raises(QueryCancelled):
                for i, _page in enumerate(h.pages()):
                    if i == 1:
                        h.cancel()
            assert h.wait(60)
            assert h.status == "cancelled"

    def test_pages_requires_stream_submission(self):
        with serve_server() as srv:
            h = srv.submit("triangle", "count")
            h.result(300)
            with pytest.raises(Exception, match="stream"):
                h.pages()
