"""Fused per-box LFTJ megakernel: the interpret-mode Pallas lane pinned to
the scalar ``ref.py`` oracle, the host ``searchsorted`` frontier machine,
and the TriangleEngine/QueryEngine end-to-end oracles.

Acceptance pins (PR 7):

* ``fused_count`` / ``fused_list`` match ``fused_ref`` exactly on
  triangle / 4-clique / diamond atom shapes over random CSRs, including
  SENTINEL-padded ragged rows, empty frontiers, and starts-only depths.
* ``VectorizedBoxJoin(device="fused")`` matches the host lane bit-exactly
  (counts AND canonical listings) on identical BoundAtoms, and keeps the
  PR-6 bounded-buffer contract: exact ``count`` with a deterministic
  emitted prefix under any capacity.
* ``QueryEngine(backend="fused")`` matches the host backend across
  RMAT / star / ER x triangle / 4-clique / diamond x workers {1, 4} x
  cache on/off, boxed small so multiple boxes stream; the stats ledger
  records one device invocation per fused box.
* ``TriangleEngine(backend="fused")`` matches the default backend and
  records the per-box invocation ledger in ``EngineStats``.
* the crossover cache is keyed by (jax backend, device kind) and
  ``REPRO_CROSSOVER_REMEASURE`` clears only the active backend's entries.
"""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine as engine_mod
from repro.core.engine import TriangleEngine
from repro.data.graphs import rmat_graph
from repro.kernels.lftj_fused.ops import (FusedUnsupported, fused_cache_info,
                                          fused_count, fused_list,
                                          fused_supported)
from repro.kernels.lftj_fused.ref import SENTINEL, fused_ref
from repro.query import QueryEngine, patterns
from repro.query.vectorized import (BoundAtom, VectorizedBoxJoin,
                                    build_atom_slice)

WORKERS = (1, 4)

# atom shapes over the variable order, as the planner emits them: every
# pair for the cliques; the diamond's best order leaves variable 1
# starts-only (no bound atom — the binding-independent constant-row path)
DIMS = {
    "triangle": ((0, 1), (0, 2), (1, 2)),
    "four_clique": ((0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)),
    "diamond": ((1, 2), (1, 3), (0, 2), (0, 3)),
}


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def star_graph(hubs, leaves, seed):
    """A few hubs adjacent to every leaf plus a sprinkle of leaf-leaf
    edges — the skew fixture: a couple of huge rows over tiny ones."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(hubs), leaves)
    dst = hubs + np.tile(np.arange(leaves), hubs)
    extra = rng.integers(hubs, hubs + leaves, size=(leaves, 2))
    extra = extra[extra[:, 0] < extra[:, 1]]
    src = np.concatenate([src, extra[:, 0]])
    dst = np.concatenate([dst, extra[:, 1]])
    uniq = np.unique(src * (hubs + leaves) + dst)
    return (uniq // (hubs + leaves)).astype(np.int64), \
        (uniq % (hubs + leaves)).astype(np.int64)


GRAPHS = {
    "er": lambda seed: er_graph(40, 0.2, seed),
    "rmat": lambda seed: rmat_graph(64, 500, seed=seed),
    "star": lambda seed: star_graph(3, 24, seed),
}


def graph_csr(src, dst):
    """Oriented (u < v) adjacency as (keys, off, vals) compact CSR."""
    u = np.minimum(src, dst)
    v = np.maximum(src, dst)
    keep = u != v
    u, v = u[keep], v[keep]
    order = np.lexsort((v, u))
    u, v = u[order], v[order]
    uniq = np.unique(u * (int(max(v.max(initial=0), 1)) + 1) + v)
    stride = int(max(v.max(initial=0), 1)) + 1
    u, v = uniq // stride, uniq % stride
    keys, counts = np.unique(u, return_counts=True)
    off = np.concatenate([np.zeros(1, np.int64),
                          np.cumsum(counts, dtype=np.int64)])
    return keys.astype(np.int64), off, v.astype(np.int32)


def canonical(rows):
    rows = np.asarray(rows, dtype=np.int64)
    if len(rows) == 0:
        return rows
    order = np.lexsort(tuple(rows[:, c]
                             for c in range(rows.shape[1] - 1, -1, -1)))
    return rows[order]


class TestFusedVsRef:
    """kernels-layer pin: interpret-mode megakernel vs the scalar oracle."""

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from(sorted(DIMS)),
           st.sampled_from(sorted(GRAPHS)))
    def test_count_and_list_match_ref(self, seed, pattern, graph):
        src, dst = GRAPHS[graph](seed % 997)
        csr = graph_csr(src, dst)
        dims = DIMS[pattern]
        n_vars = max(sd for _, sd in dims) + 1
        csrs = [csr] * len(dims)
        want, want_rows = fused_ref(dims, csrs, n_vars, mode="list")
        got = fused_count(dims, csrs, n_vars, interpret=True)
        assert got == want
        total, rows = fused_list(dims, csrs, n_vars,
                                 capacity=max(1, want), interpret=True)
        assert total == want
        assert not len(rows) or not np.any(rows == SENTINEL)
        assert np.array_equal(canonical(rows), canonical(want_rows))

    def test_bounded_capacity_is_exact_prefix(self):
        src, dst = er_graph(30, 0.3, 7)
        csr = graph_csr(src, dst)
        dims = DIMS["triangle"]
        want, _ = fused_ref(dims, [csr] * 3, 3)
        assert want > 4
        total, rows = fused_list(dims, [csr] * 3, 3, capacity=2,
                                 interpret=True)
        assert total == want and len(rows) == 2
        full_total, full = fused_list(dims, [csr] * 3, 3, capacity=want,
                                      interpret=True)
        assert full_total == want
        # overflow rows are the deterministic prefix of the full traversal
        assert np.array_equal(rows, full[:2])

    def test_empty_graph_and_empty_frontier(self):
        empty = (np.zeros(0, np.int64), np.zeros(1, np.int64),
                 np.zeros(0, np.int32))
        dims = DIMS["triangle"]
        assert fused_count(dims, [empty] * 3, 3, interpret=True) == 0
        total, rows = fused_list(dims, [empty] * 3, 3, capacity=4,
                                 interpret=True)
        assert total == 0 and len(rows) == 0
        # disjoint key sets: depth-0 intersection is empty, no launch
        a = graph_csr(*er_graph(20, 0.3, 1))
        shifted = (a[0] + 1_000, a[1], a[2])
        assert fused_count(dims, [a, shifted, a], 3, interpret=True) == 0

    def test_starts_only_constant_depth(self):
        """Diamond dims leave variable 1 unbound-by-atom: candidates are a
        binding-independent key intersection, shipped as a constant row."""
        csr = graph_csr(*er_graph(28, 0.25, 3))
        dims = DIMS["diamond"]
        want, want_rows = fused_ref(dims, [csr] * 4, 4, mode="list")
        got = fused_count(dims, [csr] * 4, 4, interpret=True)
        assert got == want
        total, rows = fused_list(dims, [csr] * 4, 4,
                                 capacity=max(1, want), interpret=True)
        assert total == want
        assert np.array_equal(canonical(rows), canonical(want_rows))

    def test_supported_gate(self):
        assert fused_supported(DIMS["triangle"], 3) is None
        assert fused_supported(DIMS["diamond"], 4) is None
        assert fused_supported((), 3) is not None          # no atoms
        assert fused_supported(((0, 1),), 1) is not None   # one variable
        assert fused_supported(((1, 0),), 2) is not None   # not forward
        assert fused_supported(((0, 1),), 3) is not None   # innermost free
        # variable 1 touches no atom at all: Cartesian expansion
        assert fused_supported(((0, 2), (2, 3)), 4) is not None
        deep = tuple((d, d + 1) for d in range(7))
        assert "MAX_DEPTH" in fused_supported(deep, 8)
        with pytest.raises(FusedUnsupported):
            fused_count(((1, 0),), [graph_csr(*er_graph(10, 0.3, 0))], 2,
                        interpret=True)

    def test_program_cache_is_shape_bucketed(self):
        """Boxes of nearby sizes share one compiled program (pow2-bucketed
        pad shapes), so the jit cache stays logarithmic, not per-box."""
        before = fused_cache_info()["count_programs"]
        for n in (33, 35, 38, 40):
            csr = graph_csr(*er_graph(n, 0.2, n))
            fused_count(DIMS["triangle"], [csr] * 3, 3, interpret=True)
        after = fused_cache_info()["count_programs"]
        assert after - before <= 2


def atoms_from_csr(csr, dims):
    keys, off, vals = csr
    indptr = np.zeros(int(keys.max(initial=-1)) + 2, np.int64)
    indptr[keys + 1] = np.diff(off)
    indptr = np.cumsum(indptr)
    return [BoundAtom(fd, sd, build_atom_slice(indptr, vals, 0))
            for fd, sd in dims]


class TestFusedJoinLane:
    """VectorizedBoxJoin(device='fused') vs the staged host frontier
    machine on identical BoundAtoms."""

    @pytest.mark.parametrize("pattern", sorted(DIMS))
    def test_count_and_listing_parity(self, pattern):
        csr = graph_csr(*er_graph(36, 0.22, 11))
        dims = DIMS[pattern]
        n_vars = max(sd for _, sd in dims) + 1
        host = VectorizedBoxJoin(atoms_from_csr(csr, dims), n_vars,
                                 mode="list", device="host")
        fused = VectorizedBoxJoin(atoms_from_csr(csr, dims), n_vars,
                                  mode="list", device="fused")
        assert host.run() == fused.run()
        assert fused.used_fused and not host.used_fused
        assert np.array_equal(canonical(host.bindings()),
                              canonical(fused.bindings()))

    def test_overflow_keeps_exact_count(self):
        """PR-6 bounded-buffer contract through the fused lane: ``count``
        stays exact past capacity and the emitted rows are a prefix."""
        csr = graph_csr(*er_graph(32, 0.3, 5))
        dims = DIMS["triangle"]
        full = VectorizedBoxJoin(atoms_from_csr(csr, dims), 3,
                                 mode="list", device="fused")
        want = full.run()
        assert want > 1
        vj = VectorizedBoxJoin(atoms_from_csr(csr, dims), 3, mode="list",
                               device="fused", capacity=1)
        assert vj.run() == want          # exact despite the tiny buffer
        assert vj.emitted <= 1
        assert np.array_equal(vj.bindings(), full.bindings()[:vj.emitted])

    def test_unsupported_pattern_falls_back_to_staged(self):
        """path3 under its natural order binds nothing at the innermost
        depth only when dims skip variables — fabricate one: the fused
        gate rejects, the staged lane still answers."""
        csr = graph_csr(*er_graph(24, 0.25, 2))
        dims = ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6))
        n_vars = 7                       # deeper than MAX_DEPTH=6
        host = VectorizedBoxJoin(atoms_from_csr(csr, dims), n_vars,
                                 device="host")
        fused = VectorizedBoxJoin(atoms_from_csr(csr, dims), n_vars,
                                  device="fused")
        assert host.run() == fused.run()
        assert not fused.used_fused


class TestQueryEngineFused:
    """End-to-end: backend='fused' pinned to the host backend, boxed."""

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from(sorted(GRAPHS)),
           st.sampled_from(["triangle", "four_clique", "diamond"]),
           st.sampled_from(WORKERS), st.sampled_from([0, 256]))
    def test_counts_and_listings_match_host(self, seed, graph, pattern,
                                            workers, cache_words):
        src, dst = GRAPHS[graph](seed % 997)
        q = patterns.PATTERNS[pattern]()
        host = QueryEngine.from_graph(q, src, dst, mem_words=300,
                                      workers=workers,
                                      cache_words=cache_words,
                                      backend="host")
        fused = QueryEngine.from_graph(q, src, dst, mem_words=300,
                                       workers=workers,
                                       cache_words=cache_words,
                                       backend="fused")
        assert host.count() == fused.count()
        assert np.array_equal(canonical(host.list()),
                              canonical(fused.list()))
        s = fused.stats
        assert s.n_fused_boxes > 0
        assert s.device_invocations >= s.n_fused_boxes
        assert s.device_transfer_bytes > 0
        assert s.max_box_device_invocations >= 1

    def test_rescan_counter_on_overflow(self):
        src, dst = er_graph(48, 0.25, 13)
        qe = QueryEngine.from_graph(patterns.triangle(), src, dst,
                                    mem_words=300, backend="fused")
        rows = qe.list(capacity=1)
        host = QueryEngine.from_graph(patterns.triangle(), src, dst,
                                      mem_words=300, backend="host")
        assert np.array_equal(canonical(rows), canonical(host.list()))
        assert qe.stats.n_rescans >= 1


class TestTriangleEngineFused:
    @pytest.mark.parametrize("workers", WORKERS)
    def test_count_matches_auto(self, workers):
        src, dst = rmat_graph(128, 1200, seed=17)
        want = TriangleEngine(src, dst).count()
        eng = TriangleEngine(src, dst, mem_words=1000, workers=workers,
                             backend="fused")
        assert eng.count() == want
        s = eng.stats
        assert s.n_fused_boxes > 0
        assert s.device_invocations >= s.n_fused_boxes
        assert s.max_box_device_invocations >= 1
        assert s.device_transfer_bytes > 0

    def test_star_graph_hub_box(self):
        src, dst = star_graph(4, 60, 3)
        want = TriangleEngine(src, dst).count()
        eng = TriangleEngine(src, dst, mem_words=800, backend="fused")
        assert eng.count() == want
        assert eng.stats.n_fused_boxes > 0


class TestCrossoverCache:
    """Backend-keyed crossover persistence + selective REMEASURE."""

    def _reset(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
        monkeypatch.setattr(engine_mod, "_crossover_memo", {})
        monkeypatch.setattr(engine_mod, "_remeasure_handled", False)

    def test_keys_are_backend_prefixed(self, monkeypatch, tmp_path):
        self._reset(monkeypatch, tmp_path)
        got = engine_mod._cached_crossover(":unit", 7, lambda: 0.25)
        assert got == 0.25
        data = json.load(open(os.path.join(tmp_path, "crossover.json")))
        key = f"{engine_mod._active_prefix()}:nv7:unit"
        assert data[key] == 0.25
        # second call is memo/file served, never remeasured
        assert engine_mod._cached_crossover(
            ":unit", 7, lambda: (_ for _ in ()).throw(AssertionError)) == 0.25

    def test_remeasure_clears_only_active_backend(self, monkeypatch,
                                                  tmp_path):
        self._reset(monkeypatch, tmp_path)
        active = f"{engine_mod._active_prefix()}:nv7:unit"
        other = "tpu:TPU v4:nv256"
        engine_mod._crossover_store({active: 0.5, other: 0.125})
        monkeypatch.setenv("REPRO_CROSSOVER_REMEASURE", "1")
        got = engine_mod._cached_crossover(":unit", 7, lambda: 0.75)
        assert got == 0.75               # active entry was dropped
        data = json.load(open(os.path.join(tmp_path, "crossover.json")))
        assert data[other] == 0.125      # foreign backend survives
        assert data[active] == 0.75
        # the clear happens once per process: a second call re-reads
        monkeypatch.setattr(engine_mod, "_crossover_memo", {})
        assert engine_mod._cached_crossover(
            ":unit", 7, lambda: (_ for _ in ()).throw(AssertionError)) == 0.75
