"""Out-of-core path: edge store format, streaming engine equivalence,
bounded working set, prefetcher semantics.

Headline acceptance (ISSUE 2): ``TriangleEngine`` produces identical counts
and listings whether fed in-memory arrays or a ``data/edgestore`` path, with
the streaming path's per-box working set bounded by the planner budget (plus
pinned spill rows) on a graph whose padded neighbor matrix exceeds the
budget — and the run reports measured block I/Os.
"""

import os

import numpy as np
import pytest

from repro.core import (BlockDevice, TriangleEngine, TrieArray,
                        lftj_triangle_count, orient_edges, pad_neighbors,
                        plan_boxes_from_degrees)
from repro.core.lftj_jax import csr_from_edges
from repro.data.edgestore import (EdgeStore, InMemoryEdgeSource,
                                  write_edge_store)
from repro.data.graphs import rmat_graph
from repro.data.pipeline import Prefetcher


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def grid_graph(n):
    i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    v = (i * n + j)
    right = np.stack([v[:, :-1].ravel(), v[:, 1:].ravel()], 1)
    down = np.stack([v[:-1, :].ravel(), v[1:, :].ravel()], 1)
    e = np.concatenate([right, down])
    return e[:, 0], e[:, 1]


def reference(src, dst):
    a, b = orient_edges(src, dst)
    out = []
    n = lftj_triangle_count(TrieArray.from_edges(a, b), emit=out.append)
    tris = np.asarray(out, dtype=np.int64).reshape(-1, 3)
    tris = np.sort(tris, axis=1)
    order = np.lexsort((tris[:, 2], tris[:, 1], tris[:, 0]))
    return n, tris[order]


# ---------------------------------------------------------------------------
# format: write -> mmap read roundtrip
# ---------------------------------------------------------------------------

class TestEdgeStoreFormat:
    def test_roundtrip_rows_match_csr(self, tmp_path):
        src, dst = rmat_graph(200, 2500, seed=4)
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        path = write_edge_store(tmp_path / "g.csr", src, dst,
                                chunk_rows=7, align_words=16)
        store = EdgeStore(path)
        assert store.n_nodes == len(indptr) - 1
        assert store.n_edges == len(indices)
        assert store.orientation == "minmax"
        np.testing.assert_array_equal(store.indptr, indptr)
        # row ranges straddling chunk boundaries reassemble exactly
        for lo, hi in [(0, store.n_nodes - 1), (0, 6), (5, 9), (6, 7),
                       (13, 41), (store.n_nodes - 3, store.n_nodes - 1)]:
            ip, vals = store.read_rows(lo, hi)
            np.testing.assert_array_equal(
                vals, indices[indptr[lo]:indptr[hi + 1]])
            np.testing.assert_array_equal(
                ip, indptr[lo:hi + 2] - indptr[lo])

    def test_empty_graph_store_roundtrip(self, tmp_path):
        """Regression: an edgeless store has no indices region; opening it
        must not attempt a zero-length mmap past EOF."""
        path = write_edge_store(tmp_path / "empty.csr",
                                np.zeros(0, int), np.zeros(0, int))
        store = EdgeStore(path)
        assert store.n_edges == 0
        eng = TriangleEngine(store=path)
        assert eng.count() == 0
        assert eng.list().shape == (0, 3)

    def test_engine_requires_edges_or_store(self):
        with pytest.raises(ValueError, match="either"):
            TriangleEngine()

    def test_bad_magic_rejected(self, tmp_path):
        p = tmp_path / "junk.bin"
        p.write_bytes(b"\x00" * 256)
        with pytest.raises(ValueError, match="magic"):
            EdgeStore(p)

    def test_reads_are_charged_to_device(self, tmp_path):
        src, dst = rmat_graph(128, 1500, seed=1)
        path = write_edge_store(tmp_path / "g.csr", src, dst,
                                chunk_rows=16, align_words=8)
        dev = BlockDevice(block_words=8, cache_blocks=4)
        store = EdgeStore(path, device=dev)
        _, vals = store.read_rows(0, store.n_nodes - 1)
        assert dev.stats.word_reads == len(vals)
        # every word costs at most one block fetch; sequential reads amortize
        assert 1 <= dev.stats.block_reads <= len(vals) // 8 + store.n_chunks + 1

    def test_in_memory_source_matches_store(self, tmp_path):
        src, dst = er_graph(40, 0.2, seed=2)
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        path = write_edge_store(tmp_path / "g.csr", src, dst, chunk_rows=4)
        store = EdgeStore(path)
        mem = InMemoryEdgeSource(indptr, indices)
        for lo, hi in [(0, 5), (3, 17), (0, store.n_nodes - 1)]:
            ip_s, v_s = store.read_rows(lo, hi)
            ip_m, v_m = mem.read_rows(lo, hi)
            np.testing.assert_array_equal(v_s, v_m)
            np.testing.assert_array_equal(ip_s, ip_m)


# ---------------------------------------------------------------------------
# planner: degree-index plan partitions the edge set
# ---------------------------------------------------------------------------

class TestDegreePlanner:
    def test_boxes_partition_oriented_edges(self):
        src, dst = rmat_graph(128, 2000, seed=3)
        a, b = orient_edges(src, dst)
        indptr, _ = csr_from_edges(a, b)
        boxes = plan_boxes_from_degrees(indptr, mem_words=300)
        assert len(boxes) > 1
        covered = np.zeros(len(a), dtype=int)
        for (lx, hx, ly, hy) in boxes:
            covered += ((a >= lx) & (a <= hx) & (b >= ly) & (b <= hy))
        assert (covered == 1).all()

    def test_single_box_when_budget_fits(self):
        src, dst = er_graph(20, 0.3, seed=0)
        a, b = orient_edges(src, dst)
        indptr, _ = csr_from_edges(a, b)
        boxes = plan_boxes_from_degrees(indptr, mem_words=1 << 20)
        assert boxes == [(0, len(indptr) - 2, 0, len(indptr) - 2)]

    def test_x_ranges_respect_budget_except_pinned(self):
        src, dst = rmat_graph(128, 2000, seed=3)
        a, b = orient_edges(src, dst)
        indptr, _ = csr_from_edges(a, b)
        mem = 300
        bx = int(mem * 4 / 5)
        deg = np.diff(indptr)
        cost = np.where(deg > 0, deg + 2, 0)
        for (lx, hx, _ly, _hy) in plan_boxes_from_degrees(indptr, mem):
            words = int(cost[lx:hx + 1].sum())
            assert words <= bx or lx == hx, (lx, hx, words)


# ---------------------------------------------------------------------------
# engine equivalence: in-memory vs edge-store-backed execution
# ---------------------------------------------------------------------------

GRAPHS = [
    ("er", er_graph(40, 0.18, seed=7)),
    ("rmat", rmat_graph(128, 1500, seed=7)),
    ("grid", grid_graph(6)),
]


class TestOutOfCoreEquivalence:
    @pytest.mark.parametrize("name,edges", GRAPHS, ids=[g[0] for g in GRAPHS])
    def test_count_and_list_match_memory_engine(self, tmp_path, name, edges):
        src, dst = edges
        want_n, want_tris = reference(src, dst)
        path = write_edge_store(tmp_path / f"{name}.csr", src, dst,
                                chunk_rows=16, align_words=8)
        for mem_words in (None, 150):
            eng_m = TriangleEngine(src, dst, mem_words=mem_words)
            eng_s = TriangleEngine(store=path, mem_words=mem_words,
                                   io_block_words=64)
            assert eng_m.count() == want_n
            assert eng_s.count() == want_n, (name, mem_words)
            if mem_words is not None and len(src) > 60:
                assert eng_s.stats.n_boxes > 1    # budget forces many boxes
            np.testing.assert_array_equal(eng_s.list(), want_tris)
            np.testing.assert_array_equal(eng_m.list(), want_tris)

    def test_store_backed_run_reports_block_io(self, tmp_path):
        src, dst = rmat_graph(128, 1500, seed=9)
        path = write_edge_store(tmp_path / "g.csr", src, dst,
                                chunk_rows=16, align_words=8)
        eng = TriangleEngine(store=path, mem_words=200, io_block_words=64)
        eng.count()
        assert eng.stats.source == "edgestore"
        assert eng.stats.block_reads > 0
        assert eng.stats.word_reads >= eng.stats.slice_words_read > 0
        n = eng.count()
        tris = TriangleEngine(store=path, mem_words=200).list()
        assert len(tris) == n

    def test_sharded_store_backed_agrees(self, tmp_path):
        src, dst = rmat_graph(128, 1500, seed=11)
        want_n, want_tris = reference(src, dst)
        path = write_edge_store(tmp_path / "g.csr", src, dst, chunk_rows=32)
        eng = TriangleEngine(store=path, mem_words=250, shard=True)
        assert eng.count() == want_n
        np.testing.assert_array_equal(eng.list(), want_tris)


class TestBoundedWorkingSet:
    def test_streaming_working_set_bounded_by_budget(self, tmp_path):
        """Acceptance: on a graph whose padded neighbor matrix exceeds the
        budget, the streaming path (a) never materializes the global npad,
        (b) DMAs at most budget + O(pinned row) words per box, and (c) the
        per-box padded slice stays far below the global matrix."""
        src, dst = rmat_graph(512, 6000, seed=5)
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        npad_words = pad_neighbors(indptr, indices).size
        csr_words = len(indices) + 2 * (len(indptr) - 1)
        budget = max(256, csr_words // 8)
        assert npad_words > budget          # the premise of the test
        path = write_edge_store(tmp_path / "big.csr", src, dst,
                                chunk_rows=64, align_words=32)
        eng = TriangleEngine(store=path, mem_words=budget, io_block_words=64)
        want_n, want_tris = reference(src, dst)
        assert eng.count() == want_n
        assert eng.stats.n_boxes > 1
        # (a) global padded matrix never built, edge list never resident
        assert eng._npad is None and eng._npad_host is None
        assert eng.indices is None
        # (b) raw words DMA'd per box ≤ budget, unless a single pinned row
        # (the plan-level spill) exceeds it by itself
        max_row = int(np.diff(indptr).max()) + 2
        assert eng.stats.max_slice_words <= max(budget, 2 * max_row), \
            (eng.stats.max_slice_words, budget)
        # (c) compacted per-box padding stays well below the global matrix
        assert eng.stats.max_slice_padded_words < npad_words / 2
        np.testing.assert_array_equal(
            TriangleEngine(store=path, mem_words=budget).list(), want_tris)


# ---------------------------------------------------------------------------
# prefetcher: ordering, exception propagation, early close
# ---------------------------------------------------------------------------

class TestPrefetcher:
    def test_preserves_order(self):
        assert list(Prefetcher(iter(range(100)), depth=3)) == list(range(100))

    def test_propagates_producer_exception(self):
        def gen():
            yield 1
            yield 2
            raise RuntimeError("disk on fire")

        pf = Prefetcher(gen(), depth=1)
        assert next(pf) == 1
        assert next(pf) == 2
        with pytest.raises(RuntimeError, match="disk on fire"):
            next(pf)

    def test_exception_before_first_item(self):
        def gen():
            raise ValueError("bad header")
            yield 1  # pragma: no cover

        with pytest.raises(ValueError, match="bad header"):
            next(Prefetcher(gen(), depth=2))

    def test_close_stops_producer(self):
        produced = []

        def gen():
            for i in range(10_000):
                produced.append(i)
                yield i

        pf = Prefetcher(gen(), depth=2)
        assert next(pf) == 0
        pf.close()
        pf.thread.join(timeout=5)
        assert not pf.thread.is_alive()
        assert len(produced) < 10_000       # stopped early, not drained

    def test_close_is_idempotent_under_double_close(self):
        """Stress contract: close() joins the producer and a second (or
        third) close is a cheap no-op — no hang, no error, no thread."""
        pf = Prefetcher(iter(range(1000)), depth=2)
        assert next(pf) == 0
        pf.close()
        assert not pf.thread.is_alive()
        pf.close()                          # double-close: no-op
        pf.close()
        assert not pf.thread.is_alive()

    def test_close_after_exhaustion(self):
        pf = Prefetcher(iter(range(3)), depth=2)
        assert list(pf) == [0, 1, 2]
        pf.close()
        pf.close()
        assert not pf.thread.is_alive()


# ---------------------------------------------------------------------------
# concurrent readers: the mmap store serves parallel row-range reads
# ---------------------------------------------------------------------------

class TestConcurrentReads:
    def test_concurrent_read_rows_match_serial(self, tmp_path):
        """The async scheduler's slice builders share one EdgeStore: reads
        from many threads must reassemble exactly what a serial reader
        sees, and the shared device ledger must account every word."""
        import threading

        src, dst = rmat_graph(512, 6000, seed=13)
        path = write_edge_store(tmp_path / "g.csr", src, dst,
                                chunk_rows=32, align_words=16)
        dev = BlockDevice(block_words=64, cache_blocks=64)
        store = EdgeStore(path, device=dev)
        serial = EdgeStore(path)
        rng = np.random.default_rng(0)
        windows = [tuple(sorted(rng.integers(0, store.n_nodes, 2)))
                   for _ in range(64)]
        want = [serial.read_rows(lo, hi) for lo, hi in windows]
        got = [None] * len(windows)
        errs = []

        def reader(ids):
            try:
                for i in ids:
                    lo, hi = windows[i]
                    got[i] = store.read_rows(lo, hi)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=reader,
                                    args=(range(k, len(windows), 4),))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        total_words = 0
        for (ip_w, v_w), (ip_g, v_g), (lo, hi) in zip(want, got, windows):
            np.testing.assert_array_equal(ip_w, ip_g)
            np.testing.assert_array_equal(v_w, v_g)
            total_words += len(v_w)
        # the shared ledger saw exactly the words the threads pulled
        assert dev.stats.word_reads == total_words
