"""Data pipeline: generators, sampler, prefetcher, mesh generation."""

import numpy as np
import pytest

from repro.data.graphs import (clustered_graph, icosahedral_mesh,
                               make_gnn_batch, random_graph, rmat_graph)
from repro.data.pipeline import Prefetcher
from repro.data.recsys import CriteoLikeGenerator
from repro.data.sampler import NeighborSampler
from repro.data.tokens import TokenStream
from repro.core import csr_from_edges


class TestGenerators:
    def test_random_graph_simple(self):
        src, dst = random_graph(100, 2000, seed=0)
        assert np.all(src < dst)                       # oriented, no self loops
        e = set(zip(src.tolist(), dst.tolist()))
        assert len(e) == len(src)                       # no duplicates

    def test_rmat_powerlaw_ish(self):
        src, dst = rmat_graph(1 << 12, 40000, seed=0)
        deg = np.bincount(np.concatenate([src, dst]))
        # heavy tail: max degree far above mean (vs uniform RAND)
        assert deg.max() > 8 * deg[deg > 0].mean()

    def test_clustered_graph_has_triangles(self):
        from repro.core import count_triangles
        src, dst = clustered_graph(5, 10, p_in=0.9)
        assert count_triangles(src, dst, method="vectorized") > 50

    def test_token_stream(self):
        ts = TokenStream(vocab=100, seed=0)
        b = ts.batch(4, 32)
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)
        # next-token alignment
        b2 = ts.batch(2, 8)
        assert b2["tokens"].max() < 100

    def test_criteo_gen(self):
        gen = CriteoLikeGenerator((100, 50, 20), n_dense=13, hot=2)
        b = gen.batch(64)
        assert b["dense"].shape == (64, 13)
        assert b["sparse"].shape == (64, 3, 2)
        assert b["sparse"][:, 0].max() < 100
        assert set(np.unique(b["labels"])) <= {0.0, 1.0}
        # zipf: index 0 should be the most common
        counts = np.bincount(b["sparse"][:, 0].ravel())
        assert counts[0] == counts.max()


class TestSampler:
    def test_block_shapes_and_masks(self):
        src, dst = random_graph(500, 4000, seed=1)
        # symmetrize for sampling
        s2 = np.concatenate([src, dst])
        d2 = np.concatenate([dst, src])
        indptr, indices = csr_from_edges(s2, d2, 500)
        samp = NeighborSampler(indptr, indices, fanout=(5, 3), seed=0)
        feats = np.random.default_rng(0).standard_normal((500, 16)).astype(np.float32)
        labels = np.zeros(500, np.int32)
        batch = samp.padded_batch(np.arange(32), feats, labels,
                                  blk_nodes=32 * 24, blk_edges=32 * 20)
        assert batch["node_feat"].shape == (768, 16)
        ne = int(batch["edge_mask"].sum())
        assert 0 < ne <= 640
        # all masked-in edges reference masked-in nodes
        es = batch["edge_src"][batch["edge_mask"] > 0]
        ed = batch["edge_dst"][batch["edge_mask"] > 0]
        nn = int(batch["node_mask"].sum())
        assert es.max() < nn and ed.max() < nn
        # only seeds supervised
        assert batch["label_mask"].sum() <= 32

    def test_fanout_bound(self):
        src, dst = random_graph(200, 3000, seed=2)
        s2 = np.concatenate([src, dst]); d2 = np.concatenate([dst, src])
        indptr, indices = csr_from_edges(s2, d2, 200)
        samp = NeighborSampler(indptr, indices, fanout=(4,), seed=0)
        nodes, es, ed = samp.sample_block(np.arange(10))
        assert len(es) <= 10 * 4


class TestPrefetcher:
    def test_order_preserved(self):
        out = list(Prefetcher(iter(range(20)), depth=3))
        assert out == list(range(20))

    def test_error_propagates(self):
        def gen():
            yield 1
            raise RuntimeError("boom")
        pf = Prefetcher(gen())
        assert next(pf) == 1
        with pytest.raises(RuntimeError):
            list(pf)


class TestIcoMesh:
    def test_refinement_counts(self):
        verts, src, dst = icosahedral_mesh(2)
        # V(r) = 10*4^r + 2
        assert len(verts) == 10 * 4 ** 2 + 2
        assert np.all(src < dst)
        np.testing.assert_allclose(np.linalg.norm(verts, axis=1), 1.0,
                                   rtol=1e-5)

    def test_multimesh_includes_coarse_edges(self):
        _, s1, d1 = icosahedral_mesh(0)
        _, s2, d2 = icosahedral_mesh(1)
        e1 = set(zip(s1.tolist(), d1.tolist()))
        e2 = set(zip(s2.tolist(), d2.tolist()))
        assert e1 <= e2     # multimesh = union over levels
