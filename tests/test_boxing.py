"""Boxing (Algorithm 2): correctness under memory pressure, spills,
partition invariants, I/O accounting vs the paper's bounds."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockDevice, TrieArray, adversarial_graph,
                        boxed_triangle_count, brute_force_count,
                        count_triangles, lftj_triangle_count, orient_edges,
                        plan_boxes)


def graph(max_n=25, max_e=120):
    return st.lists(st.tuples(st.integers(0, max_n), st.integers(0, max_n)),
                    min_size=1, max_size=max_e)


class TestBoxedCorrectness:
    @settings(max_examples=25, deadline=None)
    @given(graph(), st.integers(12, 400))
    def test_boxed_any_budget(self, edges, mem):
        e = np.asarray(edges)
        want = brute_force_count(e[:, 0], e[:, 1])
        got = count_triangles(e[:, 0], e[:, 1], method="boxed", mem_words=mem)
        assert got == want

    @settings(max_examples=10, deadline=None)
    @given(graph(max_n=40, max_e=200), st.integers(24, 200))
    def test_boxed_vectorized_any_budget(self, edges, mem):
        e = np.asarray(edges)
        want = brute_force_count(e[:, 0], e[:, 1])
        got = count_triangles(e[:, 0], e[:, 1], method="boxed_vec",
                              mem_words=mem)
        assert got == want

    def test_spill_star_graph(self):
        """A hub whose neighbor list exceeds any per-atom budget spills;
        results must still be exact (§3.3 spill handling)."""
        hub = np.zeros(80, dtype=int)
        leaves = np.arange(1, 81)
        src = np.concatenate([hub, leaves[:-1]])
        dst = np.concatenate([leaves, leaves[1:]])
        want = brute_force_count(src, dst)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        for mem in (10, 20, 40):
            cnt, stats = boxed_triangle_count(ta, mem)
            assert cnt == want
            if mem <= 20:
                assert stats.n_spills > 0

    def test_listing_matches_counting(self):
        rng = np.random.default_rng(3)
        src = rng.integers(0, 40, 400)
        dst = rng.integers(0, 40, 400)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        full = lftj_triangle_count(ta)
        out = []
        cnt, _ = boxed_triangle_count(ta, 64, emit=out.append)
        assert cnt == full == len(out)
        assert len(set(out)) == len(out)


class TestBoxPlan:
    def test_plan_covers_all_edges(self):
        """Boxes partition the (x, y) plane: every oriented edge falls in
        >= 1 box x-range and exactly one x-interval (no overlap)."""
        rng = np.random.default_rng(4)
        src = rng.integers(0, 100, 800)
        dst = rng.integers(0, 100, 800)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        boxes = plan_boxes(ta, mem_words=120)
        assert boxes
        xs = sorted({(lx, hx) for (lx, hx, _, _) in boxes})
        # x-intervals are disjoint and ordered
        for (l1, h1), (l2, h2) in zip(xs, xs[1:]):
            assert h1 < l2
        # coverage: every x value with outgoing edges is inside some interval
        for v in np.unique(a):
            assert any(l <= v <= h for (l, h) in xs)

    def test_box_count_shrinks_with_memory(self):
        """Lemma 9: #boxes ~ O((|I|/M)^2); more memory => fewer boxes."""
        rng = np.random.default_rng(5)
        src = rng.integers(0, 200, 3000)
        dst = rng.integers(0, 200, 3000)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        counts = []
        for mem in (80, 320, 1280, ta.words() * 2):
            _, stats = boxed_triangle_count(ta, mem)
            counts.append(stats.n_boxes)
        assert counts[0] >= counts[1] >= counts[2] >= counts[3]
        assert counts[-1] <= 2   # |I| <= M: O(1) boxes

    def test_provisioned_words_bound(self):
        """Thm. 13 (rank 2): provisioned words ~ O(|I|^2 / M)."""
        rng = np.random.default_rng(6)
        src = rng.integers(0, 300, 4000)
        dst = rng.integers(0, 300, 4000)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        n = ta.words()
        for mem in (n // 8, n // 4, n // 2):
            _, stats = boxed_triangle_count(ta, mem)
            bound = 40 * (n * n / mem + n)   # generous constant
            assert stats.provisioned_words <= bound


class TestIOModel:
    def test_adversarial_thrashing(self):
        """Prop. 4 (footnote-9 form): vanilla LFTJ incurs Omega(|E|) block
        I/Os on G_N under LRU — one miss per tuple (thrashing)."""
        m, bsz = 400, 16
        src, dst = adversarial_graph(1600, m, bsz)
        ne = len(src)
        dev = BlockDevice(block_words=bsz, cache_blocks=m // bsz)
        count_triangles(src, dst, method="faithful", device=dev)
        assert dev.stats.block_reads >= ne  # >= one I/O per tuple

    def test_boxed_beats_vanilla_on_rmat(self):
        """Fig. 9 qualitative claim: at 10% memory, boxed LFTJ does far
        fewer block I/Os than vanilla LFTJ under LRU paging, with equal
        counts. (The paper measures 65x on billion-edge data + mmap; the
        simulator shows the same dominance at test scale.)"""
        from repro.data.graphs import rmat_graph
        src, dst = rmat_graph(1 << 11, 22000, seed=0)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        words, bsz = ta.words(), 64
        m = int(words * 0.1)
        dev = BlockDevice(block_words=bsz, cache_blocks=max(2, m // bsz))
        c1 = count_triangles(src, dst, method="faithful", device=dev)
        vanilla = dev.stats.block_reads
        dev2 = BlockDevice(block_words=bsz, cache_blocks=max(2, m // bsz))
        dev2.register_triearray(ta)
        c2, _ = boxed_triangle_count(ta, m, block_words=bsz, device=dev2)
        assert c1 == c2
        assert dev2.stats.block_reads * 2 < vanilla  # >= 2x fewer I/Os

    def test_boxed_io_within_thm13_bound(self):
        """Thm. 13 (rank 2): boxed I/O ∈ O(|I|²/(MB) + |I|/B) — assert the
        measured block reads stay within a constant of the bound."""
        from repro.data.graphs import rmat_graph
        src, dst = rmat_graph(1 << 11, 22000, seed=1)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        words, bsz = ta.words(), 64
        for frac in (0.1, 0.3):
            m = int(words * frac)
            dev = BlockDevice(block_words=bsz, cache_blocks=max(2, m // bsz))
            dev.register_triearray(ta)
            boxed_triangle_count(ta, m, block_words=bsz, device=dev)
            bound = words * words / (m * bsz) + words / bsz
            assert dev.stats.block_reads <= 12 * bound

    def test_lru_cache_counts(self):
        dev = BlockDevice(block_words=8, cache_blocks=2)
        arr = np.arange(64)
        dev.register(arr)
        dev.touch(arr, 0)
        dev.touch(arr, 1)        # same block: hit
        assert dev.stats.block_reads == 1
        dev.touch(arr, 8)
        dev.touch(arr, 16)
        dev.touch(arr, 0)        # evicted by LRU: miss again
        assert dev.stats.block_reads == 4
