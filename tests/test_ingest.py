"""Streaming bounded-memory ingest: equivalence, budget, cache accounting.

Headline acceptance (ISSUE 3): the whole pipeline is bounded-memory end to
end. ``EdgeStoreWriter`` builds the chunked-CSR store via two-pass
external-sort ingest and its output is *byte-identical* to the in-memory
``write_edge_store`` path; ingesting an edge list larger than the budget
keeps peak allocations under ~2x the budget (plus the O(V) resident degree
index); and the ``SliceCache`` strictly reduces measured block reads on the
adjacent-box workload without changing any count.
"""

import os
import tracemalloc
from collections import OrderedDict

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BlockDevice, SliceCache, TriangleEngine
from repro.data.edgestore import (EdgeStore, EdgeStoreWriter,
                                  write_edge_store,
                                  write_edge_store_streaming)
from repro.data.graphs import random_graph, rmat_graph
from repro.data.pipeline import edge_batches


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


GRAPHS = [
    ("er", er_graph(96, 0.3, seed=11)),
    ("rmat", rmat_graph(512, 6000, seed=11)),
    ("rand", random_graph(300, 4000, seed=11)),
]


# ---------------------------------------------------------------------------
# streaming writer == in-memory writer, byte for byte
# ---------------------------------------------------------------------------

class TestStreamingWriterEquivalence:
    @pytest.mark.parametrize("name,edges", GRAPHS, ids=[g[0] for g in GRAPHS])
    @pytest.mark.parametrize("orientation", ["minmax", "degree"])
    def test_byte_identical_to_in_memory_writer(self, tmp_path, name,
                                                edges, orientation):
        src, dst = edges
        # duplicates, reversals and self-loops must dedup identically
        src2 = np.concatenate([src, dst, src[:50], np.arange(20)])
        dst2 = np.concatenate([dst, src, dst[:50], np.arange(20)])
        p_mem = write_edge_store(tmp_path / "mem.csr", src2, dst2,
                                 orientation=orientation,
                                 chunk_rows=19, align_words=16)
        p_str = write_edge_store_streaming(
            tmp_path / "str.csr", edge_batches(src2, dst2, batch_edges=501),
            orientation=orientation, chunk_rows=19, align_words=16,
            budget_words=2048)
        assert p_mem != p_str
        with open(p_mem, "rb") as a, open(p_str, "rb") as b:
            assert a.read() == b.read()

    def test_empty_graph_byte_identical(self, tmp_path):
        p_mem = write_edge_store(tmp_path / "mem.csr",
                                 np.zeros(0, int), np.zeros(0, int))
        p_str = write_edge_store_streaming(tmp_path / "str.csr", iter([]))
        with open(p_mem, "rb") as a, open(p_str, "rb") as b:
            assert a.read() == b.read()

    def test_count_and_list_equivalence(self, tmp_path):
        """Counts/listings from an ingested store match the in-memory
        engine — the store itself is equivalent, not just byte-compatible."""
        src, dst = rmat_graph(256, 3000, seed=3)
        eng_mem = TriangleEngine(src, dst, mem_words=200)
        eng_ing = TriangleEngine.ingest(
            tmp_path / "g.csr", (src, dst), chunk_rows=32, align_words=16,
            ingest_budget_words=1024, mem_words=200)
        assert eng_ing.count() == eng_mem.count()
        np.testing.assert_array_equal(eng_ing.list(), eng_mem.list())

    def test_writer_rejects_mismatched_batches_and_bad_ids(self, tmp_path):
        w = EdgeStoreWriter(tmp_path / "g.csr")
        with pytest.raises(ValueError, match="length"):
            w.add_edges(np.arange(3), np.arange(4))
        with pytest.raises(ValueError, match="ids"):
            w.add_edges(np.asarray([-1]), np.asarray([2]))
        w.add_edges(np.asarray([0, 1]), np.asarray([1, 2]))
        w.finalize()
        with pytest.raises(RuntimeError, match="finalized"):
            w.add_edges(np.asarray([0]), np.asarray([1]))

    def test_failed_merge_leaves_no_partial_store(self, tmp_path, monkeypatch):
        """A pass-2 failure (disk full, ...) must not leave a truncated
        store masquerading as the real file, nor any spill debris."""
        src, dst = rmat_graph(256, 3000, seed=2)
        w = EdgeStoreWriter(tmp_path / "g.csr", budget_words=1024)

        def boom(self, f):
            raise OSError("disk full")

        monkeypatch.setattr(EdgeStoreWriter, "_merge", boom)
        with pytest.raises(OSError, match="disk full"):
            with w:
                w.add_edges(src, dst)
        assert os.listdir(tmp_path) == []     # no partial store, no runs

    def test_spill_runs_cleaned_up(self, tmp_path):
        src, dst = rmat_graph(256, 4000, seed=1)
        w = EdgeStoreWriter(tmp_path / "g.csr", budget_words=1024)
        w.add_edges(src, dst)
        w.finalize()
        assert w.n_spill_runs > 1            # the budget actually spilled
        leftovers = [p for p in os.listdir(tmp_path)
                     if p != "g.csr"]
        assert leftovers == []


# ---------------------------------------------------------------------------
# bounded-memory ingest: edge list > budget, peak allocations ~2x budget
# ---------------------------------------------------------------------------

class TestIngestBudget:
    def test_peak_allocations_bounded_by_budget(self, tmp_path):
        """Acceptance: ingest a graph whose raw edge list exceeds the
        budget; peak ingest allocations stay under ~2x the budget plus the
        O(V) resident degree/index arrays, and the result is byte-identical
        to the in-memory writer's."""
        nv = 384
        src, dst = er_graph(nv, 0.5, seed=7)
        budget_words = 6000
        budget_bytes = 4 * budget_words
        edge_list_bytes = 16 * len(src)       # two int64 endpoints per edge
        assert edge_list_bytes > 4 * budget_bytes   # the premise
        batch = budget_words // 16            # ~56 B/edge transient per batch
        writer = EdgeStoreWriter(tmp_path / "g.csr", chunk_rows=64,
                                 align_words=32, budget_words=budget_words)
        tracemalloc.start()
        with writer:
            for s, d in edge_batches(src, dst, batch_edges=batch):
                writer.add_edges(s, d)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert writer.n_spill_runs > 2
        # 2x budget + the O(V) resident arrays (outdeg/indptr/offsets/
        # transient bincount) + a small fixed python slack
        allowed = 2 * budget_bytes + 48 * nv + 16384
        assert peak < allowed, (peak, allowed)
        p_mem = write_edge_store(tmp_path / "mem.csr", src, dst,
                                 chunk_rows=64, align_words=32)
        with open(p_mem, "rb") as a, open(tmp_path / "g.csr", "rb") as b:
            assert a.read() == b.read()


# ---------------------------------------------------------------------------
# slice cache: fewer block reads, identical counts, honest accounting
# ---------------------------------------------------------------------------

class TestSliceCache:
    def _store(self, tmp_path, seed=5):
        src, dst = rmat_graph(512, 6000, seed=seed)
        path = write_edge_store(tmp_path / "g.csr", src, dst,
                                chunk_rows=64, align_words=32)
        return path, src, dst

    def test_cache_reduces_block_reads_same_counts(self, tmp_path):
        """Acceptance: same workload (identical box plan), cache on vs off
        -> strictly fewer block reads, identical triangle count, and the
        hits show up in the engine + device accounting."""
        path, src, dst = self._store(tmp_path)
        mem = 400
        off = TriangleEngine(store=path, mem_words=mem, io_block_words=64)
        n_off = off.count()
        on = TriangleEngine(store=path, mem_words=mem, io_block_words=64,
                            cache_words=8 * mem)
        n_on = on.count()
        assert n_on == n_off == TriangleEngine(src, dst).count()
        assert on.stats.n_boxes == off.stats.n_boxes      # same workload
        assert on.stats.block_reads < off.stats.block_reads
        assert on.stats.cache_hits > 0
        assert 0.0 < on.stats.cache_hit_rate <= 1.0
        assert on.stats.cache_hit_words > 0
        # the avoided traffic is visible on the device's ledger
        assert on.device.stats.cache_served_words >= on.stats.cache_hit_words

    def test_cached_listing_identical(self, tmp_path):
        path, _, _ = self._store(tmp_path, seed=6)
        t_off = TriangleEngine(store=path, mem_words=400).list()
        t_on = TriangleEngine(store=path, mem_words=400,
                              cache_words=4096).list()
        np.testing.assert_array_equal(t_on, t_off)

    def test_cache_read_rows_matches_source(self, tmp_path):
        """Every (lo, hi) window reassembles exactly, across hit/miss/
        partial-edge paths and after evictions."""
        path, _, _ = self._store(tmp_path, seed=8)
        store = EdgeStore(path)
        cache = SliceCache(EdgeStore(path), budget_words=512, block_rows=5)
        rng = np.random.default_rng(0)
        windows = [(0, store.n_nodes - 1), (0, 4), (3, 3), (17, 93)]
        windows += [tuple(sorted(rng.integers(0, store.n_nodes, 2)))
                    for _ in range(30)]
        for lo, hi in windows:
            ip_c, v_c = cache.read_rows(lo, hi)
            ip_s, v_s = store.read_rows(lo, hi)
            np.testing.assert_array_equal(ip_c, ip_s)
            np.testing.assert_array_equal(v_c, v_s)
        assert cache.hits > 0 and cache.misses > 0

    def test_cache_budget_evicts(self, tmp_path):
        path, _, _ = self._store(tmp_path, seed=9)
        cache = SliceCache(EdgeStore(path), budget_words=256, block_rows=4)
        cache.read_rows(0, 400)
        assert cache._words <= 256 or len(cache._blocks) == 1

    def test_cache_never_reads_more_than_uncached(self, tmp_path):
        """The pass-through design guarantee: even with a thrashing tiny
        budget, the cached engine never charges *more* word reads than the
        uncached one."""
        path, _, _ = self._store(tmp_path, seed=5)
        mem = 400
        off = TriangleEngine(store=path, mem_words=mem, io_block_words=64)
        off.count()
        tiny = TriangleEngine(store=path, mem_words=mem, io_block_words=64,
                              cache_words=64)
        tiny.count()
        assert tiny.stats.word_reads <= off.stats.word_reads


# ---------------------------------------------------------------------------
# slice-cache invariants under randomized access patterns (hypothesis)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cache_store(tmp_path_factory):
    """One shared store for the property tests (module-scoped: hypothesis
    replays many examples against it, and the graph itself is fixed)."""
    src, dst = rmat_graph(512, 6000, seed=21)
    path = tmp_path_factory.mktemp("cachestore") / "g.csr"
    return str(write_edge_store(path, src, dst, chunk_rows=64,
                                align_words=32))


def _windows_strategy(nv):
    pair = st.tuples(st.integers(0, nv - 1), st.integers(0, nv - 1))
    return st.lists(pair.map(lambda p: (min(p), max(p))),
                    min_size=1, max_size=25)


class TestSliceCacheProperties:
    NV = 512

    @settings(max_examples=15, deadline=None)
    @given(windows=_windows_strategy(NV), block_rows=st.integers(2, 16),
           budget=st.integers(128, 2048))
    def test_lru_eviction_order_matches_model(self, cache_store, windows,
                                              block_rows, budget):
        """The resident block set and its recency order track a reference
        LRU model exactly: hits move-to-end, miss runs insert in block
        order, eviction trims oldest-first past the word budget."""
        store = EdgeStore(cache_store)
        cache = SliceCache(EdgeStore(cache_store), budget_words=budget,
                           block_rows=block_rows)
        ip = store.indptr
        br = cache.block_rows

        def block_words(bid):
            # interior blocks are always full: values + (br + 1) indptr
            return int(ip[bid * br + br] - ip[bid * br]) + br + 1

        model: OrderedDict = OrderedDict()
        for lo, hi in windows:
            ib0, ib1 = -(-lo // br), (hi + 1) // br - 1
            cache.read_rows(lo, hi)
            for bid in range(ib0, ib1 + 1):
                if bid in model:
                    model.move_to_end(bid)
                else:
                    model[bid] = block_words(bid)
                    while sum(model.values()) > budget and len(model) > 1:
                        model.popitem(last=False)
            assert list(cache._blocks) == list(model), (lo, hi)
        assert cache._words == sum(model.values())

    @settings(max_examples=10, deadline=None)
    @given(windows=_windows_strategy(NV), block_rows=st.integers(2, 16))
    def test_hit_rate_monotone_in_cache_words(self, cache_store, windows,
                                              block_rows):
        """LRU inclusion: replaying one access pattern against growing
        budgets (same block granularity) never loses hits."""
        hits = []
        for budget in (192, 768, 3072, 1 << 20):
            cache = SliceCache(EdgeStore(cache_store), budget_words=budget,
                               block_rows=block_rows)
            for lo, hi in windows:
                cache.read_rows(lo, hi)
            hits.append(cache.hits)
        assert hits == sorted(hits), hits

    @settings(max_examples=10, deadline=None)
    @given(windows=_windows_strategy(NV), block_rows=st.integers(2, 16),
           budget=st.integers(64, 1024))
    def test_cache_never_reads_more_than_uncached(self, cache_store,
                                                  windows, block_rows,
                                                  budget):
        """Design guarantee under arbitrary access patterns: the cached
        reader never charges more block or word reads than the uncached
        one — worst case (zero reuse, thrashing budget) costs the same."""
        dev_raw = BlockDevice(block_words=64, cache_blocks=8)
        raw = EdgeStore(cache_store, device=dev_raw)
        dev_c = BlockDevice(block_words=64, cache_blocks=8)
        cached = SliceCache(EdgeStore(cache_store, device=dev_c),
                            budget_words=budget, block_rows=block_rows)
        for lo, hi in windows:
            ip_r, v_r = raw.read_rows(lo, hi)
            ip_c, v_c = cached.read_rows(lo, hi)
            np.testing.assert_array_equal(v_c, v_r)
            np.testing.assert_array_equal(ip_c, ip_r)
        assert dev_c.stats.block_reads <= dev_raw.stats.block_reads
        assert dev_c.stats.word_reads <= dev_raw.stats.word_reads


# ---------------------------------------------------------------------------
# multi-tenant shared cache (repro.serve.cache): the single-query model
# extended with per-tenant attribution + floor-protected eviction
# ---------------------------------------------------------------------------

def _tenant_windows_strategy(nv, n_tenants=3):
    pair = st.tuples(st.integers(0, n_tenants - 1),
                     st.integers(0, nv - 1), st.integers(0, nv - 1))
    return st.lists(pair.map(lambda p: (p[0], min(p[1:]), max(p[1:]))),
                    min_size=1, max_size=30)


class TestSharedSliceCacheProperties:
    NV = 512

    @settings(max_examples=15, deadline=None)
    @given(accesses=_tenant_windows_strategy(NV),
           block_rows=st.integers(2, 16), budget=st.integers(256, 4096))
    def test_tenant_ledgers_sum_to_global(self, cache_store, accesses,
                                          block_rows, budget):
        """Per-tenant hit/miss accounting partitions the global ledger
        exactly: no access is double-counted or dropped, and per-tenant
        resident words sum to the cache's word total."""
        from repro.serve import SharedSliceCache
        cache = SharedSliceCache(EdgeStore(cache_store),
                                 budget_words=budget,
                                 block_rows=block_rows)
        views = {t: cache.register(t, floor_words=budget // 8)
                 for t in range(3)}
        for t, lo, hi in accesses:
            views[t].read_rows(lo, hi)
        stats = [cache.tenant_stats(t) for t in range(3)]
        assert sum(s.hits for s in stats) == cache.hits
        assert sum(s.misses for s in stats) == cache.misses
        assert sum(s.hit_words for s in stats) == cache.hit_words
        assert sum(s.miss_words for s in stats) == cache.miss_words
        assert sum(s.passthrough_words for s in stats) == \
            cache.passthrough_words
        assert sum(s.words for s in stats) == cache._words

    @settings(max_examples=15, deadline=None)
    @given(accesses=_tenant_windows_strategy(NV),
           block_rows=st.integers(2, 16), budget=st.integers(256, 2048))
    def test_eviction_never_crosses_tenant_floor(self, cache_store,
                                                 accesses, block_rows,
                                                 budget):
        """Once a tenant's resident words reach its reservation floor,
        no eviction — its own inserts' or a neighbour's — ever takes it
        below the floor again."""
        from repro.serve import SharedSliceCache
        cache = SharedSliceCache(EdgeStore(cache_store),
                                 budget_words=budget,
                                 block_rows=block_rows)
        floors = {0: budget // 4, 1: budget // 8, 2: 0}
        views = {t: cache.register(t, floor_words=f)
                 for t, f in floors.items()}
        reached = set()
        for t, lo, hi in accesses:
            views[t].read_rows(lo, hi)
            for u, f in floors.items():
                words = cache.tenant_stats(u).words
                if words >= f:
                    reached.add(u)
                elif u in reached:
                    raise AssertionError(
                        f"tenant {u} evicted below its floor: "
                        f"{words} < {f}")

    @settings(max_examples=10, deadline=None)
    @given(windows=_windows_strategy(NV), block_rows=st.integers(2, 16),
           budget=st.integers(128, 2048))
    def test_single_tenant_matches_plain_slicecache(self, cache_store,
                                                    windows, block_rows,
                                                    budget):
        """With exactly one tenant the shared cache degenerates to the
        plain ``SliceCache``: identical data, identical resident set and
        recency order, identical hit/miss ledger."""
        from repro.serve import SharedSliceCache
        plain = SliceCache(EdgeStore(cache_store), budget_words=budget,
                           block_rows=block_rows)
        shared = SharedSliceCache(EdgeStore(cache_store),
                                  budget_words=budget,
                                  block_rows=block_rows)
        view = shared.register("q0", floor_words=0)
        for lo, hi in windows:
            ip_p, v_p = plain.read_rows(lo, hi)
            ip_s, v_s = view.read_rows(lo, hi)
            np.testing.assert_array_equal(v_s, v_p)
            np.testing.assert_array_equal(ip_s, ip_p)
        assert list(shared._blocks) == list(plain._blocks)
        assert (shared.hits, shared.misses) == (plain.hits, plain.misses)
        assert (shared.hit_words, shared.miss_words) == \
            (plain.hit_words, plain.miss_words)
        assert shared._words == plain._words

    @settings(max_examples=10, deadline=None)
    @given(accesses=_tenant_windows_strategy(NV),
           block_rows=st.integers(2, 16))
    def test_reads_are_correct_and_unregister_frees_floor(
            self, cache_store, accesses, block_rows):
        """Every attributed read returns exactly what the store returns,
        and unregistering a tenant releases its floor (a replacement
        tenant registers at the same floor) while its blocks stay warm."""
        from repro.serve import SharedSliceCache
        store = EdgeStore(cache_store)
        cache = SharedSliceCache(EdgeStore(cache_store),
                                 budget_words=1024,
                                 block_rows=block_rows)
        views = {t: cache.register(t, floor_words=512) for t in range(2)}
        with pytest.raises(ValueError, match="oversubscribe"):
            cache.register(9, floor_words=512)
        for t, lo, hi in accesses:
            ip_c, v_c = views[t % 2].read_rows(lo, hi)
            ip_r, v_r = store.read_rows(lo, hi)
            np.testing.assert_array_equal(v_c, v_r)
            np.testing.assert_array_equal(ip_c, ip_r)
        resident = set(cache._blocks)
        cache.unregister(0)
        assert set(cache._blocks) == resident     # stays warm
        view9 = cache.register(9, floor_words=512)  # floor freed
        if resident:
            bid = next(iter(resident))
            br = cache.block_rows
            view9.read_rows(bid * br, bid * br + br - 1)
            assert cache.cross_hits > 0 or cache.tenant_stats(9).hits > 0 \
                or cache.tenant_stats(9).misses > 0


# ---------------------------------------------------------------------------
# reader format checks fail loudly (docs/EDGESTORE_FORMAT.md contract)
# ---------------------------------------------------------------------------

class TestFormatChecks:
    def test_version_mismatch_fails_loudly(self, tmp_path):
        src, dst = er_graph(32, 0.3, seed=0)
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        with open(path, "r+b") as f:
            f.seek(8)                         # version field (after magic)
            f.write((99).to_bytes(4, "little"))
        with pytest.raises(ValueError, match="version 99"):
            EdgeStore(path)

    def test_truncated_header_fails(self, tmp_path):
        p = tmp_path / "short.csr"
        p.write_bytes(b"RPRCSR01")            # magic only, header cut off
        with pytest.raises(ValueError, match="truncated header"):
            EdgeStore(p)

    def test_truncated_indices_fails(self, tmp_path):
        src, dst = er_graph(32, 0.3, seed=0)
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size - 64)
        with pytest.raises(ValueError, match="truncated indices"):
            EdgeStore(path)

    def test_corrupt_header_fails(self, tmp_path):
        src, dst = er_graph(32, 0.3, seed=0)
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        with open(path, "r+b") as f:
            f.seek(16)                        # n_nodes field
            f.write((-5).to_bytes(8, "little", signed=True))
        with pytest.raises(ValueError, match="corrupt header"):
            EdgeStore(path)
