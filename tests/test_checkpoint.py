"""Checkpoint manager: roundtrip, atomicity, keep-k, resume."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32),
                       "c": [jnp.ones((2, 2)), jnp.zeros((5,))]}}


def trees_equal(x, y):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(x),
                               jax.tree_util.tree_leaves(y)))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_save=False)
        t = tree(1)
        mgr.save(5, t)
        got, step = mgr.restore(tree(2))
        assert step == 5
        assert trees_equal(got, t)

    def test_async_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_save=True)
        t = tree(3)
        mgr.save(1, t)
        mgr.wait()
        got, _ = mgr.restore(tree(4))
        assert trees_equal(got, t)

    def test_keep_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_latest_and_resume(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(10, tree(1))
        mgr.save(20, tree(2))
        got, step = mgr.restore(tree(0))
        assert step == 20
        assert trees_equal(got, tree(2))
        got, step = mgr.restore(tree(0), step=10)
        assert trees_equal(got, tree(1))

    def test_partial_save_ignored(self, tmp_path):
        """A crashed save (leftover .tmp dir, or dir without manifest) must
        never be restored."""
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(1, tree(1))
        # simulate a crash mid-save at a later step
        crashed = Path(tmp_path) / "step_0000000009.tmp"
        crashed.mkdir()
        (crashed / "arrays.npz").write_bytes(b"garbage")
        half = Path(tmp_path) / "step_0000000008"
        half.mkdir()
        assert mgr.latest_step() == 1
        got, step = mgr.restore(tree(0))
        assert step == 1

    def test_extra_metadata(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(7, tree(1), extra={"loss": 1.5})
        man = json.loads(
            (Path(tmp_path) / "step_0000000007" / "manifest.json").read_text())
        assert man["extra"]["loss"] == 1.5
        assert man["step"] == 7

    def test_train_resume_equivalence(self, tmp_path):
        """Training N steps == training k, restoring, training N-k (exact
        state recovery: params + opt moments + step count)."""
        from repro.optim import adamw
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
        y = x @ jnp.asarray([[1.], [2.], [-1.], [0.5]])
        cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=20)

        def loss(p):
            return jnp.mean((x @ p["w"] - y) ** 2)

        @jax.jit
        def step(p, o):
            g = jax.grad(loss)(p)
            return adamw.apply(cfg, p, g, o)[:2]

        p = {"w": jnp.zeros((4, 1))}
        o = adamw.init(p)
        for _ in range(10):
            p, o = step(p, o)
        ref = np.asarray(p["w"])

        p2 = {"w": jnp.zeros((4, 1))}
        o2 = adamw.init(p2)
        mgr = CheckpointManager(tmp_path, async_save=False)
        for _ in range(4):
            p2, o2 = step(p2, o2)
        mgr.save(4, (p2, o2))
        (p3, o3), _ = mgr.restore((p2, o2))
        for _ in range(6):
            p3, o3 = step(p3, o3)
        np.testing.assert_allclose(np.asarray(p3["w"]), ref, rtol=1e-5)
