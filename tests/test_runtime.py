"""Fault tolerance: elastic re-meshing, straggler watchdog, box scheduler."""

import numpy as np
import pytest

from repro.runtime.elastic import (DevicePool, ElasticState, MeshPlan,
                                   accum_steps_for, plan_mesh)
from repro.runtime.straggler import (BoxScheduler, StepTimeWatchdog,
                                     fail_worker)


class TestElastic:
    def test_plan_preserves_model_axis(self):
        plan = plan_mesh(256, model_parallel=16)
        assert plan.model == 16 and plan.data == 16

    def test_plan_after_failure_shrinks_pow2(self):
        plan = plan_mesh(255, model_parallel=16)
        assert plan.model == 16 and plan.data == 8   # 255//16=15 -> pow2 8

    def test_infeasible(self):
        assert plan_mesh(8, model_parallel=16) is None

    def test_failure_recovery_cycle(self):
        pool = DevicePool(n_hosts=64, devices_per_host=4)   # 256 devices
        st = ElasticState(pool, model_parallel=16, global_batch=256)
        assert st.plan.data == 16
        st.on_failure(3)
        assert st.plan.data == 8          # 252 alive -> 15 -> pow2 8
        assert st.generation == 1
        st.on_recovery(3)
        assert st.plan.data == 16

    def test_global_batch_invariance(self):
        """Elastic semantics: dp-size changes rescale accumulation, the
        global batch never changes."""
        for n_data in (16, 8, 4):
            plan = MeshPlan(data=n_data, model=16)
            acc = accum_steps_for(256, plan, per_device_batch=2)
            assert acc * plan.data * 2 >= 256
            assert (acc - 1) * plan.data * 2 < 256


class TestWatchdog:
    def test_flags_outlier(self):
        wd = StepTimeWatchdog(min_samples=4, threshold=2.0)
        flags = [wd.record(1.0) for _ in range(8)]
        assert not any(flags)
        assert wd.record(5.0) is True
        assert wd.record(1.0) is False

    def test_adapts_to_drift(self):
        wd = StepTimeWatchdog(window=8, min_samples=4, threshold=2.5)
        for t in np.linspace(1.0, 2.0, 16):
            wd.record(float(t))   # slow drift should not flag
        assert len(wd.flagged) == 0


class TestBoxScheduler:
    def test_all_boxes_complete(self):
        sched = BoxScheduler(range(20), n_workers=4)
        while not sched.all_done():
            for w in range(4):
                t = sched.next_for(w, now=0.0)
                if t:
                    sched.complete(w, t.box_id, t.payload * 2)
        assert sched.results() == [i * 2 for i in range(20)]

    def test_worker_failure_requeues(self):
        sched = BoxScheduler(range(6), n_workers=2)
        t0 = sched.next_for(0, now=0.0)
        t1 = sched.next_for(0, now=0.0)
        n = fail_worker(sched, 0)
        assert n == 2
        # worker 1 finishes everything, including the re-queued boxes
        while not sched.all_done():
            t = sched.next_for(1, now=0.0)
            assert t is not None
            sched.complete(1, t.box_id, 0)
        assert sched.all_done()

    def test_steal_from_straggler(self):
        sched = BoxScheduler(range(2), n_workers=2, steal_after_s=10.0)
        t0 = sched.next_for(0, now=0.0)     # worker 0 takes box, stalls
        t1 = sched.next_for(1, now=0.0)
        sched.complete(1, t1.box_id, "r1")
        # before timeout: nothing to steal
        assert sched.next_for(1, now=5.0) is None
        # after timeout: worker 1 steals worker 0's box
        stolen = sched.next_for(1, now=20.0)
        assert stolen is not None and stolen.box_id == t0.box_id
        assert sched.duplicates == 1
        assert sched.complete(1, stolen.box_id, "r-stolen") is True
        # the straggler finally finishes: idempotent, first result kept
        assert sched.complete(0, t0.box_id, "r-late") is False
        assert sched.tasks[t0.box_id].result == "r-stolen"
