"""TrieArray structure: build, enumerate, slice, probe (unit + property)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SPILL, TrieArray


def rows(draw_arity=2, max_val=20, max_rows=60):
    return st.lists(
        st.tuples(*[st.integers(0, max_val)] * draw_arity),
        min_size=0, max_size=max_rows)


def canon(tuples, arity):
    if not tuples:
        return np.zeros((0, arity), dtype=np.int64)
    return np.unique(np.asarray(sorted(tuples), dtype=np.int64), axis=0)


class TestBuild:
    def test_paper_figure1(self):
        # ternary relation of paper Fig. 1
        tuples = [(a, b, c) for a, bs in
                  [(1, [(1, [3, 4, 5])]),
                   (2, [(1, [1]), (3, [8, 9])])]
                  for (b, cs) in bs for c in cs]
        ta = TrieArray.from_tuples(np.asarray(tuples))
        assert ta.arity == 3
        np.testing.assert_array_equal(ta.val[0], [1, 2])
        np.testing.assert_array_equal(ta.val[1], [1, 1, 3])
        np.testing.assert_array_equal(ta.val[2], [3, 4, 5, 1, 8, 9])
        np.testing.assert_array_equal(ta.to_tuples(), np.asarray(tuples))

    def test_empty(self):
        ta = TrieArray.from_tuples(np.zeros((0, 2), dtype=np.int64))
        assert ta.n_tuples() == 0
        assert ta.to_tuples().shape == (0, 2)

    @settings(max_examples=30, deadline=None)
    @given(rows(2))
    def test_roundtrip_binary(self, tuples):
        want = canon(tuples, 2)
        ta = TrieArray.from_tuples(want.reshape(-1, 2))
        got = ta.to_tuples()
        np.testing.assert_array_equal(got, want.reshape(-1, 2))

    @settings(max_examples=20, deadline=None)
    @given(rows(3, max_val=8, max_rows=40))
    def test_roundtrip_ternary(self, tuples):
        want = canon(tuples, 3)
        ta = TrieArray.from_tuples(want.reshape(-1, 3))
        np.testing.assert_array_equal(ta.to_tuples(), want.reshape(-1, 3))

    def test_words_linear(self):
        rng = np.random.default_rng(0)
        t = rng.integers(0, 50, (200, 2))
        ta = TrieArray.from_tuples(t)
        # words <= values + index overhead (Prop. 3: O(|R|))
        assert ta.words() <= 3 * ta.n_tuples() + len(ta.val[0]) + 2


class TestSlice:
    @settings(max_examples=30, deadline=None)
    @given(rows(2), st.integers(0, 20), st.integers(0, 20))
    def test_slice_semantics(self, tuples, l, h):
        """Def. 6: slice == { t | l <= t[0] <= h }."""
        want_all = canon(tuples, 2).reshape(-1, 2)
        ta = TrieArray.from_tuples(want_all)
        s = ta.make_slice((), l, h)
        want = want_all[(want_all[:, 0] >= l) & (want_all[:, 0] <= h)]
        np.testing.assert_array_equal(s.to_tuples(), want)
        assert s.words_loaded == s.words() or len(want) == 0

    @settings(max_examples=20, deadline=None)
    @given(rows(3, max_val=6, max_rows=40), st.integers(0, 6),
           st.integers(0, 6), st.integers(0, 6))
    def test_slice_level1(self, tuples, pre, l, h):
        """Slice at level 1 with prefix (pre,)."""
        want_all = canon(tuples, 3).reshape(-1, 3)
        ta = TrieArray.from_tuples(want_all)
        s = ta.make_slice((pre,), l, h)
        want = want_all[(want_all[:, 0] == pre) &
                        (want_all[:, 1] >= l) & (want_all[:, 1] <= h)][:, 1:]
        np.testing.assert_array_equal(s.to_tuples(), want)

    def test_nested_slice(self):
        """Slices of slices re-base index offsets correctly."""
        rng = np.random.default_rng(1)
        t = np.unique(rng.integers(0, 12, (80, 2)), axis=0)
        ta = TrieArray.from_tuples(t)
        s1 = ta.make_slice((), 2, 9)
        s2 = s1.make_slice((), 4, 7)
        want = t[(t[:, 0] >= 4) & (t[:, 0] <= 7)]
        np.testing.assert_array_equal(s2.to_tuples(), want)


class TestProbe:
    @settings(max_examples=30, deadline=None)
    @given(rows(2, max_val=15, max_rows=50), st.integers(0, 15),
           st.integers(2, 60))
    def test_probe_maximality(self, tuples, l, budget):
        """Prop. 8: probe returns the max h whose slice fits the budget."""
        want = canon(tuples, 2).reshape(-1, 2)
        ta = TrieArray.from_tuples(want)
        res, w = ta.probe((), l, budget)
        vals = np.unique(want[want[:, 0] >= l][:, 0])
        if len(vals) == 0:
            assert res == np.inf
            return
        if res == SPILL:
            assert ta.slice_words((), vals[0], vals[0]) > budget
            return
        assert w <= budget
        if res != np.inf:
            assert ta.slice_words((), vals[0], int(res)) <= budget
            nxt = vals[vals > res]
            if len(nxt):
                assert ta.slice_words((), vals[0], int(nxt[0])) > budget
        else:
            assert ta.slice_words((), vals[0], int(vals[-1])) <= budget
