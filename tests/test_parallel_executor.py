"""Async multi-worker box scheduler: every parallel path pinned to the
``workers=1`` sequential oracle.

Headline acceptance (ISSUE 4): for random graphs x orientations x
``workers ∈ {1,2,4,8}`` x cache on/off, the parallel count and the sorted
listing output are byte-identical to the ``workers=1`` run, and the
measured ``IOStats.read_words`` never exceeds the serial run's. On top of
the equivalence properties, the suite stress-tests the failure paths (a
worker raising mid-queue propagates, cancels the remaining boxes and leaks
no threads) and the scheduler's budget/telemetry contracts (in-flight
window bounds, utilization in [0, 1], deterministic reduction).

The CI ``parallel`` job runs this file with ``REPRO_TEST_WORKERS=4``,
which pins the non-hypothesis smoke tests to that worker count.
"""

import os
import tempfile
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import ThreadGuard
from repro.core import StreamingExecutor, TriangleEngine, TrieArray, \
    lftj_triangle_count, orient_edges
from repro.core.lftj_jax import csr_from_edges
from repro.data.edgestore import InMemoryEdgeSource, write_edge_store
from repro.data.graphs import rmat_graph
from repro.parallel.sharding import balanced_box_schedule, lpt_order

WORKER_COUNTS = (1, 2, 4, 8)
ENV_WORKERS = int(os.environ.get("REPRO_TEST_WORKERS", "4"))


def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def make_graph(kind, seed):
    if kind == "er":
        return er_graph(72, 0.16, seed % 1000)
    return rmat_graph(128, 1400, seed=seed % 1000)


def reference(src, dst, orientation="minmax"):
    out = []
    a, b = orient_edges(src, dst, orientation)
    n = lftj_triangle_count(TrieArray.from_edges(a, b), emit=out.append)
    tris = np.sort(np.asarray(out, np.int64).reshape(-1, 3), axis=1)
    return n, tris[np.lexsort((tris[:, 2], tris[:, 1], tris[:, 0]))]


def in_memory_source(src, dst):
    a, b = orient_edges(src, dst)
    nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
    ip, idx = csr_from_edges(a, b, n_nodes=nv)
    return InMemoryEdgeSource(ip, idx)


# ---------------------------------------------------------------------------
# property: parallel == sequential oracle (count, listing, I/O ledger)
# ---------------------------------------------------------------------------

class TestParallelOracleEquivalence:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000),
           st.sampled_from(WORKER_COUNTS),
           st.booleans(),
           st.sampled_from(["minmax", "degree"]),
           st.sampled_from(["er", "rmat"]))
    def test_store_backed_matches_oracle(self, seed, workers, cached,
                                         orientation, kind):
        src, dst = make_graph(kind, seed)
        cache_words = 2048 if cached else 0
        with tempfile.TemporaryDirectory() as td:
            path = write_edge_store(os.path.join(td, "g.csr"), src, dst,
                                    orientation=orientation,
                                    chunk_rows=32, align_words=16)

            def run(w):
                eng = TriangleEngine(store=path, mem_words=200,
                                     io_block_words=64,
                                     cache_words=cache_words, workers=w)
                n = eng.count()
                words_count = eng.stats.word_reads
                tris = eng.list()
                return n, tris, words_count, eng.stats.word_reads

            n1, t1, wc1, wl1 = run(1)
            want_n, want_t = reference(src, dst, orientation)
            assert n1 == want_n
            np.testing.assert_array_equal(t1, want_t)
            nw, tw, wcw, wlw = run(workers)
            assert nw == n1, (workers, cached, orientation)
            np.testing.assert_array_equal(tw, t1)
            # the read ledger of the parallel run never exceeds serial —
            # and for store-backed (charged) sources the queue runs in
            # plan order with serialized fetches, so the measured I/O is
            # *identical*, cache on or off
            assert wcw == wc1, (workers, cached)
            assert wlw == wl1, (workers, cached)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from(WORKER_COUNTS),
           st.sampled_from(["auto", "host", "binary"]))
    def test_in_memory_matches_oracle(self, seed, workers, backend):
        src, dst = make_graph("rmat", seed)
        want = TriangleEngine(src, dst, mem_words=250).count()
        eng = TriangleEngine(src, dst, mem_words=250, workers=workers,
                             backend=backend)
        assert eng.count() == want, (workers, backend)

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from((2, 4, 8)))
    def test_parallel_run_is_deterministic(self, seed, workers):
        """Fixed box-order reduction: two runs of the same parallel config
        agree exactly (no arrival-order nondeterminism)."""
        src, dst = make_graph("er", seed)
        eng = TriangleEngine(src, dst, mem_words=150, workers=workers)
        n_a, t_a = eng.count(), eng.list()
        n_b, t_b = eng.count(), eng.list()
        assert n_a == n_b
        np.testing.assert_array_equal(t_a, t_b)


# ---------------------------------------------------------------------------
# scheduler contracts: in-flight window, telemetry, LPT priority order
# ---------------------------------------------------------------------------

class TestSchedulerContracts:
    def test_inflight_window_bounds_resident_words(self):
        src, dst = rmat_graph(512, 6000, seed=5)
        mem = 400
        with tempfile.TemporaryDirectory() as td:
            path = write_edge_store(os.path.join(td, "g.csr"), src, dst,
                                    chunk_rows=64, align_words=32)
            eng = TriangleEngine(store=path, mem_words=mem,
                                 io_block_words=64,
                                 workers=ENV_WORKERS, inflight_boxes=3)
            n = eng.count()
            assert n == TriangleEngine(src, dst).count()
            s = eng.stats
            assert 1 <= s.max_inflight_boxes <= 3
            # each resident slice is bounded by the planner budget except
            # pinned spill rows, which may exceed it alone
            a, b = orient_edges(src, dst)
            ip, _ = csr_from_edges(a, b)
            spill = 2 * (int(np.diff(ip).max()) + 2)
            assert s.max_inflight_words <= 3 * max(mem, spill)

    def test_scheduler_telemetry_sane(self):
        src, dst = rmat_graph(256, 3000, seed=2)
        eng = TriangleEngine(src, dst, mem_words=200, workers=ENV_WORKERS)
        want = TriangleEngine(src, dst, mem_words=200).count()
        assert eng.count() == want
        s = eng.stats
        # the pool is clamped to the hardware parallelism — extra runnable
        # threads beyond the cores measurably thrash
        assert s.n_workers == max(
            1, min(ENV_WORKERS, os.cpu_count() or ENV_WORKERS))
        assert s.inflight_boxes >= 2
        assert s.queue_wait_s >= 0.0 and s.overlap_s >= 0.0
        assert s.build_s > 0.0 and s.compute_s > 0.0
        assert 0.0 < s.worker_utilization <= 1.01

    def test_serial_run_reports_no_parallel_telemetry(self):
        src, dst = rmat_graph(128, 1200, seed=0)
        eng = TriangleEngine(src, dst, mem_words=200)
        eng.count()
        assert eng.stats.n_workers == 1
        assert eng.stats.max_inflight_boxes == 0

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(0, 1000), min_size=0, max_size=40),
           st.integers(1, 8))
    def test_lpt_order_shared_by_queue_and_schedule(self, costs, n_shards):
        order = lpt_order(costs)
        assert sorted(order) == list(range(len(costs)))
        ordered = [costs[i] for i in order]
        assert ordered == sorted(costs, reverse=True)
        # ties broken by index: deterministic priority order
        for a, b in zip(order, order[1:]):
            if costs[a] == costs[b]:
                assert a < b
        # the shard schedule consumes the same order: its first assignments
        # are the heaviest boxes, one per idle shard
        schedule = balanced_box_schedule(costs, n_shards)
        assert sorted(i for s in schedule for i in s) \
            == list(range(len(costs)))
        heads = [s[0] for s in schedule if s]
        assert heads == order[:len(heads)]

    def test_sharded_engine_consumes_queue_for_heavy_boxes(self):
        """The shard_map path's local dense/pallas boxes run through the
        same async queue when workers > 1 — counts unchanged."""
        src, dst = rmat_graph(256, 3000, seed=7)
        want = TriangleEngine(src, dst, mem_words=400).count()
        eng = TriangleEngine(src, dst, mem_words=400, shard=True,
                             workers=ENV_WORKERS)
        assert eng.count() == want


# ---------------------------------------------------------------------------
# stress/fault: worker exceptions cancel, propagate, and leak nothing
# ---------------------------------------------------------------------------

class TestWorkerFaults:
    def _boxes_and_source(self, nv=256, ne=3000, n_boxes=16):
        src, dst = rmat_graph(nv, ne, seed=0)
        source = in_memory_source(src, dst)
        step = -(-source.n_nodes // n_boxes)
        return [(i * step, min((i + 1) * step - 1, source.n_nodes - 1),
                 0, source.n_nodes - 1) for i in range(n_boxes)], source

    def test_backend_exception_propagates_and_cancels(self):
        boxes, source = self._boxes_and_source()
        calls = []

        def bad_backend(n_edges, wx, wy):
            calls.append(1)
            if len(calls) == 3:
                raise RuntimeError("backend exploded")
            return "host"

        guard = ThreadGuard()
        ex = StreamingExecutor(source, pick_backend=bad_backend,
                               workers=ENV_WORKERS)
        with pytest.raises(RuntimeError, match="backend exploded"):
            ex.run_count(boxes)
        guard.assert_clean(timeout=5)                # no leaked workers
        assert len(calls) < len(boxes)               # remaining cancelled

    def test_source_read_exception_propagates(self):
        boxes, source = self._boxes_and_source()

        class FlakySource(InMemoryEdgeSource):
            reads = 0

            def read_rows(self, lo, hi):
                FlakySource.reads += 1
                if FlakySource.reads > 5:
                    raise OSError("disk on fire")
                return super().read_rows(lo, hi)

        flaky = FlakySource(source.indptr, source.indices)
        guard = ThreadGuard()
        ex = StreamingExecutor(flaky, pick_backend=lambda *a: "host",
                               workers=ENV_WORKERS)
        with pytest.raises(OSError, match="disk on fire"):
            ex.run_count(boxes)
        guard.assert_clean(timeout=5)

    def test_listing_exception_propagates(self):
        boxes, source = self._boxes_and_source()

        class Boom(InMemoryEdgeSource):
            reads = 0

            def read_rows(self, lo, hi):
                Boom.reads += 1
                if Boom.reads > 8:
                    raise ValueError("bad sector")
                return super().read_rows(lo, hi)

        ex = StreamingExecutor(Boom(source.indptr, source.indices),
                               pick_backend=lambda *a: "binary",
                               workers=ENV_WORKERS)
        with pytest.raises(ValueError, match="bad sector"):
            ex.run_list(boxes)


# ---------------------------------------------------------------------------
# host (pure numpy) backend: the GIL-releasing lane workers scale with
# ---------------------------------------------------------------------------

class TestHostBackend:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from(["er", "rmat"]))
    def test_host_backend_matches_reference(self, seed, kind):
        src, dst = make_graph(kind, seed)
        want, _ = reference(src, dst)
        for w in (1, ENV_WORKERS):
            eng = TriangleEngine(src, dst, mem_words=200, backend="host",
                                 workers=w)
            assert eng.count() == want, (seed, kind, w)
            assert eng.stats.n_host_boxes > 0

    def test_host_backend_on_store(self):
        src, dst = rmat_graph(256, 3000, seed=4)
        want, _ = reference(src, dst)
        with tempfile.TemporaryDirectory() as td:
            path = write_edge_store(os.path.join(td, "g.csr"), src, dst)
            eng = TriangleEngine(store=path, mem_words=300, backend="host",
                                 workers=ENV_WORKERS)
            assert eng.count() == want
