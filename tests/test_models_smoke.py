"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, assert output shapes + no
NaNs; LM archs additionally exercise prefill + decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_arch

LM_ARCHS = ["qwen2-7b", "yi-6b", "qwen1.5-32b", "deepseek-v2-236b",
            "llama4-maverick-400b-a17b"]
GNN_ARCHS = ["gcn-cora", "gin-tu", "schnet", "graphcast"]


def tree_no_nan(tree) -> bool:
    return not any(bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))
                   for x in jax.tree_util.tree_leaves(tree)
                   if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                             jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestLMSmoke:
    def test_train_step(self, arch):
        from repro.models import transformer as M
        from repro.optim import adamw
        cfg = get_arch(arch).smoke_config
        rng = jax.random.PRNGKey(0)
        params = M.init_params(cfg, rng)
        toks = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "targets": toks}
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        assert loss.shape == ()
        assert float(loss) > 0 and not bool(jnp.isnan(loss))
        assert tree_no_nan(grads)
        opt = adamw.init(params)
        p2, opt2, om = adamw.apply(adamw.AdamWConfig(), params, grads, opt)
        assert tree_no_nan(p2)
        # params actually moved
        d = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(p2)))
        assert d > 0

    def test_prefill_decode(self, arch):
        from repro.models import transformer as M
        cfg = get_arch(arch).smoke_config
        rng = jax.random.PRNGKey(1)
        params = M.init_params(cfg, rng)
        toks = jax.random.randint(rng, (2, 12), 0, cfg.vocab)
        cache, logits = M.prefill(cfg, params, toks, max_len=16)
        assert logits.shape == (2, cfg.vocab)
        logits2, cache = M.decode_step(cfg, params, cache, toks[:, :1],
                                       jnp.int32(12))
        assert logits2.shape == (2, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits2)))

    def test_decode_consistency_with_forward(self, arch):
        """Greedy decode after prefill matches teacher-forced forward."""
        from repro.models import transformer as M
        cfg = get_arch(arch).smoke_config
        rng = jax.random.PRNGKey(2)
        params = M.init_params(cfg, rng)
        toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab)
        full_logits, _ = M.forward(cfg, params, toks)
        cache, last = M.prefill(cfg, params, toks[:, :-1], max_len=8)
        dec, _ = M.decode_step(cfg, params, cache, toks[:, -1:],
                               jnp.int32(7))
        # prefill's last-token logits == forward logits at position -2
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(full_logits[:, -2, :]),
                                   rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("arch", GNN_ARCHS)
class TestGNNSmoke:
    def _batch(self, d_in, n=48, e=160, with_labels=True, seed=0):
        rng = np.random.default_rng(seed)
        batch = {
            "node_feat": rng.standard_normal((n, d_in)).astype(np.float32),
            "edge_src": rng.integers(0, n, e).astype(np.int32),
            "edge_dst": rng.integers(0, n, e).astype(np.int32),
            "edge_mask": np.ones(e, np.float32),
            "node_mask": np.ones(n, np.float32),
        }
        if with_labels:
            batch["labels"] = rng.integers(0, 3, n).astype(np.int32)
            batch["label_mask"] = np.ones(n, np.float32)
        else:
            batch["pos"] = rng.standard_normal((n, 3)).astype(np.float32)
            batch["graph_id"] = np.zeros(n, np.int32)
            batch["targets"] = rng.standard_normal((n, 1)).astype(np.float32)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def test_classification_step(self, arch):
        from repro.models import gnn as M
        cfg = dataclasses.replace(get_arch(arch).smoke_config, d_in=12, d_out=3)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = self._batch(12)
        out = M.forward(cfg, params, batch)
        assert out.shape == (48, 3)
        loss, _ = M.loss_fn(cfg, params, batch)
        grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
        assert not bool(jnp.isnan(loss)) and tree_no_nan(grads)

    def test_regression_step(self, arch):
        from repro.models import gnn as M
        cfg = dataclasses.replace(get_arch(arch).smoke_config, d_in=12, d_out=1)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = self._batch(12, with_labels=False)
        loss, _ = M.loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss))

    def test_edge_mask_zeroes_messages(self, arch):
        """Masked edges must not affect outputs (padding correctness)."""
        from repro.models import gnn as M
        cfg = dataclasses.replace(get_arch(arch).smoke_config, d_in=6, d_out=2)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        b1 = self._batch(6, n=32, e=64, seed=3)
        # add garbage edges with mask 0
        b2 = dict(b1)
        rng = np.random.default_rng(9)
        extra = 32
        b2["edge_src"] = jnp.concatenate(
            [b1["edge_src"], jnp.asarray(rng.integers(0, 32, extra), jnp.int32)])
        b2["edge_dst"] = jnp.concatenate(
            [b1["edge_dst"], jnp.asarray(rng.integers(0, 32, extra), jnp.int32)])
        b2["edge_mask"] = jnp.concatenate(
            [b1["edge_mask"], jnp.zeros(extra, jnp.float32)])
        o1 = M.forward(cfg, params, b1)
        o2 = M.forward(cfg, params, b2)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)


class TestDLRMSmoke:
    def _batch(self, cfg, b=16, seed=0):
        rng = np.random.default_rng(seed)
        return {
            "dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)),
                                 jnp.float32),
            "sparse": jnp.asarray(
                rng.integers(0, 5, (b, cfg.n_sparse, cfg.hot)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
        }

    def test_train_step(self):
        from repro.models import dlrm as M
        from repro.optim import adamw
        cfg = get_arch("dlrm-mlperf").smoke_config
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = self._batch(cfg)
        loss, _ = M.loss_fn(cfg, params, batch)
        grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
        assert 0 < float(loss) < 20 and tree_no_nan(grads)

    def test_serve_and_retrieval(self):
        from repro.models import dlrm as M
        cfg = get_arch("dlrm-mlperf").smoke_config
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = self._batch(cfg, b=4)
        scores = M.serve_step(cfg, params, batch)
        assert scores.shape == (4,)
        assert bool(jnp.all((scores >= 0) & (scores <= 1)))
        q = {k: v[:1] for k, v in batch.items()}
        q["candidates"] = jnp.asarray(
            np.random.default_rng(1).standard_normal((300, cfg.embed_dim)),
            jnp.float32)
        ts, ti = M.retrieval_score(cfg, params, q)
        assert ti.shape == (1, 100)
        # returned scores are the true top-k
        assert bool(jnp.all(jnp.diff(ts[0]) <= 1e-6))


def test_all_archs_registered():
    assert len(all_arch_ids()) == 10
    for aid in all_arch_ids():
        b = get_arch(aid)
        assert len(b.shapes) == 4
        assert b.smoke_config is not None
