"""Optimizer + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim import compression as C


class TestAdamW:
    def test_converges_quadratic(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
        target = jnp.asarray([[1.0], [-2.0], [0.5], [3.0]])
        y = a @ target
        cfg = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=5,
                                total_steps=400)
        p = {"w": jnp.zeros((4, 1))}
        o = adamw.init(p)

        @jax.jit
        def step(p, o):
            g = jax.grad(lambda p: jnp.mean((a @ p["w"] - y) ** 2))(p)
            return adamw.apply(cfg, p, g, o)

        for _ in range(400):
            p, o, m = step(p, o)
        np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                                   atol=0.05)

    def test_clip_global_norm(self):
        g = {"a": jnp.full((10,), 100.0), "b": jnp.full((10,), -100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) > 400
        cn = adamw.global_norm(clipped)
        np.testing.assert_allclose(float(cn), 1.0, rtol=1e-4)

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
        assert lrs[-1] == pytest.approx(0.1, rel=1e-3)  # floor
        assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))

    def test_bf16_params_updated_via_f32(self):
        p = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
        g = {"w": jnp.full((4, 4), 1e-3, jnp.bfloat16)}
        o = adamw.init(p)
        assert o.m["w"].dtype == jnp.float32
        p2, o2, _ = adamw.apply(adamw.AdamWConfig(clip_norm=1e9), p, g, o)
        assert p2["w"].dtype == jnp.bfloat16
        assert float(jnp.sum(jnp.abs(p2["w"].astype(jnp.float32)))) > 0


class TestCompression:
    def test_bf16_roundtrip_small_error(self):
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
        back = C.decompress_bf16(C.compress_bf16(g))
        err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
        assert err < 0.02

    def test_int8_error_feedback_accumulates(self):
        """EF property: the same gradient applied repeatedly loses nothing
        on average — residuals carry the rounding error forward."""
        rng = np.random.default_rng(2)
        g = {"w": jnp.asarray(rng.standard_normal((32, 32)) * 1e-3,
                              jnp.float32)}
        ef = C.init_error_feedback(g)
        total = jnp.zeros_like(g["w"])
        n = 50
        for _ in range(n):
            packed, ef = C.compress_int8_ef(g, ef)
            total = total + C.decompress_int8(packed)["w"]
        # mean transmitted ~= mean true gradient (error feedback closes gap)
        np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                                   atol=2e-5)

    def test_int8_single_shot_bounded_error(self):
        g = {"w": jnp.asarray(np.linspace(-1, 1, 256), jnp.float32)}
        ef = C.init_error_feedback(g)
        packed, ef2 = C.compress_int8_ef(g, ef)
        back = C.decompress_int8(packed)
        err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
        assert err <= 1.0 / 127.0 + 1e-6
        # residual equals the quantization error
        np.testing.assert_allclose(np.asarray(ef2["w"]),
                                   np.asarray(g["w"] - back["w"]), atol=1e-7)
