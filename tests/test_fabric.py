"""Distributed box fabric (PR 9): every mesh/sharding path pinned to the
single-host ``QueryEngine`` oracle.

The fabric's contract is that distribution changes WHERE boxes run, never
what they compute or what I/O they are charged: per mesh shape x pattern,
the distributed count/listing must be byte-identical to the single-host
engine, the per-shard ``BlockDevice`` ledgers must be byte-identical to a
solo engine running the same restricted plan over the full data
(``Fabric.oracle_engine``), and each shard's measured block reads must sit
inside the Thm. 13 envelope at its local budget. The CI ``fabric`` job
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=48``
plus true multi-process subprocess workers.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import ThreadGuard  # noqa: F401  (thread_guard fixture home)
from repro.core.lftj_jax import csr_from_edges, orient_edges
from repro.data.edgestore import InMemoryEdgeSource, write_edge_store
from repro.data.graphs import random_graph, rmat_graph
from repro.launch.mesh import fabric_mesh, resolve_fabric_shards
from repro.parallel.fabric import (Fabric, FabricShippingError,
                                   ShippedEdgeSource)
from repro.query.executor import QueryEngine
from repro.query.patterns import PATTERNS
from repro.query.planner import thm13_io_bound

ENV_WORKERS = max(2, int(os.environ.get("REPRO_TEST_WORKERS", "4")))
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PATTERN_NAMES = ("triangle", "four_clique", "diamond", "path3")
MESH_SHAPES = (1, 2, 4, 8)

SMALL = random_graph(96, 400, seed=7)
GRAPH = rmat_graph(128, 600, seed=3)

_ORACLE = {}


def oracle(name, mode="count", graph=SMALL, mem_words=1 << 12):
    """Cached single-host QueryEngine result for the acceptance matrix."""
    key = (name, mode, id(graph), mem_words)
    if key not in _ORACLE:
        src, dst = graph
        eng = QueryEngine.from_graph(PATTERNS[name](), src, dst,
                                     mem_words=mem_words)
        _ORACLE[key] = eng.count() if mode == "count" else eng.list()
    return _ORACLE[key]


def small_fabric(name, shards, graph=SMALL, **kw):
    kw.setdefault("mem_words", 1 << 12)
    src, dst = graph
    return Fabric.from_graph(PATTERNS[name](), src, dst,
                             n_shards=shards, **kw)


def _sub_env(n_devices=None):
    env = dict(os.environ)
    if n_devices:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count"
                              f"={n_devices}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep \
        + env.get("PYTHONPATH", "")
    return env


@pytest.fixture(scope="module")
def store_path(tmp_path_factory):
    src, dst = GRAPH
    path = str(tmp_path_factory.mktemp("fabric") / "g.csr")
    write_edge_store(path, src, dst, orientation="minmax", chunk_rows=32)
    return path


# ---------------------------------------------------------------------------
# acceptance matrix: distributed results == single-host oracle
# ---------------------------------------------------------------------------

class TestFabricMatchesSingleHost:
    @pytest.mark.parametrize("shards", MESH_SHAPES)
    @pytest.mark.parametrize("pattern", PATTERN_NAMES)
    def test_count(self, pattern, shards):
        fab = small_fabric(pattern, shards)
        assert fab.count() == oracle(pattern)
        assert fab.stats.n_shards == shards
        # the schedule is an exact partition of the global box list
        lay = fab.layout()
        flat = sorted(b for ids in lay.schedule for b in ids)
        assert flat == list(range(len(lay.plan.boxes)))
        assert fab.stats.sum_block_reads == \
            sum(fab.stats.shard_block_reads)

    @pytest.mark.parametrize("shards", (1, 4, 8))
    @pytest.mark.parametrize("pattern", PATTERN_NAMES)
    def test_listing(self, pattern, shards):
        fab = small_fabric(pattern, shards)
        np.testing.assert_array_equal(fab.list(), oracle(pattern, "list"))

    @pytest.mark.parametrize("pattern", PATTERN_NAMES)
    def test_plan_identical_to_single_host(self, pattern):
        """The fabric plans on an ordinary full-source engine: its global
        plan is the single-host plan, and each shard's sub-plan is exactly
        the scheduled subset of it."""
        fab = small_fabric(pattern, 4)
        src, dst = SMALL
        solo = QueryEngine.from_graph(PATTERNS[pattern](), src, dst,
                                      mem_words=1 << 12)
        gp, sp = fab.layout().plan, solo.plan()
        assert gp.order == sp.order
        assert gp.rank == sp.rank
        assert gp.boxes == sp.boxes
        assert gp.lanes == sp.lanes
        for s in range(4):
            eng = fab.shard_engine(s)
            assert eng.plan().boxes == \
                [gp.boxes[i] for i in fab.layout().schedule[s]]

    def test_reduce_arg_validated(self):
        fab = small_fabric("triangle", 2)
        with pytest.raises(ValueError, match="reduce"):
            fab.count(reduce="bogus")


# ---------------------------------------------------------------------------
# per-shard ledger byte-identity vs the solo oracle engine
# ---------------------------------------------------------------------------

LEDGER_FIELDS = ("block_reads", "block_writes", "word_reads", "cache_hits",
                 "cache_misses", "cache_hit_words", "slice_words_read",
                 "n_results")

CONFIGS = {
    "mem": dict(store=False, cache_words=0, workers=1, skew="uniform"),
    "store": dict(store=True, cache_words=0, workers=1, skew="uniform"),
    "store_cache": dict(store=True, cache_words=1 << 10, workers=1,
                        skew="uniform"),
    "store_workers": dict(store=True, cache_words=0, workers=ENV_WORKERS,
                          skew="uniform"),
    "store_skew": dict(store=True, cache_words=0, workers=1,
                       skew="heavy_light"),
}


class TestShardLedgerByteIdentity:
    @pytest.mark.parametrize("cfg", list(CONFIGS))
    @pytest.mark.parametrize("pattern", ["triangle", "diamond"])
    def test_shard_equals_solo_oracle(self, pattern, cfg, store_path):
        """A shard over SHIPPED byte ranges and a solo engine over the
        FULL data, both restricted to the shard's boxes on fresh
        identically-configured devices, land on byte-identical ledgers —
        under stores, caches, multi-worker drains and skewed plans."""
        c = CONFIGS[cfg]
        kw = dict(mem_words=1 << 11, cache_words=c["cache_words"],
                  io_block_words=64, workers=c["workers"], skew=c["skew"])
        mode = "list" if cfg == "store" else "count"
        for shards in (2, 4):
            if c["store"]:
                fab = Fabric(PATTERNS[pattern](), store=store_path,
                             n_shards=shards, **kw)
            else:
                src, dst = GRAPH
                fab = Fabric.from_graph(PATTERNS[pattern](), src, dst,
                                        n_shards=shards, **kw)
            for s in range(shards):
                rep = fab.run_local(s, mode)
                orc = fab.oracle_engine(s)
                want = orc.run_boxes(mode)
                assert len(rep.results) == len(want)
                for got_r, want_r in zip(rep.results, want):
                    if want_r is None:
                        assert got_r is None
                    elif mode == "count":
                        assert int(got_r) == int(want_r)
                    else:
                        np.testing.assert_array_equal(got_r, want_r)
                for f in LEDGER_FIELDS:
                    assert getattr(rep.stats, f) == \
                        getattr(orc.stats, f), (cfg, pattern, shards, s, f)
                assert rep.io.block_reads == orc.device.stats.block_reads
                assert rep.io.word_reads == orc.device.stats.word_reads

    def test_summed_shard_reads_equal_solo_sum(self):
        """The fabric's aggregate block reads are exactly the sum of the
        per-shard solo envelopes — distribution adds no hidden I/O."""
        src, dst = GRAPH
        fab = Fabric.from_graph(PATTERNS["triangle"](), src, dst,
                                n_shards=4, mem_words=1 << 11,
                                io_block_words=64)
        fab.count()
        solo = 0
        for s in range(4):
            orc = fab.oracle_engine(s)
            orc.run_boxes("count")
            solo += orc.stats.block_reads
        assert fab.stats.sum_block_reads == solo


# ---------------------------------------------------------------------------
# Thm. 13 envelope at each shard's local budget
# ---------------------------------------------------------------------------

class TestThm13Envelope:
    @pytest.mark.parametrize("pattern", ["triangle", "diamond"])
    def test_per_shard_io_within_envelope(self, pattern):
        m, b = 1 << 11, 64
        src, dst = GRAPH
        fab = Fabric.from_graph(PATTERNS[pattern](), src, dst, n_shards=4,
                                mem_words=m, io_block_words=b)
        fab.count()
        rank = fab.layout().plan.rank
        for rep in fab.reports:
            if not rep.box_ids:
                continue
            inp = max(1, rep.shipped_words)
            # rank-r no-spill term + one scan of the shipped input
            bound = thm13_io_bound(inp, m, b, rank) + inp / b
            assert rep.stats.block_reads <= 12 * bound \
                + 8 * len(rep.box_ids) + 16, \
                (pattern, rep.shard, rep.stats.block_reads, bound)


# ---------------------------------------------------------------------------
# shipping safety: under-shipping is loud, never wrong
# ---------------------------------------------------------------------------

class TestShipping:
    def _base(self):
        src, dst = random_graph(64, 200, seed=1)
        a, b = orient_edges(src, dst)
        nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        ip, ix = csr_from_edges(a, b, n_nodes=nv)
        return InMemoryEdgeSource(ip, ix)

    def test_shipped_reads_match_base(self):
        base = self._base()
        s = ShippedEdgeSource(base, [(0, 9)])
        ip_got, vals_got = s.read_rows(0, 9)
        ip_want, vals_want = base.read_rows(0, 9)
        np.testing.assert_array_equal(ip_got, ip_want)
        np.testing.assert_array_equal(vals_got, vals_want)
        assert s.shipped_words == len(vals_want)

    def test_read_outside_shipped_ranges_raises(self):
        base = self._base()
        s = ShippedEdgeSource(base, [(0, 5)])
        with pytest.raises(FabricShippingError):
            s.read_rows(3, 10)

    def test_gap_between_shipped_ranges_raises(self):
        base = self._base()
        s = ShippedEdgeSource(base, [(0, 3), (8, 9)])
        with pytest.raises(FabricShippingError):
            s.read_rows(2, 9)
        # both covered ends still serve
        np.testing.assert_array_equal(s.read_rows(8, 9)[1],
                                      base.read_rows(8, 9)[1])


# ---------------------------------------------------------------------------
# hypothesis stress: patterns x mesh shapes x workers x cache x skew
# ---------------------------------------------------------------------------

class TestFabricStress:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000),
           st.sampled_from(list(PATTERN_NAMES)),
           st.sampled_from(list(MESH_SHAPES)),
           st.sampled_from([1, ENV_WORKERS]),
           st.sampled_from([0, 1 << 10]),
           st.sampled_from(["uniform", "heavy_light"]))
    def test_fabric_equals_single_host(self, seed, pattern, shards,
                                       workers, cache_words, skew):
        src, dst = random_graph(64, 240, seed=seed % 997)
        kw = dict(mem_words=1 << 11, cache_words=cache_words,
                  workers=workers, skew=skew)
        fab = Fabric.from_graph(PATTERNS[pattern](), src, dst,
                                n_shards=shards, **kw)
        solo = QueryEngine.from_graph(PATTERNS[pattern](), src, dst, **kw)
        assert fab.count() == solo.count()
        if seed % 2:
            np.testing.assert_array_equal(fab.list(), solo.list())


# ---------------------------------------------------------------------------
# mesh reduction (in-process + 48 fake devices in a subprocess)
# ---------------------------------------------------------------------------

class TestMeshReduce:
    def test_mesh_psum_equals_host_sum(self):
        import jax
        mesh = fabric_mesh(len(jax.devices()))
        fab = small_fabric("triangle", None, mesh=mesh)
        assert fab.n_shards == int(mesh.devices.size)
        assert fab.count(reduce="mesh") == oracle("triangle")
        # auto picks the mesh when one is attached
        assert fab.count() == oracle("triangle")

    def test_mesh_reduce_rejects_partial_process(self):
        fab = small_fabric("triangle", 2, process_index=0, n_processes=2)
        with pytest.raises(ValueError, match="n_processes"):
            fab.count(reduce="mesh")

    def test_48_fake_devices_subprocess(self):
        """Acceptance: a 48-device CPU mesh (XLA forced host devices)
        reproduces the single-host count through the shard_map psum
        reduction at mesh shapes 8 and 48, and the 48-shard listing is
        byte-identical."""
        script = r"""
import numpy as np, jax
assert len(jax.devices()) == 48, jax.devices()
from repro.data.graphs import random_graph
from repro.launch.mesh import fabric_mesh, resolve_fabric_shards
from repro.parallel.fabric import Fabric
from repro.query.executor import QueryEngine
from repro.query.patterns import PATTERNS

assert resolve_fabric_shards() == 48
src, dst = random_graph(96, 400, seed=7)
want = QueryEngine.from_graph(PATTERNS["triangle"](), src, dst,
                              mem_words=1 << 12).count()
for shards in (8, 48):
    fab = Fabric.from_graph(PATTERNS["triangle"](), src, dst,
                            n_shards=shards, mem_words=1 << 12,
                            mesh=fabric_mesh(shards))
    got = fab.count(reduce="mesh")
    assert got == want, (shards, got, want)
    assert fab.stats.n_shards == shards
rows = Fabric.from_graph(PATTERNS["path3"](), src, dst, n_shards=48,
                         mem_words=1 << 12).list()
ref = QueryEngine.from_graph(PATTERNS["path3"](), src, dst,
                             mem_words=1 << 12).list()
assert np.array_equal(rows, ref)
print("FABRIC-MESH48-OK")
"""
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True,
                             env=_sub_env(48), timeout=600)
        assert res.returncode == 0, res.stderr[-2000:]
        assert "FABRIC-MESH48-OK" in res.stdout


# ---------------------------------------------------------------------------
# multi-process protocol: worker CLI + partial merging
# ---------------------------------------------------------------------------

class TestMultiProcess:
    def test_worker_cli_two_processes_merge(self, tmp_path):
        """True multi-process run: two worker processes each execute their
        ``shard % 2 == process_index`` slice of a 5-shard fabric and emit
        JSON partials; the merged count equals the single-host oracle."""
        parts = []
        for p in range(2):
            out = tmp_path / f"part{p}.json"
            res = subprocess.run(
                [sys.executable, "-m", "repro.parallel.fabric",
                 "--pattern", "triangle", "--nv", "96", "--ne", "400",
                 "--seed", "7", "--shards", "5", "--mem-words", "4096",
                 "--process-index", str(p), "--n-processes", "2",
                 "--out", str(out)],
                capture_output=True, text=True, env=_sub_env(),
                timeout=600)
            assert res.returncode == 0, res.stderr[-2000:]
            assert "FABRIC-PARTIAL-OK" in res.stdout
            parts.append(json.loads(out.read_text()))
        assert Fabric.merge_partials(parts) == oracle("triangle")
        with pytest.raises(ValueError, match="missing shard"):
            Fabric.merge_partials(parts[:1])

    def test_partial_merge_list_mode(self):
        """partial()/merge_partials round-trips listings through JSON and
        lands byte-identical to the single-host listing."""
        fabs = [small_fabric("diamond", 4, process_index=p, n_processes=2)
                for p in range(2)]
        parts = [json.loads(json.dumps(f.partial("list"))) for f in fabs]
        merged = Fabric.merge_partials(parts)
        np.testing.assert_array_equal(merged, oracle("diamond", "list"))

    def test_process_index_validated(self):
        with pytest.raises(ValueError, match="process_index"):
            small_fabric("triangle", 4, process_index=3, n_processes=2)


# ---------------------------------------------------------------------------
# serving layer integration (admission-gated fabric runs)
# ---------------------------------------------------------------------------

class TestServeFabric:
    def test_fabric_run_matches_served_query(self, thread_guard):
        from repro.serve import Server, Session

        src, dst = SMALL
        srv = Server.from_graph(src, dst, mem_words=1 << 15,
                                use_pallas_kernels=False)
        try:
            want = srv.submit("triangle").result(timeout=300)
            got, stats = srv.fabric_run("triangle", "count", n_shards=4)
            assert got == want == oracle("triangle")
            assert stats.n_shards == 4
            rows, _ = srv.fabric_run("triangle", "list", n_shards=4)
            np.testing.assert_array_equal(rows, oracle("triangle", "list"))
            # the reservation is fully returned afterwards
            assert srv.admission.reserved_words == 0
            assert srv.admission.active == 0
            with Session(srv) as ses:
                assert ses.fabric_count("triangle", n_shards=2) == want
        finally:
            srv.close()
