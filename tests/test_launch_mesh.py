"""Launch-layer coverage for the fabric path: mesh-shape resolution and
env overrides (``launch.mesh``) plus the no-device fabric dry-run
(``launch.dryrun``)."""

import importlib
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.launch.mesh import (FABRIC_AXIS, FABRIC_SHARDS_ENV, fabric_mesh,
                               host_device_count_from_flags,
                               maybe_init_distributed,
                               resolve_fabric_shards)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestHostDeviceCountFromFlags:
    def test_absent_is_none(self):
        assert host_device_count_from_flags("") is None
        assert host_device_count_from_flags(
            "--xla_cpu_enable_fast_math=true") is None

    def test_present(self):
        assert host_device_count_from_flags(
            "--xla_force_host_platform_device_count=48") == 48
        assert host_device_count_from_flags(
            "-a=1 --xla_force_host_platform_device_count=8 -b=2") == 8

    def test_repeated_flag_last_wins(self):
        flags = ("--xla_force_host_platform_device_count=8 "
                 "--xla_force_host_platform_device_count=48")
        assert host_device_count_from_flags(flags) == 48

    def test_none_reads_environment(self, monkeypatch):
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=17")
        assert host_device_count_from_flags() == 17
        monkeypatch.delenv("XLA_FLAGS")
        assert host_device_count_from_flags() is None


class TestResolveFabricShards:
    def test_explicit_request_wins(self, monkeypatch):
        monkeypatch.setenv(FABRIC_SHARDS_ENV, "9")
        assert resolve_fabric_shards(3) == 3
        assert resolve_fabric_shards(0) == 1          # clamped

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv(FABRIC_SHARDS_ENV, "6")
        assert resolve_fabric_shards() == 6

    def test_default_is_one_per_device(self, monkeypatch):
        monkeypatch.delenv(FABRIC_SHARDS_ENV, raising=False)
        assert resolve_fabric_shards() == max(1, len(jax.devices()))
        assert resolve_fabric_shards(devices=[object()] * 5) == 5


class TestFabricMesh:
    def test_mesh_shape_and_axis(self):
        n = len(jax.devices())
        mesh = fabric_mesh(n)
        assert mesh.axis_names == (FABRIC_AXIS,)
        assert int(mesh.devices.size) == n

    def test_more_shards_than_devices_raises(self):
        n = len(jax.devices())
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            fabric_mesh(n + 1)


class TestMaybeInitDistributed:
    def test_unconfigured_is_false(self, monkeypatch):
        for var in ("REPRO_FABRIC_COORDINATOR",
                    "REPRO_FABRIC_NUM_PROCESSES",
                    "REPRO_FABRIC_PROCESS_ID"):
            monkeypatch.delenv(var, raising=False)
        assert maybe_init_distributed() is False
        # partial configuration is still unconfigured
        monkeypatch.setenv("REPRO_FABRIC_COORDINATOR", "127.0.0.1:9999")
        assert maybe_init_distributed() is False


class TestDryrunFabric:
    def test_import_guard_respects_preset_flags(self, monkeypatch):
        """Reloading ``launch.dryrun`` must not clobber a caller-pinned
        forced-device count (the fabric CI job pins 48), and must append
        the 512 default when none is pinned."""
        import repro.launch.dryrun as dryrun

        preset = "--xla_force_host_platform_device_count=48"
        monkeypatch.setenv("XLA_FLAGS", preset)
        importlib.reload(dryrun)
        assert os.environ["XLA_FLAGS"] == preset
        monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_enable_fast_math=true")
        importlib.reload(dryrun)
        assert host_device_count_from_flags() == 512
        assert "--xla_cpu_enable_fast_math=true" in os.environ["XLA_FLAGS"]

    def test_fabric_dryrun_record(self, tmp_path):
        from repro.launch.dryrun import fabric_dryrun

        rec = fabric_dryrun(tmp_path, n_shards=3, nv=64, ne=200)
        assert rec["ok"] and rec["n_shards"] == 3
        assert rec["n_boxes"] >= 1 and rec["rank"] >= 2
        assert len(rec["shards"]) == 3
        assert sum(s["boxes"] for s in rec["shards"]) == rec["n_boxes"]
        assert sum(s["mass"] for s in rec["shards"]) == rec["total_mass"]
        on_disk = json.loads((tmp_path / "fabric__triangle__s3.json")
                             .read_text())
        assert on_disk == rec

    def test_fabric_cli_smoke(self, tmp_path):
        """``python -m repro.launch.dryrun --fabric`` plans a fabric with
        zero accelerators visible (JAX_PLATFORMS=cpu, 1 device)."""
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep \
            + env.get("PYTHONPATH", "")
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--fabric",
             "--fabric-shards", "2", "--out", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=300)
        assert res.returncode == 0, res.stderr[-2000:]
        assert "[OK] fabric__triangle__s2" in res.stdout
        assert (tmp_path / "fabric__triangle__s2.json").exists()
