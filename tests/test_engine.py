"""TriangleEngine: cross-engine equivalence, sharding, listing, padding.

The headline property (ISSUE 1 acceptance): ``TriangleEngine.count()`` and
``.list()`` agree with the scalar ``LeapfrogTriejoin`` reference on every
property-test graph — Erdős–Rényi, power-law (RMAT), planar grid — and on
golden counts for known graphs (K_n → C(n,3), grids → 0), including under
multi-device box sharding (subprocess with forced host devices).
"""

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TrieArray, TriangleEngine, boxed_triangle_count,
                        brute_force_count, engine_count, lftj_triangle_count,
                        measure_dense_crossover, orient_edges, pad_neighbors,
                        pad_neighbors_binned, plan_boxes)
from repro.core.lftj_jax import SENTINEL, _list_chunked, csr_from_edges
from repro.data.graphs import rmat_graph

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# graph generators for the property tests
# ---------------------------------------------------------------------------

def er_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = np.triu(rng.random((n, n)) < p, k=1)
    src, dst = np.nonzero(adj)
    return src.astype(np.int64), dst.astype(np.int64)


def grid_graph(n):
    """n x n planar grid: triangle-free by construction."""
    i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    v = (i * n + j)
    right = np.stack([v[:, :-1].ravel(), v[:, 1:].ravel()], 1)
    down = np.stack([v[:-1, :].ravel(), v[1:, :].ravel()], 1)
    e = np.concatenate([right, down])
    return e[:, 0], e[:, 1]


def complete_graph(n):
    i, j = np.triu_indices(n, k=1)
    return i.astype(np.int64), j.astype(np.int64)


def reference_count(src, dst):
    a, b = orient_edges(src, dst)
    return lftj_triangle_count(TrieArray.from_edges(a, b))


def reference_list(src, dst):
    out = []
    a, b = orient_edges(src, dst)
    lftj_triangle_count(TrieArray.from_edges(a, b), emit=out.append)
    tris = np.asarray(out, dtype=np.int64).reshape(-1, 3)
    order = np.lexsort((tris[:, 2], tris[:, 1], tris[:, 0]))
    return tris[order]


ENGINE_CONFIGS = [
    dict(),
    dict(mem_words=200),
    dict(degree_bins=True),
    dict(mem_words=200, degree_bins=True),
    dict(shard=True),
    dict(mem_words=200, shard=True),
    dict(mem_words=200, shard=True, degree_bins=True),
    dict(backend="dense"),
    dict(backend="binary"),
    dict(backend="host"),
    dict(orientation="degree"),
    dict(mem_words=200, workers=4),
    dict(mem_words=200, workers=2, backend="host"),
]


class TestCrossEngineEquivalence:
    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000))
    def test_erdos_renyi(self, seed):
        src, dst = er_graph(30, 0.2, seed)
        want = reference_count(src, dst)
        for kw in ENGINE_CONFIGS:
            assert engine_count(src, dst, **kw) == want, kw

    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 10_000))
    def test_power_law(self, seed):
        src, dst = rmat_graph(64, 600, seed=seed)
        want = reference_count(src, dst)
        for kw in ENGINE_CONFIGS:
            assert engine_count(src, dst, **kw) == want, kw

    @settings(max_examples=3, deadline=None)
    @given(st.integers(2, 7))
    def test_planar_grid_triangle_free(self, n):
        src, dst = grid_graph(n)
        assert reference_count(src, dst) == 0
        for kw in ENGINE_CONFIGS:
            assert engine_count(src, dst, **kw) == 0, kw

    @settings(max_examples=4, deadline=None)
    @given(st.integers(3, 12))
    def test_golden_complete_graph(self, n):
        src, dst = complete_graph(n)
        want = n * (n - 1) * (n - 2) // 6
        assert reference_count(src, dst) == want
        for kw in ENGINE_CONFIGS:
            assert engine_count(src, dst, **kw) == want, kw

    def test_agrees_with_brute_force(self):
        src, dst = rmat_graph(200, 2500, seed=11)
        want = brute_force_count(src, dst)
        eng = TriangleEngine(src, dst, mem_words=300)
        assert eng.count() == want
        assert eng.stats.n_boxes > 1


class TestListing:
    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000))
    def test_list_matches_reference(self, seed):
        src, dst = er_graph(25, 0.25, seed)
        want = reference_list(src, dst)
        for kw in [dict(), dict(mem_words=150), dict(shard=True)]:
            got = TriangleEngine(src, dst, **kw).list()
            np.testing.assert_array_equal(got, want), kw

    def test_overflow_rescan(self):
        """A deliberately tiny buffer must still produce the full, exact
        listing via the overflow→rescan protocol."""
        src, dst = complete_graph(12)
        eng = TriangleEngine(src, dst)
        tris = eng.list(capacity=4)
        assert len(tris) == 12 * 11 * 10 // 6
        assert eng.stats.n_rescans >= 1
        np.testing.assert_array_equal(tris, reference_list(src, dst))

    def test_list_chunked_total_exact_on_overflow(self):
        import jax.numpy as jnp
        src, dst = complete_graph(10)
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        npad = jnp.asarray(pad_neighbors(indptr, indices))
        total, buf = _list_chunked(npad, jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), cap=8)
        assert int(total) == 120  # exact count even though only 8 fit
        assert buf.shape == (8, 3)

    def test_listing_empty_graph(self):
        tris = TriangleEngine(np.zeros(0, int), np.zeros(0, int)).list()
        assert tris.shape == (0, 3)


class TestDegreeOrientation:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000))
    def test_outdegree_sqrt_bound(self, seed):
        """degree orientation: if out-deg(v) = d, every out-neighbor has
        degree >= d, so 2|E| >= d^2 — out-degrees are <= sqrt(2|E|)."""
        src, dst = rmat_graph(128, 1500, seed=seed)
        a, b = orient_edges(src, dst, mode="degree")
        m = len(a)
        if m == 0:
            return
        out_deg = np.bincount(a)
        assert out_deg.max() <= np.sqrt(2 * m) + 1

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 10_000))
    def test_orientation_invariant_counts(self, seed):
        src, dst = er_graph(30, 0.18, seed)
        want = reference_count(src, dst)
        assert engine_count(src, dst, orientation="degree") == want
        assert engine_count(src, dst, orientation="degree",
                            mem_words=200) == want


class TestBoxingInvariants:
    def _plan(self, seed=3, mem=300):
        src, dst = rmat_graph(128, 2000, seed=seed)
        a, b = orient_edges(src, dst)
        ta = TrieArray.from_edges(a, b)
        return a, b, ta, plan_boxes(ta, mem), mem

    def test_boxes_partition_oriented_edges(self):
        """Every oriented edge falls in exactly one box (the partitioning
        is overlap-free; pruned boxes hold no oriented edge)."""
        a, b, ta, boxes, _ = self._plan()
        covered = np.zeros(len(a), dtype=int)
        for (lx, hx, ly, hy) in boxes:
            covered += ((a >= lx) & (a <= hx) & (b >= ly) & (b <= hy))
        assert (covered == 1).all()

    def test_per_box_provisioned_words_within_budget(self):
        """The x-dimension slice each box provisions fits its budget share
        (4:1 x:y split as in §5), except single-value pinned (spill) boxes
        which are allowed to exceed it by construction."""
        a, b, ta, boxes, mem = self._plan()
        bx = int(mem * 4.0 / 5.0)
        for (lx, hx, ly, hy) in boxes:
            lo = max(lx, int(ta.val[0][0]))
            hi = min(hx, int(ta.val[0][-1]))
            if hi < lo:
                continue
            words = ta.slice_words((), lo, hi)
            assert words <= bx or lo == hi, (lx, hx, words, bx)

    def test_spill_path_exercised(self):
        """A hub star + triangle forces single-value pinned boxes; the
        count must survive the spill handling."""
        hub = np.zeros(80, dtype=int)
        leaves = np.arange(1, 81)
        src = np.concatenate([hub, [1, 1, 2]])
        dst = np.concatenate([leaves, [2, 3, 3]])
        want = brute_force_count(src, dst)
        ta = TrieArray.from_edges(*orient_edges(src, dst))
        cnt, stats = boxed_triangle_count(ta, mem_words=24)
        assert cnt == want
        assert stats.n_spills > 0
        assert engine_count(src, dst, mem_words=24) == want

    def test_plan_single_box_when_budget_fits(self):
        src, dst = er_graph(20, 0.3, seed=0)
        eng = TriangleEngine(src, dst, mem_words=1 << 20)
        eng.count()
        assert eng.stats.n_boxes == 1


class TestPadding:
    def test_pad_neighbors_rejects_truncation(self):
        """Regression: k < max degree used to silently drop neighbors and
        miscount; it must be a hard error now."""
        src = np.array([0, 0, 0, 1])
        dst = np.array([1, 2, 3, 2])
        indptr, indices = csr_from_edges(src, dst)
        with pytest.raises(ValueError, match="truncate"):
            pad_neighbors(indptr, indices, k=2)
        ok = pad_neighbors(indptr, indices, k=5)   # wider than needed: fine
        assert ok.shape[1] == 5
        assert (np.sort(ok[0][ok[0] != SENTINEL]) == [1, 2, 3]).all()

    def test_binned_padding_reconstructs(self):
        src, dst = rmat_graph(64, 800, seed=2)
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        row_bin, bins = pad_neighbors_binned(indptr, indices)
        deg = np.diff(indptr)
        seen = {}
        for rows, npad in bins:
            for j, v in enumerate(rows):
                seen[v] = npad[j][npad[j] != SENTINEL]
        for v in range(len(deg)):
            if deg[v] == 0:
                assert row_bin[v] == -1
            else:
                np.testing.assert_array_equal(
                    seen[v], indices[indptr[v]:indptr[v + 1]])

    def test_binned_padding_caps_waste(self):
        """One hub must not inflate every row to K = max degree."""
        hub = np.zeros(200, dtype=int)
        leaves = np.arange(1, 201)
        extra_s = np.arange(1, 50)
        extra_d = np.arange(2, 51)
        src = np.concatenate([hub, extra_s])
        dst = np.concatenate([leaves, extra_d])
        a, b = orient_edges(src, dst)
        indptr, indices = csr_from_edges(a, b)
        monolithic = pad_neighbors(indptr, indices)
        _, bins = pad_neighbors_binned(indptr, indices)
        binned_words = sum(npad.size for _, npad in bins)
        assert binned_words < monolithic.size / 10


class TestNonReplicatedSharding:
    """Acceptance: the shard_map path ships per-shard *local* slices, never
    the global (V, K) padded matrix."""

    def test_local_slice_shapes_scale_with_shard(self):
        src, dst = rmat_graph(256, 3000, seed=7)
        want = reference_count(src, dst)
        eng = TriangleEngine(src, dst, mem_words=400, shard=True)
        assert eng.count() == want
        shape = eng.stats.local_npad_shape
        assert shape is not None
        n_shards, R, K = shape
        assert n_shards == len(eng.devices)
        # the rows dimension is exactly the largest shard slice (+ pad row),
        # by construction — not the vertex count
        assert R == max(eng.stats.shard_rows) + 1
        assert all(r <= eng.nv for r in eng.stats.shard_rows)
        # K is the max degree among *referenced* rows, bounded by global K
        deg = np.diff(eng.indptr)
        assert 1 <= K <= int(deg.max())

    def test_listing_local_slices_agree(self):
        src, dst = er_graph(30, 0.25, seed=3)
        eng = TriangleEngine(src, dst, mem_words=200, shard=True)
        np.testing.assert_array_equal(eng.list(), reference_list(src, dst))
        assert eng.stats.local_npad_shape is not None
        assert eng.stats.local_npad_shape[1] <= eng.nv + 1

    def test_binned_shard_path_agrees(self):
        """degree_bins wired into shard_map: per-bin-pair kernels on
        pad_neighbors_binned widths."""
        hub = np.zeros(120, dtype=int)
        leaves = np.arange(1, 121)
        src = np.concatenate([hub, [1, 1, 2, 5, 5, 6]])
        dst = np.concatenate([leaves, [2, 3, 3, 6, 7, 7]])
        want = reference_count(src, dst)
        eng = TriangleEngine(src, dst, mem_words=120, shard=True,
                             degree_bins=True)
        assert eng.count() == want


class TestDegreeBinsFallbacks:
    def test_store_backed_degree_bins_honored_without_warning(self, tmp_path):
        """Store-backed engines honor degree_bins for real now: per-box
        slices are re-laid-out into degree bins inside the streaming
        executor, so the knob neither warns nor changes results — the
        old warn-and-drop fallback is gone."""
        import warnings

        from repro.data.edgestore import write_edge_store

        src, dst = rmat_graph(128, 1500, seed=3)
        path = write_edge_store(tmp_path / "g.csr", src, dst)
        with warnings.catch_warnings():
            warnings.simplefilter("error")   # any warning fails the test
            eng = TriangleEngine(store=path, mem_words=200,
                                 degree_bins=True)
            n = eng.count()
            tris = eng.list()
        assert n == reference_count(src, dst)
        assert len(tris) == n
        np.testing.assert_array_equal(tris, reference_list(src, dst))

    def test_sharded_binned_listing_matches_oracle(self):
        """shard=True + degree_bins=True listing runs the binned per-bin-
        pair listing kernels (no silent unbinned fallback): the triangles
        must match the unsharded reference exactly, and the binned count
        must agree with the listing total."""
        hub = np.zeros(120, dtype=int)
        leaves = np.arange(1, 121)
        src = np.concatenate([hub, [1, 1, 2, 5, 5, 6]])
        dst = np.concatenate([leaves, [2, 3, 3, 6, 7, 7]])
        eng = TriangleEngine(src, dst, mem_words=120, shard=True,
                             degree_bins=True)
        n_binned = eng.count()
        tris = eng.list()                    # binned sharded listing
        assert len(tris) == n_binned
        np.testing.assert_array_equal(tris, reference_list(src, dst))


class TestEngineConfig:
    def test_measured_crossover_is_sane(self):
        thr = measure_dense_crossover()
        assert 0.0 < thr <= 1.0

    def test_crossover_persisted_to_json_cache(self, tmp_path, monkeypatch):
        """Calibration is cached per (backend, device kind) in a JSON file;
        REPRO_CROSSOVER_REMEASURE forces a fresh measurement."""
        import json

        from repro.core import engine as eng_mod

        monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_CROSSOVER_REMEASURE", raising=False)
        eng_mod._crossover_memo.clear()
        thr = measure_dense_crossover(nv=64, repeats=1)
        cache_file = tmp_path / "crossover.json"
        assert cache_file.exists()
        data = json.loads(cache_file.read_text())
        key = [k for k in data if k.endswith(":nv64")]
        assert key and data[key[0]] == thr

        # a planted cache value is trusted (no re-measurement)
        data[key[0]] = 0.123
        cache_file.write_text(json.dumps(data))
        eng_mod._crossover_memo.clear()
        assert measure_dense_crossover(nv=64, repeats=1) == 0.123

        # ... unless a re-measure is forced, which overwrites the entry
        monkeypatch.setenv("REPRO_CROSSOVER_REMEASURE", "1")
        thr2 = measure_dense_crossover(nv=64, repeats=1)
        assert 0.0 < thr2 <= 1.0
        assert json.loads(cache_file.read_text())[key[0]] == thr2

    def test_pallas_band_persisted_alongside_dense_crossover(
            self, tmp_path, monkeypatch):
        """The pallas mid-band calibration lands in the same JSON cache as
        the dense crossover (`:pallas` key suffix) and plumbs through the
        `pallas_threshold="measured"` engine knob."""
        import json

        from repro.core import engine as eng_mod
        from repro.core import measure_pallas_crossover

        monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_CROSSOVER_REMEASURE", raising=False)
        eng_mod._crossover_memo.clear()
        dense = measure_dense_crossover(nv=64, repeats=1)
        band = measure_pallas_crossover(nv=64, repeats=1)
        assert 0.0 < band <= 1.0
        data = json.loads((tmp_path / "crossover.json").read_text())
        dense_keys = [k for k in data if k.endswith(":nv64")]
        band_keys = [k for k in data if k.endswith(":nv64:pallas")]
        assert dense_keys and band_keys       # both entries, one file
        assert data[band_keys[0]] == band
        assert data[dense_keys[0]] == dense

        # a planted band value is trusted and steers the engine knob
        # (the knob measures at the default nv=256 grid)
        data[band_keys[0].replace(":nv64:", ":nv256:")] = 0.031
        (tmp_path / "crossover.json").write_text(json.dumps(data))
        eng_mod._crossover_memo.clear()
        src = np.asarray([0, 0, 1])
        dst = np.asarray([1, 2, 2])
        eng = TriangleEngine(src, dst,
                             pallas_threshold="measured")
        assert eng.pallas_threshold == pytest.approx(0.031)
        # default stays the static crossover/4 band
        eng2 = TriangleEngine(src, dst, dense_threshold=0.2)
        assert eng2.pallas_threshold == pytest.approx(0.05)

    def test_auto_dispatch_routes_midband_to_pallas_when_supported(self):
        """Regression: 'auto' could only ever return dense/binary, leaving
        the Pallas backend dead. With pallas support flagged, mid-density
        boxes (within 4x below the dense crossover) now dispatch to it;
        without support (CPU interpret mode) 'auto' still avoids it."""
        src, dst = complete_graph(8)
        tpu_like = TriangleEngine(src, dst, use_pallas_kernels=True)
        # density 0.2 >> threshold -> dense regardless
        assert tpu_like._pick_backend(200, 32, 32) == "dense"
        # mid band: threshold/4 < 0.02 <= threshold
        assert tpu_like._pick_backend(20, 32, 32) == "pallas"
        # sparse: below the band
        assert tpu_like._pick_backend(5, 100, 100) == "binary"
        cpu = TriangleEngine(src, dst, use_pallas_kernels=False)
        assert cpu._pick_backend(20, 32, 32) == "binary"

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            TriangleEngine(np.array([0]), np.array([1]), backend="gpu")

    def test_stats_report_backends(self):
        src, dst = rmat_graph(128, 2000, seed=1)
        eng = TriangleEngine(src, dst, mem_words=300)
        eng.count()
        s = eng.stats
        executed = s.n_dense_boxes + s.n_binary_boxes + s.n_pallas_boxes
        assert 1 <= executed <= s.n_boxes  # empty boxes execute no backend
        assert s.dense_threshold == 0.05


_MULTI_DEVICE_SCRIPT = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.core import TriangleEngine, TrieArray, lftj_triangle_count, orient_edges
from repro.data.graphs import rmat_graph

for seed in (0, 5):
    src, dst = rmat_graph(128, 1500, seed=seed)
    a, b = orient_edges(src, dst)
    out = []
    want = lftj_triangle_count(TrieArray.from_edges(a, b), emit=out.append)
    eng = TriangleEngine(src, dst, mem_words=300)
    assert eng.shard and len(eng.devices) == 8
    got = eng.count()
    assert got == want, (seed, got, want)
    assert eng.stats.n_shards == 8
    # non-replicated sharding: per-device arrays are the local slice
    # (rows-referenced x local-K), never the global (V, K) matrix
    shp = eng.stats.local_npad_shape
    assert shp is not None and shp[0] == 8, shp
    assert shp[1] == max(eng.stats.shard_rows) + 1, (shp, eng.stats.shard_rows)
    assert shp[1] <= eng.nv
    tris = eng.list()
    assert len(tris) == want
    ref = np.sort(np.asarray(out, np.int64).reshape(-1, 3), axis=1)
    ref = ref[np.lexsort((ref[:, 2], ref[:, 1], ref[:, 0]))]
    assert (tris == ref).all()
print("MULTI_DEVICE_OK")
"""


class TestMultiDeviceSharding:
    def test_count_and_list_under_8_host_devices(self):
        """Acceptance: count()/list() agree with the reference under
        XLA_FLAGS=--xla_force_host_platform_device_count=8 box sharding."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8")
        env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep \
            + env.get("PYTHONPATH", "")
        # the forced-device-count flag only applies to the host platform;
        # pin it so jax never attempts (slow) accelerator backend init
        env["JAX_PLATFORMS"] = "cpu"
        res = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert res.returncode == 0, res.stderr[-2000:]
        assert "MULTI_DEVICE_OK" in res.stdout
