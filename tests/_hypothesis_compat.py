"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The tier-1 suite uses a small slice of the hypothesis API —
``@settings(max_examples=..., deadline=None)``, ``@given(...)`` and the
``integers`` / ``lists`` / ``tuples`` / ``sampled_from`` / ``booleans``
strategies. When ``import hypothesis`` fails, ``conftest.py`` registers this
module (and its ``strategies`` namespace) in ``sys.modules`` so the test
modules import unchanged.

The shim draws examples from a deterministically seeded PRNG (per test
name, so runs are reproducible) and re-raises the first failure annotated
with the falsifying example. No shrinking — install the real
``hypothesis`` (see requirements-dev.txt) for minimized counterexamples.
"""

from __future__ import annotations

import functools
import inspect
import random
import types

__version__ = "0.0-compat"

_DEFAULT_MAX_EXAMPLES = 20


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class SearchStrategy:
    """A strategy draws one value from an RNG via ``example``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self.example(rng)))

    def filter(self, pred, max_tries: int = 100):
        def draw(rng):
            for _ in range(max_tries):
                v = self.example(rng)
                if pred(v):
                    return v
            raise Unsatisfiable(f"filter predicate never satisfied: {pred}")
        return SearchStrategy(draw)


class Unsatisfiable(Exception):
    pass


def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31 - 1):
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw):
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(seq):
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))])


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements, min_size: int = 0, max_size: int = 10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw)


def just(value):
    return SearchStrategy(lambda rng: value)


def one_of(*strategies):
    return SearchStrategy(
        lambda rng: strategies[rng.randrange(len(strategies))].example(rng))


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("SearchStrategy", "integers", "booleans", "floats",
              "sampled_from", "tuples", "lists", "just", "one_of"):
    setattr(strategies, _name, globals()[_name])


# ---------------------------------------------------------------------------
# given / settings / assume
# ---------------------------------------------------------------------------

class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much]


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator form only (matches the suite's usage)."""
    def apply(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return apply


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", None) \
                or getattr(fn, "_hyp_max_examples", None) \
                or _DEFAULT_MAX_EXAMPLES
            rng = random.Random(fn.__qualname__)
            ran = 0
            attempts = 0
            while ran < n and attempts < 10 * n + 10:
                attempts += 1
                drawn = tuple(s.example(rng) for s in arg_strategies)
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **{**kwargs, **drawn_kw})
                    ran += 1
                except _Assumption:
                    continue
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example (hypothesis-compat shim, "
                        f"example {ran + 1}/{n}): args={drawn!r} "
                        f"kwargs={drawn_kw!r}") from exc
            if ran == 0:
                # real hypothesis raises Unsatisfiable here; silently
                # passing would mask a test whose assume() rejects every
                # drawn example
                raise AssertionError(
                    f"hypothesis-compat shim: assume() rejected all "
                    f"{attempts} drawn examples of {fn.__qualname__}; "
                    f"the test never ran")
            return None

        # Hide the drawn parameters from pytest's fixture resolution: the
        # exposed signature keeps only the leading params (self / real
        # fixtures) that strategies do not fill.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[:len(params) - len(arg_strategies)]
        keep = [p for p in keep if p.name not in kw_strategies]
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # or inspect follows it back to fn
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return decorate
