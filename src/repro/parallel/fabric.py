"""Distributed multi-device box fabric for the ``QueryEngine``.

The PR-4/PR-5 worker pool parallelizes one host; this module is the
cross-machine tier the paper points at ("the single-thread gap ... can be
alleviated by parallelization"): the n-dimensional ``QueryPlan`` box list
is partitioned over a device mesh, each shard receives ONLY the edge-store
byte ranges its boxes touch, and every shard re-runs the restricted plan
through an ordinary single-host ``QueryEngine`` — so the whole distributed
run inherits the engine's workers=1 oracle contract instead of inventing a
new execution path.

Layout (``Fabric.layout``)
    One *planner* engine over the full sources computes the box plan;
    ``sharding.box_mass_costs_nd`` prices every box in raw CSR words from
    the resident degree indexes, ``balanced_box_schedule`` LPT-packs boxes
    onto ``n_shards`` shards (each shard's box ids then sorted back to
    plan order), and ``sharding.shard_shipped_ranges`` derives, per shard
    and relation key (including derived ``~rev`` reversed indexes), the
    disjoint vertex-row intervals whose neighbor bytes must ship.

Shipping (``ShippedEdgeSource``)
    A shard-local EdgeSource holding the FULL resident ``indptr`` but only
    the shipped value ranges (the backing array is allocated full-length
    and zero-filled — the OS commits pages lazily, so resident memory
    scales with the shipped bytes). Its ``read_rows`` charges the shard's
    fresh ``BlockDevice`` with byte-identical block addresses to the
    original source (chunked charging for a store base, one DMA for an
    in-memory base), and raises ``FabricShippingError`` on any read
    outside the shipped intervals — under-shipping is loud, never wrong.

Determinism / oracle contract
    Per shard, the restricted plan + shipped sources + a fresh device
    reproduce, byte for byte, the ledger of a solo single-host engine
    running the same boxes over the full data (``Fabric.oracle_engine``
    builds exactly that engine); the global count is the sum of per-box
    counts and the global listing is the per-box row concatenation in
    GLOBAL plan-box order — identical to the single-host ``count()`` /
    ``list()``, which are the same reductions over the same per-box
    results. ``tests/test_fabric.py`` pins all three against the
    single-host oracle across mesh shapes and patterns.

Reduction
    Host-side summation by default; with a 1-D ``launch.mesh.fabric_mesh``
    attached the count reduction runs as a ``shard_map`` ``psum`` over the
    ``"shards"`` axis. Multi-process runs (one process per mesh slice,
    ``jax.distributed`` behind ``launch.mesh.maybe_init_distributed``)
    exchange JSON ``partial()`` payloads merged by ``merge_partials`` —
    the worker CLI at the bottom is that protocol:

        python -m repro.parallel.fabric --pattern triangle --nv 96 \
            --ne 400 --shards 4 --process-index 0 --n-processes 2 \
            --out part0.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.iomodel import BlockDevice, IOStats
from repro.core.lftj_jax import csr_from_edges, orient_edges
from repro.core.queries import Query
from repro.data.edgestore import EdgeStore, InMemoryEdgeSource
from repro.launch.mesh import (FABRIC_AXIS, fabric_mesh,
                               maybe_init_distributed,
                               resolve_fabric_shards)
from repro.parallel.sharding import (balanced_box_schedule, box_mass_costs_nd,
                                     interval_gaps, merge_interval,
                                     shard_shipped_ranges)
from repro.query.executor import QueryEngine, QueryStats
from repro.query.planner import QueryPlan


class FabricShippingError(RuntimeError):
    """A shard read vertex rows outside its shipped byte ranges — the
    shipping planner under-provisioned. Raised instead of silently serving
    zeros, because a quiet miss would corrupt counts downstream."""


class ShippedEdgeSource:
    """Shard-local EdgeSource over shipped byte ranges (module docstring).

    ``base`` is the origin source (an ``EdgeStore`` or in-memory CSR — any
    object with ``indptr`` + ``read_rows``); ``ranges`` the sorted
    disjoint inclusive vertex-row intervals to ship. Shipping reads go
    through ``base.read_rows``, so they are charged to the ORIGIN device
    (the shipping cost is real, measured I/O); serving reads are charged
    to this source's own (shard) device at the same virtual block
    addresses the origin layout would use.
    """

    def __init__(self, base, ranges: Sequence[Tuple[int, int]],
                 device: Optional[BlockDevice] = None):
        self.indptr = np.asarray(base.indptr, dtype=np.int64)
        self.n_nodes = len(self.indptr) - 1
        self.n_edges = int(self.indptr[-1]) if len(self.indptr) else 0
        self.orientation = getattr(base, "orientation", "raw")
        if isinstance(base, EdgeStore):
            # mirror the store's chunked file layout so charged block
            # addresses (incl. chunk padding) match the origin byte for
            # byte; exposing ``chunk_rows`` also keeps SliceCache's
            # block_rows derivation identical to a store-backed oracle
            self.chunk_rows = base.chunk_rows
            self._chunk_off = np.asarray(base._chunk_off, dtype=np.int64)
            total = int(self._chunk_off[-1])
        else:
            self._chunk_off = None
            total = self.n_edges
        self._total_words = total
        # full-length backing: virtual addresses equal the origin layout;
        # zeros pages stay uncommitted until a range actually ships
        self._vals = np.zeros(total, dtype=np.int32)
        self._covered: List[Tuple[int, int]] = []
        self.shipped_words = 0
        self.device: Optional[BlockDevice] = None
        if device is not None:
            self.attach_device(device)
        for lo, hi in ranges:
            self._ship(base, int(lo), int(hi))

    # -- construction ---------------------------------------------------------

    def attach_device(self, device: Optional[BlockDevice]) -> None:
        self.device = device
        if device is not None and self._total_words:
            device.register(self._vals)

    def _ship(self, base, lo: int, hi: int) -> None:
        """Copy rows [lo, hi] out of the origin source into the backing
        array at their home positions (charging the origin's device)."""
        lo = max(0, lo)
        hi = min(self.n_nodes - 1, hi)
        if hi < lo:
            return
        _ip, vals = base.read_rows(lo, hi)
        self.shipped_words += len(vals)
        if self._chunk_off is None:
            s, e = int(self.indptr[lo]), int(self.indptr[hi + 1])
            self._vals[s:e] = vals
        else:
            off = 0
            c0, c1 = lo // self.chunk_rows, hi // self.chunk_rows
            for c in range(c0, c1 + 1):
                r0 = max(lo, c * self.chunk_rows)
                r1 = min(hi, (c + 1) * self.chunk_rows - 1)
                cbase = int(self._chunk_off[c]) \
                    - int(self.indptr[c * self.chunk_rows])
                s = cbase + int(self.indptr[r0])
                e = cbase + int(self.indptr[r1 + 1])
                if e > s:
                    self._vals[s:e] = vals[off:off + (e - s)]
                    off += e - s
        self._covered = merge_interval(self._covered, lo, hi)

    # -- EdgeSource interface -------------------------------------------------

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def words(self) -> int:
        return self.n_edges

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        lo = max(0, int(lo))
        hi = min(self.n_nodes - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        if interval_gaps(self._covered, lo, hi):
            raise FabricShippingError(
                f"rows [{lo}, {hi}] not fully shipped to this shard "
                f"(covered: {self._covered})")
        if self._chunk_off is not None:
            parts = []
            c0, c1 = lo // self.chunk_rows, hi // self.chunk_rows
            for c in range(c0, c1 + 1):
                r0 = max(lo, c * self.chunk_rows)
                r1 = min(hi, (c + 1) * self.chunk_rows - 1)
                cbase = int(self._chunk_off[c]) \
                    - int(self.indptr[c * self.chunk_rows])
                s = cbase + int(self.indptr[r0])
                e = cbase + int(self.indptr[r1 + 1])
                if e > s:
                    if self.device is not None:
                        self.device.read_range(self._vals, s, e)
                    parts.append(np.asarray(self._vals[s:e]))
            vals = np.concatenate(parts) if parts else np.zeros(0, np.int32)
            return self.indptr[lo:hi + 2] - self.indptr[lo], vals
        s, e = int(self.indptr[lo]), int(self.indptr[hi + 1])
        if self.device is not None and e > s:
            self.device.read_range(self._vals, s, e)
        return self.indptr[lo:hi + 2] - self.indptr[lo], self._vals[s:e]


@dataclass
class FabricLayout:
    """The fabric's static execution layout: plan + costs + schedule +
    per-shard shipped row intervals per relation key."""

    plan: QueryPlan
    costs: List[int]
    schedule: List[List[int]]
    shipped: List[Dict[str, List[Tuple[int, int]]]]


@dataclass
class ShardReport:
    """One shard execution: its box ids (global plan indices, ascending),
    per-box results in that order, the shard engine's ``QueryStats``, and
    the shard device's raw ledger."""

    shard: int
    box_ids: List[int]
    results: List
    stats: QueryStats
    io: IOStats
    shipped_words: int
    engine: QueryEngine


@dataclass
class FabricStats:
    """One distributed ``count()`` / ``list()`` run, per shard and summed."""

    n_shards: int = 0
    n_boxes: int = 0
    n_results: int = 0
    total_mass: int = 0
    shard_boxes: List[int] = field(default_factory=list)
    shard_mass: List[int] = field(default_factory=list)
    shipped_words: List[int] = field(default_factory=list)
    shard_block_reads: List[int] = field(default_factory=list)
    shard_word_reads: List[int] = field(default_factory=list)
    sum_block_reads: int = 0
    sum_word_reads: int = 0
    balance: float = 1.0               # max shard mass / mean nonzero mass


class Fabric:
    """Facade over a distributed box-fabric run (module docstring).

    Parameters mirror ``QueryEngine`` where they share meaning; the extra
    knobs are ``n_shards`` (default: ``launch.mesh.resolve_fabric_shards``
    — one shard per local device, overridable via ``REPRO_FABRIC_SHARDS``),
    ``mesh`` (a ``launch.mesh.fabric_mesh``; attaching one switches the
    count reduction to a ``shard_map`` ``psum``), and the multi-process
    pair ``process_index`` / ``n_processes`` (this process executes shards
    with ``shard % n_processes == process_index``; cross-process merging
    goes through ``partial()`` / ``merge_partials``).
    """

    def __init__(self, query: Query, relations: Optional[Dict] = None, *,
                 store=None,
                 order: Optional[Sequence[str]] = None,
                 n_shards: Optional[int] = None,
                 mesh=None,
                 mem_words: Optional[int] = None,
                 cache_words: int = 0,
                 io_block_words: int = 4096,
                 backend: str = "auto",
                 workers: int = 1,
                 skew: str = "uniform",
                 heavy_threshold: Optional[int] = None,
                 device: Optional[BlockDevice] = None,
                 process_index: int = 0,
                 n_processes: int = 1,
                 use_pallas_kernels: Optional[bool] = None,
                 tracer=None,
                 metrics=None):
        self.query = query
        # observability: one tracer spans planning and every shard run
        # (each shard on its own trace lane); the registry picks up each
        # shard engine's queue/kernel series
        self.tracer = tracer
        self.metrics = metrics
        self.mem_words = mem_words
        self.cache_words = int(cache_words)
        self.io_block_words = int(io_block_words)
        self.backend = backend
        self.workers = max(1, int(workers))
        self.skew = skew
        self.heavy_threshold = heavy_threshold
        self.mesh = mesh
        self.process_index = int(process_index)
        self.n_processes = max(1, int(n_processes))
        if not (0 <= self.process_index < self.n_processes):
            raise ValueError(
                f"process_index {process_index} outside [0, {n_processes})")
        # the planner runs plan + shipping over the FULL sources; its
        # device (if any) is charged the shipping reads
        self.planner = QueryEngine(
            query, relations=relations, store=store, order=order,
            mem_words=mem_words, cache_words=0, device=device,
            io_block_words=io_block_words, backend=backend, workers=1,
            skew=skew, heavy_threshold=heavy_threshold,
            use_pallas_kernels=use_pallas_kernels,
            tracer=tracer)
        if n_shards is None and mesh is not None:
            n_shards = int(mesh.devices.size)
        self.n_shards = resolve_fabric_shards(n_shards)
        self._layout: Optional[FabricLayout] = None
        self.stats = FabricStats()
        self.reports: List[ShardReport] = []

    @classmethod
    def from_graph(cls, query: Query, src, dst, *,
                   orientation: str = "minmax", **kw) -> "Fabric":
        """Fabric over one undirected graph, oriented exactly as
        ``QueryEngine.from_graph`` orients it."""
        rel_names = {a.rel for a in query.atoms}
        if len(rel_names) != 1:
            raise ValueError(
                f"from_graph needs a single-relation query; got {rel_names}")
        a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
        nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        ip, ix = csr_from_edges(a, b, n_nodes=nv) if nv else \
            (np.zeros(1, np.int64), np.zeros(0, np.int32))
        source = InMemoryEdgeSource(ip, ix, orientation=orientation)
        return cls(query, relations={rel_names.pop(): source}, **kw)

    # -- layout ---------------------------------------------------------------

    def _all_keys(self) -> List[str]:
        """Every relation key a shard (and its oracle) must provision, in
        the planner's registration order: forward relation names first,
        then derived reversed indexes — shard and oracle construct sources
        in this exact order so their devices' region layouts coincide."""
        fwd = []
        for a in self.query.atoms:
            if a.rel not in fwd:
                fwd.append(a.rel)
        return fwd + [k for k in self.planner.source_keys()
                      if k.endswith("~rev")]

    def _base_source(self, key: str):
        srcs = self.planner._sources
        return srcs[key] if key in srcs else self.planner._raw[key]

    def layout(self) -> FabricLayout:
        """Plan + LPT schedule + per-shard shipped row intervals (cached;
        pure metadata — no neighbor bytes move until ``run_local``)."""
        if self._layout is not None:
            return self._layout
        plan = self.planner.plan()
        dim_keys = self.planner.owned_dim_keys()
        indptr_by_key, nv_by_key = {}, {}
        for _d, keys in dim_keys:
            for key in keys:
                if key not in indptr_by_key:
                    src = self._base_source(key)
                    indptr_by_key[key] = np.asarray(src.indptr)
                    nv_by_key[key] = src.n_nodes
        costs = box_mass_costs_nd(plan.boxes, dim_keys, indptr_by_key)
        # sort each shard's boxes back to plan order: the shard engine
        # drains them in plan order (the ledger-sensitive queue policy),
        # and the global reduction re-merges by ascending global box id
        schedule = [sorted(s)
                    for s in balanced_box_schedule(costs, self.n_shards)]
        shipped = shard_shipped_ranges(plan.boxes, schedule, dim_keys,
                                       nv_by_key)
        self._layout = FabricLayout(plan, costs, schedule, shipped)
        return self._layout

    def describe(self) -> dict:
        """JSON-able layout summary (the ``launch.dryrun --fabric`` record
        and the scaling benchmark's balance report) — planning only, no
        shard executes."""
        lay = self.layout()
        shards = []
        for ids, ranges in zip(lay.schedule, lay.shipped):
            words = 0
            for key, ivals in ranges.items():
                ip = np.asarray(self._base_source(key).indptr, np.int64)
                for lo, hi in ivals:
                    words += int(ip[hi + 1] - ip[lo])
            shards.append({"boxes": len(ids),
                           "mass": int(sum(lay.costs[i] for i in ids)),
                           "shipped_words": int(words)})
        return {"n_shards": int(self.n_shards),
                "n_boxes": len(lay.plan.boxes),
                "rank": int(lay.plan.rank),
                "order": list(lay.plan.order),
                "total_mass": int(sum(lay.costs)),
                "shards": shards}

    # -- per-shard execution --------------------------------------------------

    def my_shards(self) -> List[int]:
        return [s for s in range(self.n_shards)
                if s % self.n_processes == self.process_index]

    def _shard_device(self) -> BlockDevice:
        # same geometry the engine would auto-create for a store-backed
        # run at this budget — and what oracle_engine builds, so the
        # frame-level LRU behaviour matches frame for frame
        return BlockDevice(
            block_words=self.io_block_words,
            cache_blocks=max(2, (self.mem_words or (1 << 22))
                             // self.io_block_words))

    def _engine_over(self, rels: Dict[str, object], dev: BlockDevice,
                     box_ids: Sequence[int],
                     workers: Optional[int] = None) -> QueryEngine:
        lay = self.layout()
        sub = dataclasses.replace(
            lay.plan,
            boxes=[lay.plan.boxes[i] for i in box_ids],
            lanes=[lay.plan.lanes[i] for i in box_ids]
            if lay.plan.lanes else [])
        return QueryEngine(
            self.query, relations=rels, order=self.planner.order,
            mem_words=self.mem_words, cache_words=self.cache_words,
            device=dev, io_block_words=self.io_block_words,
            backend=self.backend,
            workers=self.workers if workers is None else workers,
            skew=self.skew, heavy_threshold=self.heavy_threshold,
            plan=sub, use_pallas_kernels=self.planner.use_pallas_kernels,
            tracer=self.tracer, metrics=self.metrics)

    def shard_engine(self, shard: int) -> QueryEngine:
        """The shard's engine: fresh device, shipped sources, restricted
        plan. Public so tests can drive it box by box."""
        lay = self.layout()
        dev = self._shard_device()
        rels: Dict[str, object] = {}
        for key in self._all_keys():
            rels[key] = ShippedEdgeSource(
                self._base_source(key), lay.shipped[shard].get(key, []),
                device=dev)
        return self._engine_over(rels, dev, lay.schedule[shard])

    def oracle_engine(self, shard: int,
                      workers: Optional[int] = None) -> QueryEngine:
        """The shard's solo oracle: the SAME restricted plan over FULL
        rebuilt sources on a fresh identically-configured device — what a
        single host running just this shard's boxes would do. The fabric's
        byte-identity contract is ``shard_engine(s)`` ledgers ==
        ``oracle_engine(s)`` ledgers, at any worker count."""
        lay = self.layout()
        dev = self._shard_device()
        rels: Dict[str, object] = {}
        for key in self._all_keys():
            base = self._base_source(key)
            if isinstance(base, EdgeStore):
                rels[key] = EdgeStore(base.path, device=dev)
            else:
                rels[key] = InMemoryEdgeSource(
                    base.indptr, base.indices, device=dev,
                    orientation=getattr(base, "orientation", "raw"))
        return self._engine_over(rels, dev, lay.schedule[shard],
                                 workers=workers)

    def run_local(self, shard: int, mode: str = "count",
                  capacity: Optional[int] = None) -> ShardReport:
        """Execute one shard end to end; per-box results come back in the
        shard's (ascending global) box order."""
        lay = self.layout()
        eng = self.shard_engine(shard)
        if self.tracer is not None:
            # each shard gets its own trace lane (a Chrome process row):
            # stragglers and shipping skew line up side by side
            with self.tracer.lane(f"shard{shard}"), \
                    self.tracer.span("fabric.shard", shard=shard,
                                     mode=mode,
                                     n_boxes=len(lay.schedule[shard])):
                results = eng.run_boxes(mode, capacity)
        else:
            results = eng.run_boxes(mode, capacity)
        shipped = sum(getattr(s, "shipped_words", 0)
                      for s in (eng.source_for(k)
                                for k in eng.source_keys()))
        return ShardReport(shard=shard, box_ids=list(lay.schedule[shard]),
                           results=results, stats=eng.stats,
                           io=eng.device.stats, shipped_words=int(shipped),
                           engine=eng)

    # -- reduction ------------------------------------------------------------

    def _collect(self, reports: List[ShardReport]) -> None:
        lay = self.layout()
        st = FabricStats(n_shards=self.n_shards,
                         n_boxes=len(lay.plan.boxes),
                         total_mass=int(sum(lay.costs)))
        for rep in reports:
            mass = int(sum(lay.costs[i] for i in rep.box_ids))
            st.shard_boxes.append(len(rep.box_ids))
            st.shard_mass.append(mass)
            st.shipped_words.append(rep.shipped_words)
            st.shard_block_reads.append(rep.stats.block_reads)
            st.shard_word_reads.append(rep.stats.word_reads)
            st.n_results += rep.stats.n_results
        st.sum_block_reads = sum(st.shard_block_reads)
        st.sum_word_reads = sum(st.shard_word_reads)
        nonzero = [m for m in st.shard_mass if m] or [1]
        st.balance = max(st.shard_mass, default=0) / \
            (sum(nonzero) / len(nonzero))
        self.stats = st
        self.reports = reports

    def _mesh_sum(self, partials: Sequence[int]) -> int:
        """Count reduction as a ``psum`` over the fabric mesh's "shards"
        axis — one partial per device. int32 lanes: per-shard triangle
        counts beyond 2^31 need the host reduction."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = self.mesh if self.mesh is not None \
            else fabric_mesh(self.n_shards)
        arr = jnp.asarray(np.asarray(partials, dtype=np.int32))
        f = shard_map(lambda x: jax.lax.psum(jnp.sum(x), FABRIC_AXIS),
                      mesh=mesh, in_specs=P(FABRIC_AXIS), out_specs=P())
        return int(f(arr))

    def count(self, reduce: str = "auto") -> int:
        """Distributed count over this process's shards. ``reduce``:
        'host' (plain sum), 'mesh' (``shard_map`` ``psum`` over the fabric
        mesh), or 'auto' (mesh when one is attached). With
        ``n_processes > 1`` this is the LOCAL partial — merge across
        processes with ``partial()`` / ``merge_partials``."""
        if reduce not in ("auto", "host", "mesh"):
            raise ValueError(f"reduce {reduce!r} not in "
                             "('auto', 'host', 'mesh')")
        reports = [self.run_local(s, "count") for s in self.my_shards()]
        self._collect(reports)
        partials = [sum(int(r) for r in rep.results if r is not None)
                    for rep in reports]
        if reduce == "auto":
            reduce = "mesh" if self.mesh is not None else "host"
        if reduce == "mesh":
            if self.n_processes != 1:
                raise ValueError("mesh reduction needs every shard's "
                                 "partial in-process (n_processes == 1)")
            return self._mesh_sum(partials)
        return int(sum(partials))

    def list(self, capacity: Optional[int] = None) -> np.ndarray:
        """Distributed listing: per-box rows merged in GLOBAL plan-box
        order, then projected to head columns — byte-identical to the
        single-host ``QueryEngine.list`` on the same sources."""
        reports = [self.run_local(s, "list", capacity)
                   for s in self.my_shards()]
        self._collect(reports)
        by_box: Dict[int, np.ndarray] = {}
        for rep in reports:
            for bid, rows in zip(rep.box_ids, rep.results):
                if rows is not None:
                    by_box[bid] = rows
        parts = [by_box[b] for b in sorted(by_box)]
        rows = np.concatenate(parts) if parts \
            else np.zeros((0, self.planner.n), dtype=np.int64)
        return self.planner.head_columns(rows)

    # -- multi-process protocol ----------------------------------------------

    def partial(self, mode: str = "count",
                capacity: Optional[int] = None) -> dict:
        """This process's JSON-able shard partials. Listing rows are
        head-projected per box (projection commutes with the box-order
        concatenation ``merge_partials`` performs)."""
        shards = []
        for s in self.my_shards():
            rep = self.run_local(s, mode, capacity)
            ent: dict = {"shard": rep.shard,
                         "box_ids": [int(b) for b in rep.box_ids],
                         "block_reads": int(rep.stats.block_reads),
                         "shipped_words": int(rep.shipped_words)}
            if mode == "count":
                ent["counts"] = [int(r) if r is not None else 0
                                 for r in rep.results]
            else:
                ent["rows"] = {
                    str(b): (self.planner.head_columns(r).tolist()
                             if r is not None else [])
                    for b, r in zip(rep.box_ids, rep.results)}
            shards.append(ent)
        return {"mode": mode,
                "n_shards": int(self.n_shards),
                "n_head": len(self.query.head),
                "process_index": int(self.process_index),
                "n_processes": int(self.n_processes),
                "shards": shards}

    @staticmethod
    def merge_partials(partials: Sequence[dict]):
        """Merge ``partial()`` payloads from every process: checks shard
        coverage, then sums counts or concatenates listing rows in global
        box order. Returns an int (count) or an (m, n_head) array."""
        if not partials:
            raise ValueError("no partials to merge")
        mode = partials[0]["mode"]
        n_shards = int(partials[0]["n_shards"])
        seen: Dict[int, dict] = {}
        for p in partials:
            if p["mode"] != mode or int(p["n_shards"]) != n_shards:
                raise ValueError("partials disagree on mode/n_shards")
            for ent in p["shards"]:
                seen[int(ent["shard"])] = ent
        missing = [s for s in range(n_shards) if s not in seen]
        if missing:
            raise ValueError(f"missing shard partial(s): {missing}")
        if mode == "count":
            return sum(sum(ent["counts"]) for ent in seen.values())
        by_box: Dict[int, list] = {}
        for ent in seen.values():
            for bid, rows in ent["rows"].items():
                if rows:
                    by_box[int(bid)] = rows
        merged: list = []
        for b in sorted(by_box):
            merged.extend(by_box[b])
        n_head = int(partials[0]["n_head"])
        return np.asarray(merged, dtype=np.int64) if merged \
            else np.zeros((0, n_head), dtype=np.int64)


# ---------------------------------------------------------------------------
# worker CLI (one process per mesh slice)
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="box-fabric worker: run this process's shards of a "
                    "pattern query and emit a JSON partial")
    ap.add_argument("--pattern", default="triangle")
    ap.add_argument("--graph", default="random",
                    choices=["random", "rmat", "clustered"])
    ap.add_argument("--nv", type=int, default=96)
    ap.add_argument("--ne", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mem-words", type=int, default=1 << 12)
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--mode", default="count", choices=["count", "list"])
    ap.add_argument("--process-index", type=int, default=0)
    ap.add_argument("--n-processes", type=int, default=1)
    ap.add_argument("--out", default=None,
                    help="write the JSON partial here (default: stdout)")
    args = ap.parse_args(argv)

    from repro.data import graphs
    from repro.query.patterns import PATTERNS

    distributed = maybe_init_distributed()
    gen = {"random": graphs.random_graph, "rmat": graphs.rmat_graph}.get(
        args.graph)
    if gen is not None:
        src, dst = gen(args.nv, args.ne, seed=args.seed)
    else:
        src, dst = graphs.clustered_graph(max(1, args.nv // 16), 16,
                                          seed=args.seed)
    fab = Fabric.from_graph(PATTERNS[args.pattern](), src, dst,
                            n_shards=args.shards,
                            mem_words=args.mem_words,
                            workers=args.workers,
                            process_index=args.process_index,
                            n_processes=args.n_processes)
    part = fab.partial(args.mode)
    part["distributed"] = bool(distributed)
    payload = json.dumps(part)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
    else:
        sys.stdout.write(payload + "\n")
    print(f"FABRIC-PARTIAL-OK shards={len(part['shards'])}"
          f"/{part['n_shards']} process={args.process_index}"
          f"/{args.n_processes}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
