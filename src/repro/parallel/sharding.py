"""Per-family sharding rules (PartitionSpec trees keyed off param names).

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single.
``dp`` below = the data-parallel super-axis: ("pod", "data") when the pod
axis exists — gradient all-reduce crosses DCN exactly once per step.

LM     : FSDP over dp + Megatron TP over model (column/row-parallel pairs);
         MoE experts over model (EP); KV cache sequence-sharded over model.
GNN    : nodes/edges row-sharded over every axis (flattened); params
         replicated (they are KBs; messages dominate).
DLRM   : embedding tables row(vocab)-sharded over model; MLPs replicated;
         batch over dp.
Boxes  : the triangle engine shards the paper's box list over all devices
         (``box_mesh`` + ``balanced_box_schedule`` + ``shard_local_slices``
         below; consumed by ``repro.core.engine.TriangleEngine``). Each
         shard receives a *renumbered local* neighbor slice covering only
         the rows its boxes reference — the padded neighbor matrix is
         never replicated across the mesh.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# activation-sharding rules: models call ``constrain(x, kind)``; the step
# builders activate a rule set for the cell's mesh at trace time. With no
# active rules (CPU smoke tests) this is a no-op.
# ---------------------------------------------------------------------------

_RULES: Optional[Dict[str, Any]] = None
_RULES_MESH: Optional[Mesh] = None


def set_rules(mesh: Optional[Mesh], family: Optional[str]) -> None:
    global _RULES, _RULES_MESH
    if mesh is None or family is None:
        _RULES, _RULES_MESH = None, None
        return
    dp = dp_axes(mesh)
    alln = all_axes(mesh)
    if family == "lm":
        _RULES = {
            # sequence parallelism on the residual stream: the 28-layer
            # remat carry stack divides by the TP size (Megatron-SP style)
            "lm_act": (dp, "model", None),         # (B, S, D)
            "lm_logits": (dp, None, "model"),      # (B, S, V)
            "lm_logits2": (dp, "model"),           # (B, V) last-only/decode
            "moe_ge": (dp, "model", None, None),   # (B, E, cap, D) EP
            "moe_x_local": (dp, None, None),       # dispatch scatters run
                                                   # on full-S local rows
            # attention scores (B, KV, G, Q, S): shard Q (train/prefill)
            # or S (decode) over model — works for any head count
            "attn_q": (dp, None, None, "model", None),
            "attn_s": (dp, None, None, None, "model"),
            "mla_scores": (dp, "model", None, None),  # (B, H=128, Q, S)
        }
    elif family == "gnn":
        _RULES = {"gnn_nodes": (alln, None)}       # (N, D)
    elif family == "recsys":
        _RULES = {"dlrm_act": (dp, None),          # (B, D)
                  # row-sparse optimizer: replicate the (small) unique-row
                  # updates so the scatter onto vocab-sharded tables
                  # partitions by index-masking instead of replicating the
                  # table (§Perf dlrm_train v2)
                  "dlrm_rows": (None, None)}
    _RULES_MESH = mesh


def constrain(x, kind: str):
    if _RULES is None or kind not in _RULES:
        return x
    spec = _RULES[kind]
    dims = x.shape
    resolved = []
    for i, a in enumerate(spec[:len(dims)]):
        if a is None:
            resolved.append(None)
        elif _evenly(dims[i], _RULES_MESH, a):
            resolved.append(a)
        else:
            resolved.append(None)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_RULES_MESH, P(*resolved)))
    except Exception:  # outside jit/mesh context: ignore
        return x


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _evenly(dim: int, mesh: Mesh, axes) -> bool:
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                                else (axes,))]))
    return dim % size == 0


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def _lm_leaf_spec(name: str, shape, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    nd = len(shape)
    # stacked scan blocks carry a leading layer axis -> never sharded
    lead = (None,) if name.startswith("block") else ()
    core = shape[len(lead):]
    key = name.split("/")[-1]

    def fit(dim, axes):
        return _evenly(dim, mesh, axes)

    if key in ("norm1", "norm2", "final_norm", "q_a_norm", "kv_a_norm"):
        return P(*lead, None)
    if key in ("bq", "bk", "bv"):
        return P(*lead, "model") if fit(core[0], "model") else P(*lead, None)
    if key == "embed":
        return P("model" if fit(core[0], "model") else None,
                 dp if fit(core[1], dp) else None)
    if key == "lm_head":
        return P(dp if fit(core[0], dp) else None,
                 "model" if fit(core[1], "model") else None)
    if key == "router":
        return P(*lead, dp if fit(core[0], dp) else None, None)
    if key in ("wi", "shared_wi", "wq", "wk", "wv", "wq_b", "wkv_b"):
        if len(core) == 3:  # MoE expert-stacked (E, D, F): EP over model
            return P(*lead, "model" if fit(core[0], "model") else None,
                     dp if fit(core[1], dp) else None, None)
        return P(*lead, dp if fit(core[0], dp) else None,
                 "model" if fit(core[1], "model") else None)
    if key in ("wo", "shared_wo"):
        if len(core) == 3:  # (E, F, D)
            return P(*lead, "model" if fit(core[0], "model") else None,
                     None, dp if fit(core[2], dp) else None)
        return P(*lead, "model" if fit(core[0], "model") else None,
                 dp if fit(core[1], dp) else None)
    if key in ("wq_a", "wkv_a"):
        return P(*lead, dp if fit(core[0], dp) else None, None)
    # fallback: shard the largest fitting dim over dp
    spec = [None] * nd
    for i in np.argsort([-s for s in shape]):
        if fit(shape[i], dp):
            spec[i] = dp
            break
    return P(*spec)


def lm_param_sharding(mesh: Mesh, shapes_tree) -> Any:
    """Map the {name: (shape, dtype)} tree to NamedShardings."""
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], tuple))
    flat, tdef = jax.tree_util.tree_flatten_with_path(shapes_tree,
                                                      is_leaf=is_leaf)
    out = []
    for path, (shape, dtype) in flat:
        name = "/".join(str(p.key) for p in path)
        top = str(path[0].key)
        leaf = str(path[-1].key)
        lead_name = top if top.startswith("block") else ""
        out.append(_ns(mesh, _lm_leaf_spec(f"{lead_name}/{leaf}"
                                           if lead_name else leaf, shape, mesh)))
    return jax.tree_util.tree_unflatten(tdef, out)


def lm_batch_sharding(mesh: Mesh, specs: Dict[str, Any]) -> Any:
    dp = dp_axes(mesh)

    def spec_for(k, v):
        if k in ("tokens", "targets", "token"):
            ax = dp if _evenly(v.shape[0], mesh, dp) else None
            return _ns(mesh, P(ax, *([None] * (len(v.shape) - 1))))
        if k == "pos":
            return _ns(mesh, P())
        raise KeyError(k)

    return {k: spec_for(k, v) if k != "cache" else None
            for k, v in specs.items()}


def lm_cache_sharding(mesh: Mesh, cache_tree) -> Any:
    """KV caches: batch->dp, sequence->model (flash-decode style: works for
    any head count, scales KV bandwidth with TP size).

    Stacked-vs-unstacked is decided by the tree path ('block*' subtrees
    carry a leading layer axis, 'prefix*' do not) — shapes alone are
    ambiguous (MLA stacked 4-D == GQA unstacked 4-D)."""
    dp = dp_axes(mesh)
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, x in flat:
        nd = len(x.shape)
        top = str(path[0].key)
        if top.startswith("block"):      # stacked (L, B, S, ...)
            spec = [None, dp, "model"] + [None] * (nd - 3)
        else:                            # (B, S, ...)
            spec = [dp, "model"] + [None] * (nd - 2)
        dims = x.shape
        for i, a in enumerate(spec):
            if a is not None and not _evenly(dims[i], mesh, a):
                spec[i] = None
        out.append(_ns(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(tdef, out)


# ---------------------------------------------------------------------------
# GNN / DLRM
# ---------------------------------------------------------------------------

def gnn_param_sharding(mesh: Mesh, shapes_tree) -> Any:
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], tuple))
    return jax.tree_util.tree_map(lambda x: _ns(mesh, P()), shapes_tree,
                                  is_leaf=is_leaf)


def gnn_batch_sharding(mesh: Mesh, specs: Dict[str, Any]) -> Any:
    axes = all_axes(mesh)

    def leaf(k, v):
        if not hasattr(v, "shape") or len(v.shape) == 0:
            return _ns(mesh, P())
        if _evenly(v.shape[0], mesh, axes):
            return _ns(mesh, P(axes, *([None] * (len(v.shape) - 1))))
        return _ns(mesh, P())

    return {k: leaf(k, v) for k, v in specs.items()}


def dlrm_param_sharding(mesh: Mesh, shapes_tree) -> Any:
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], tuple))
    flat, tdef = jax.tree_util.tree_flatten_with_path(shapes_tree,
                                                      is_leaf=is_leaf)
    out = []
    for path, (shape, dtype) in flat:
        name = str(path[-1].key)
        if name.startswith("table") and _evenly(shape[0], mesh, "model"):
            out.append(_ns(mesh, P("model", None)))
        else:
            out.append(_ns(mesh, P()))
    return jax.tree_util.tree_unflatten(tdef, out)


def dlrm_batch_sharding(mesh: Mesh, specs: Dict[str, Any]) -> Any:
    dp = dp_axes(mesh)

    def leaf(k, v):
        if k == "candidates":
            ax = "model" if _evenly(v.shape[0], mesh, "model") else None
            return _ns(mesh, P(ax, None))
        if len(v.shape) == 0 or not _evenly(v.shape[0], mesh, dp):
            return _ns(mesh, P())
        return _ns(mesh, P(dp, *([None] * (len(v.shape) - 1))))

    return {k: leaf(k, v) for k, v in specs.items()}


# ---------------------------------------------------------------------------
# Boxes: data-parallel execution of the triangle engine's box work-list.
#
# Boxes are overlap-free, independent work items (paper §3.3), so the rule
# is pure data parallelism: a 1-D "boxes" mesh over every device, a greedy
# size-balanced (LPT) assignment of boxes to shards, and a device-major
# edge layout so each shard's slice is one contiguous block.
# ---------------------------------------------------------------------------

def box_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D device mesh with the single axis ``"boxes"``."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("boxes",))


def lpt_order(costs: Sequence[float]) -> List[int]:
    """Box indices in Longest-Processing-Time-first order (descending cost,
    ties broken by index so the order is deterministic).

    This is the shared priority order of both box-parallel paths: the
    shard_map schedule (``balanced_box_schedule`` hands boxes to shards in
    this order) and the async streaming scheduler
    (``core.executor.StreamingExecutor`` drains its work queue in this
    order, so the long-pole box starts first and its device compute
    overlaps every later slice build)."""
    return sorted(range(len(costs)), key=lambda i: (-float(costs[i]), i))


def box_queue_order(costs: Sequence[float],
                    ledger_sensitive: bool) -> List[int]:
    """Priority order a box work-queue is drained in — shared by the
    triangle ``StreamingExecutor`` and the generic ``query.QueryEngine``.

    ``ledger_sensitive=False`` (pure in-memory source): LPT-first — only
    makespan matters, so the long-pole box starts first. With a slice
    cache or a charged block device attached (``ledger_sensitive=True``)
    the queue folds back to plan order: adjacent boxes share row blocks in
    plan order, and because fetches are serialized in queue order this
    keeps the device's LRU frame hits and the cache's hit/miss *sequence*
    identical to the ``workers=1`` oracle (the determinism contract the
    property tests pin).

    The plan-order fallback applies *whenever* a ledger is attached — even
    for a ``workers=1`` caller, where LPT would be equally safe (a serial
    drain IS the oracle in any order). That is deliberate, not an
    oversight: the drain order must be a function of the engine's
    configuration alone, never of its worker count, so a query's measured
    I/O ledger is reproducible across ``workers`` settings and a shard of
    a distributed run (``parallel.fabric``) can be re-executed solo at any
    worker count and land on byte-identical ledgers.
    ``tests/test_sharding.py`` pins both branches as a regression
    contract."""
    if ledger_sensitive:
        return list(range(len(costs)))
    return lpt_order(costs)


# ---------------------------------------------------------------------------
# interval bookkeeping (§5 slice dedup) — shared by the QueryEngine's
# per-box fetch walk and the fabric's rank-r byte-range shipping planner
# ---------------------------------------------------------------------------

def merge_interval(covered: List[Tuple[int, int]], lo: int,
                   hi: int) -> List[Tuple[int, int]]:
    """Insert the inclusive interval [lo, hi] into a sorted disjoint
    interval list, coalescing adjacent/overlapping entries."""
    out: List[Tuple[int, int]] = []
    placed = False
    for a, b in covered:
        if b + 1 < lo:
            out.append((a, b))
        elif hi + 1 < a:
            if not placed:
                out.append((lo, hi))
                placed = True
            out.append((a, b))
        else:
            lo, hi = min(lo, a), max(hi, b)
    if not placed:
        out.append((lo, hi))
    return sorted(out)


def interval_gaps(covered: List[Tuple[int, int]], lo: int,
                  hi: int) -> List[Tuple[int, int]]:
    """Sub-intervals of [lo, hi] not covered yet, ascending."""
    gaps = []
    cur = lo
    for a, b in covered:
        if b < cur:
            continue
        if a > hi:
            break
        if a > cur:
            gaps.append((cur, a - 1))
        cur = max(cur, b + 1)
        if cur > hi:
            break
    if cur <= hi:
        gaps.append((cur, hi))
    return gaps


def box_mass_costs_nd(boxes: Sequence[Tuple[Tuple[int, int], ...]],
                      dim_keys: Sequence[Tuple[int, Sequence[str]]],
                      indptr_by_key: Dict[str, np.ndarray]) -> List[int]:
    """Rank-r generalization of ``box_mass_costs``: per-box slice mass in
    raw CSR words for n-dimensional ``QueryPlan`` boxes, from the resident
    degree indexes alone.

    ``dim_keys`` lists, per *owned* dimension, the distinct relation keys
    whose rows that dimension provisions (``QueryEngine.owned_dim_keys()``
    hands exactly this); ``indptr_by_key`` maps each key to its resident
    (V+1)-word prefix sums. Per box, each key's row intervals are walked
    dimension by dimension with the same §5 interval dedup the engine's
    ``_fetch_box`` / ``_est_box_words`` use, so the cost of a box equals
    the raw words its fetch will actually read — the LPT input of
    ``balanced_box_schedule`` and the shipping mass of
    ``shard_shipped_ranges``. On the triangle plan this reproduces
    ``box_mass_costs`` row for row (minus the one-relation special-casing),
    which ``tests/test_sharding.py`` pins."""
    costs: List[int] = []
    ips = {k: np.asarray(ip, dtype=np.int64) for k, ip in
           indptr_by_key.items()}
    for box in boxes:
        covered: Dict[str, List[Tuple[int, int]]] = {}
        words = 0
        for d, keys in dim_keys:
            lo, hi = box[d]
            for key in keys:
                ip = ips[key]
                lo_, hi_ = max(int(lo), 0), min(int(hi), len(ip) - 2)
                if hi_ < lo_:
                    continue
                for glo, ghi in interval_gaps(covered.get(key, []),
                                              lo_, hi_):
                    words += int(ip[ghi + 1] - ip[glo])
                covered[key] = merge_interval(covered.get(key, []),
                                              lo_, hi_)
        costs.append(words)
    return costs


def shard_shipped_ranges(boxes: Sequence[Tuple[Tuple[int, int], ...]],
                         schedule: Sequence[Sequence[int]],
                         dim_keys: Sequence[Tuple[int, Sequence[str]]],
                         nv_by_key: Dict[str, int]
                         ) -> List[Dict[str, List[Tuple[int, int]]]]:
    """Per-shard byte-range shipping plan: the rank-r generalization of
    ``shard_local_slices`` at the CSR row-interval layer.

    For every shard in ``schedule`` (lists of box ids) and every relation
    key, returns the sorted disjoint list of vertex-row intervals that
    shard's boxes touch through their owned dimensions — exactly the rows
    whose neighbor bytes a ``fabric.ShippedEdgeSource`` must hold for the
    shard to execute its boxes without reaching back to the origin store.
    Nothing is replicated: a row outside every assigned box's owned ranges
    appears in no interval. The union over shards covers every row some
    box touches (shards may overlap where their boxes share rows — slices
    are read-only)."""
    out: List[Dict[str, List[Tuple[int, int]]]] = []
    for box_ids in schedule:
        ranges: Dict[str, List[Tuple[int, int]]] = {}
        for b in box_ids:
            box = boxes[b]
            for d, keys in dim_keys:
                lo, hi = box[d]
                for key in keys:
                    nv = int(nv_by_key[key])
                    lo_, hi_ = max(int(lo), 0), min(int(hi), nv - 1)
                    if hi_ < lo_:
                        continue
                    ranges[key] = merge_interval(ranges.get(key, []),
                                                 lo_, hi_)
        out.append(ranges)
    return out


def box_mass_costs(indptr: np.ndarray,
                   boxes: Sequence[Tuple[int, int, int, int]]) -> List[int]:
    """Per-box *slice mass* (raw CSR words the box's slice provisions),
    computed from the resident degree index alone: the x-slab's neighbor
    words plus the y-range's, with the x/y overlap deduped (§5) — the same
    accounting ``StreamingExecutor._est_slice_words`` uses for its queue
    window. This is the LPT cost the skew-aware scheduler balances on:
    under a heavy/light plan, a one-row hub box carries its true hub mass
    instead of looking as cheap as its edge count."""
    ip = np.asarray(indptr, dtype=np.int64)
    nv = len(ip) - 1
    costs: List[int] = []
    for (lx, hx, ly, hy) in boxes:
        lx_, hx_ = max(int(lx), 0), min(int(hx), nv - 1)
        ly_, hy_ = max(int(ly), 0), min(int(hy), nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            costs.append(0)
            continue
        words = int(ip[hx_ + 1] - ip[lx_])
        for seg_lo, seg_hi in ((ly_, min(hy_, lx_ - 1)),
                               (max(ly_, hx_ + 1), hy_)):
            if seg_hi >= seg_lo:
                words += int(ip[seg_hi + 1] - ip[seg_lo])
        costs.append(words)
    return costs


def balanced_box_schedule(costs: Sequence[float],
                          n_shards: int) -> List[List[int]]:
    """Greedy LPT: assign each box (descending cost) to the least-loaded
    shard. Returns ``n_shards`` lists of box indices. Classic 4/3-OPT
    makespan bound — good enough given per-box costs are themselves
    estimates (in-box edge counts)."""
    shards: List[List[int]] = [[] for _ in range(max(1, n_shards))]
    loads = np.zeros(max(1, n_shards))
    for i in lpt_order(costs):
        s = int(np.argmin(loads))
        shards[s].append(i)
        loads[s] += costs[i]
    return shards


def shard_local_slices(edge_lists: Sequence[Tuple[np.ndarray, np.ndarray]],
                       schedule: Sequence[Sequence[int]],
                       gather,
                       pad_multiple: int = 1):
    """Per-shard *renumbered local* neighbor slices — nothing replicated.

    For every shard, concatenates its boxes' (eu, ev) edges, collects the
    distinct endpoint rows, fetches their neighbor lists via ``gather(rows)
    -> (deg, concat_values)`` (source reads are charged there when the
    graph is store-backed), and builds a box-local padded neighbor matrix.
    Device arrays therefore scale with the shard's slice — rows×K_local —
    instead of the global V×K_max matrix.

    Returns ``(eu, ev, valid, npad, rows)``:

      * ``eu``/``ev``/``valid``: (n_shards, L) local edge endpoints (row ids
        into the shard's slice); padded slots reference the shard's
        all-SENTINEL pad row and carry valid == 0;
      * ``npad``: (n_shards, R, K) per-shard padded neighbor matrices, where
        R = max referenced rows + 1 (pad row) and K = max referenced degree;
      * ``rows``: (n_shards, R) local row id -> global vertex id (-1 pads).
    """
    from repro.core.lftj_jax import SENTINEL

    n_shards = len(schedule)
    per_shard = []
    for boxes in schedule:
        if boxes:
            eu = np.concatenate([edge_lists[b][0] for b in boxes])
            ev = np.concatenate([edge_lists[b][1] for b in boxes])
            rows = np.unique(np.concatenate([eu, ev]))
        else:
            eu = ev = np.zeros(0, np.int64)
            rows = np.zeros(0, np.int64)
        deg, vals = gather(rows)
        per_shard.append((eu, ev, rows, deg, vals))

    R = max([len(rows) for _, _, rows, _, _ in per_shard] + [0]) + 1
    K = max([int(deg.max(initial=1)) for _, _, _, deg, _ in per_shard] + [1])
    lmax = max([len(eu) for eu, _, _, _, _ in per_shard] + [1])
    L = int(-(-lmax // pad_multiple) * pad_multiple)

    npad_s = np.full((n_shards, R, K), SENTINEL, np.int32)
    rows_s = np.full((n_shards, R), -1, np.int64)
    eu_s = np.zeros((n_shards, L), np.int32)
    ev_s = np.zeros((n_shards, L), np.int32)
    ok_s = np.zeros((n_shards, L), np.int32)
    for s, (eu, ev, rows, deg, vals) in enumerate(per_shard):
        pad_row = len(rows)            # all-SENTINEL: intersects to zero
        eu_s[s, :] = pad_row
        ev_s[s, :] = pad_row
        rows_s[s, :len(rows)] = rows
        if len(rows):
            rr = np.repeat(np.arange(len(rows)), deg)
            cc = np.arange(int(deg.sum())) \
                - np.repeat(np.cumsum(deg) - deg, deg)
            npad_s[s, rr, cc] = vals
        if len(eu):
            eu_s[s, :len(eu)] = np.searchsorted(rows, eu)
            ev_s[s, :len(ev)] = np.searchsorted(rows, ev)
            ok_s[s, :len(eu)] = 1
    return eu_s, ev_s, ok_s, npad_s, rows_s


# ---------------------------------------------------------------------------
# generic helpers
# ---------------------------------------------------------------------------

def replicate(mesh: Mesh, tree) -> Any:
    return jax.tree_util.tree_map(lambda _: _ns(mesh, P()), tree)


def like_tree(sharding_tree, template_tree) -> Any:
    """Re-key a sharding tree onto an identically-structured template."""
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template_tree),
        jax.tree_util.tree_leaves(sharding_tree))


def opt_state_sharding(param_sharding, opt_state_tree):
    """Moments shard like params; the step counter is replicated."""
    from repro.optim.adamw import OptState
    m = jax.tree_util.tree_map(lambda s: s, param_sharding)
    first = jax.tree_util.tree_leaves(param_sharding)[0]
    rep = NamedSharding(first.mesh, P())
    return OptState(step=rep, m=m, v=m)
