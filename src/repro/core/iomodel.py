"""Block-granular I/O cost model (paper §1 'Model & Assumptions').

The container is CPU-only, so instead of timing a disk we *count* block I/Os
in the paper's own model: data lives on a virtual block device with block
size ``B`` words; an access to a word not resident in the ``M/B``-frame
cache costs one I/O; the replacement policy is LRU (what Prop. 4's
adversarial construction targets).

numpy views share memory with their base buffer, so registering the *base*
array by data pointer makes every slice/view alias the correct device
blocks automatically — provisioning reads of a TrieArraySlice are charged to
the region of the source TrieArray, exactly like a DMA from disk.

Thread safety: the async box scheduler (``core.executor``) charges reads and
output writes from several worker threads against ONE shared device, so all
accounting entry points (``register`` / ``touch`` / ``read_range`` /
``write_words`` / ``serve_from_cache``) serialize on an internal lock — the
``IOStats`` counters and the LRU frame list never tear under concurrency.
The lock is uncontended in single-threaded runs (scalar LFTJ probing pays
one fast acquire per ``touch``).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np


def _nd_base(arr: np.ndarray) -> np.ndarray:
    """Outermost *ndarray* owning the buffer. An ``np.memmap``'s base chain
    bottoms out in a raw ``mmap.mmap`` (no array interface), so the walk
    stops at the last ndarray — views of plain arrays and of memmaps alike
    resolve to one canonical base."""
    base = arr
    while isinstance(base.base, np.ndarray):
        base = base.base
    return base


@dataclass
class IOStats:
    block_reads: int = 0
    block_writes: int = 0
    word_reads: int = 0
    probes: int = 0
    # words a host-side cache above the device served *without* issuing a
    # read (core.executor.SliceCache hits) — the device's counters stay
    # honest, and the saved traffic is still visible in one place
    cache_served_words: int = 0

    def reset(self):
        self.block_reads = self.block_writes = self.word_reads = self.probes = 0
        self.cache_served_words = 0


class BlockDevice:
    """Virtual block device + LRU buffer cache, counting block I/Os."""

    def __init__(self, block_words: int = 4096, cache_blocks: int = 1024):
        self.B = int(block_words)
        self.cache_blocks = int(cache_blocks)
        self._regions = {}          # base data ptr -> (start_word, n_words, itemsize)
        self._next_word = 0
        self._cache: OrderedDict = OrderedDict()  # block id -> True
        self.stats = IOStats()
        # all accounting serializes here: concurrent slice builders and
        # listing writers share one device ledger (see module docstring)
        self._lock = threading.Lock()

    # -- registration -------------------------------------------------------

    def register(self, arr: np.ndarray) -> None:
        base = _nd_base(arr)
        ptr = base.__array_interface__["data"][0]
        with self._lock:
            if ptr in self._regions:
                return
            n_words = base.size
            self._regions[ptr] = (self._next_word, n_words, base.itemsize)
            # round region starts to block boundaries (file layout)
            self._next_word += n_words
            self._next_word = ((self._next_word + self.B - 1) // self.B) * self.B

    def register_triearray(self, ta) -> None:
        for a in list(ta.val) + list(ta.idx):
            if len(a):
                self.register(a)

    def _word_addr(self, arr: np.ndarray, i: int) -> int:
        base = _nd_base(arr)
        bptr = base.__array_interface__["data"][0]
        start, n, itemsize = self._regions[bptr]
        off_bytes = arr.__array_interface__["data"][0] - bptr
        return start + off_bytes // itemsize + i

    # -- accounting ---------------------------------------------------------

    def _touch_block(self, blk: int) -> None:
        cache = self._cache
        if blk in cache:
            cache.move_to_end(blk)
            return
        self.stats.block_reads += 1
        cache[blk] = True
        if len(cache) > self.cache_blocks:
            cache.popitem(last=False)

    def touch(self, arr: np.ndarray, i: int) -> None:
        """Random access to element i of a registered (view of an) array."""
        with self._lock:
            self.stats.word_reads += 1
            self._touch_block(self._word_addr(arr, i) // self.B)

    def read_range(self, arr: np.ndarray, lo: int, hi: int) -> None:
        """Sequential read of arr[lo:hi] (slice provisioning DMA)."""
        if hi <= lo:
            return
        with self._lock:
            a = self._word_addr(arr, lo) // self.B
            b = self._word_addr(arr, hi - 1) // self.B
            for blk in range(a, b + 1):
                self._touch_block(blk)
            self.stats.word_reads += hi - lo

    def write_words(self, n_words: int) -> None:
        """Append-only output stream (counts ceil(n/B) over time)."""
        with self._lock:
            self.stats.block_writes += (n_words + self.B - 1) // self.B

    def serve_from_cache(self, n_words: int) -> None:
        """Record ``n_words`` served by a cache layer above the device —
        traffic that would have been ``read_range`` calls without it."""
        with self._lock:
            self.stats.cache_served_words += n_words

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()


class CountingReader:
    """Accessor handed to TrieIterators: reads an element, charging the device.

    ``None`` device = pure in-memory execution (no accounting).
    """

    def __init__(self, device: BlockDevice | None = None):
        self.device = device

    def get(self, arr: np.ndarray, i: int):
        if self.device is not None:
            self.device.touch(arr, i)
        return int(arr[i])
