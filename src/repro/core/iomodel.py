"""Block-granular I/O cost model (paper §1 'Model & Assumptions').

The container is CPU-only, so instead of timing a disk we *count* block I/Os
in the paper's own model: data lives on a virtual block device with block
size ``B`` words; an access to a word not resident in the ``M/B``-frame
cache costs one I/O; the replacement policy is LRU (what Prop. 4's
adversarial construction targets).

numpy views share memory with their base buffer, so registering the *base*
array by data pointer makes every slice/view alias the correct device
blocks automatically — provisioning reads of a TrieArraySlice are charged to
the region of the source TrieArray, exactly like a DMA from disk.

Thread safety: the async box scheduler (``core.executor``) charges reads and
output writes from several worker threads against ONE shared device, so all
accounting entry points (``register`` / ``touch`` / ``read_range`` /
``write_words`` / ``serve_from_cache``) serialize on an internal lock — the
``IOStats`` counters and the LRU frame list never tear under concurrency.
The lock is uncontended in single-threaded runs (scalar LFTJ probing pays
one fast acquire per ``touch``).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np


def _nd_base(arr: np.ndarray) -> np.ndarray:
    """Outermost *ndarray* owning the buffer. An ``np.memmap``'s base chain
    bottoms out in a raw ``mmap.mmap`` (no array interface), so the walk
    stops at the last ndarray — views of plain arrays and of memmaps alike
    resolve to one canonical base."""
    base = arr
    while isinstance(base.base, np.ndarray):
        base = base.base
    return base


@dataclass
class IOStats:
    block_reads: int = 0
    block_writes: int = 0
    word_reads: int = 0
    probes: int = 0
    # words a host-side cache above the device served *without* issuing a
    # read (core.executor.SliceCache hits) — the device's counters stay
    # honest, and the saved traffic is still visible in one place
    cache_served_words: int = 0

    def reset(self):
        self.block_reads = self.block_writes = self.word_reads = self.probes = 0
        self.cache_served_words = 0


class BlockDevice:
    """Virtual block device + LRU buffer cache, counting block I/Os.

    **Tagged attribution (the serving layer's partitioned-memory model).**
    ``open_tag(tag, cache_blocks=k)`` creates a *partition*: its own
    ``k``-frame LRU and its own ``IOStats``. While a thread runs inside
    ``with device.attributed(tag):`` every access it issues consults the
    tag's private frames (not the shared ones) and is charged to *both*
    the tag's stats and the global ``stats`` — so N concurrent queries
    each see exactly the frame behaviour of a solo run with ``m_i/B``
    frames (Pagh & Silvestri's bound applied per partition of M), while
    the global ledger stays the plain sum over partitions. Attribution is
    thread-local: each query's worker threads tag their own reads against
    one shared device without interfering.
    """

    def __init__(self, block_words: int = 4096, cache_blocks: int = 1024):
        self.B = int(block_words)
        self.cache_blocks = int(cache_blocks)
        self._regions = {}          # base data ptr -> (start_word, n_words, itemsize)
        self._next_word = 0
        self._cache: OrderedDict = OrderedDict()  # block id -> True
        self.stats = IOStats()
        # per-tag partitions: tag -> (frame OrderedDict, frame budget, stats)
        self._tags: dict = {}
        self._tls = threading.local()
        # all accounting serializes here: concurrent slice builders and
        # listing writers share one device ledger (see module docstring)
        self._lock = threading.Lock()

    # -- tagged attribution --------------------------------------------------

    def open_tag(self, tag, cache_blocks: int) -> None:
        """Create (or resize) the ``tag`` partition: a private LRU of
        ``cache_blocks`` frames plus a private ``IOStats`` ledger."""
        with self._lock:
            if tag in self._tags:
                frames, _, stats = self._tags[tag]
                self._tags[tag] = (frames, max(1, int(cache_blocks)), stats)
            else:
                self._tags[tag] = (OrderedDict(), max(1, int(cache_blocks)),
                                   IOStats())

    def close_tag(self, tag) -> IOStats:
        """Drop the partition's frames; its final stats are returned (and
        remain readable via ``tag_stats`` until the tag is reopened)."""
        with self._lock:
            frames, budget, stats = self._tags.get(
                tag, (OrderedDict(), 1, IOStats()))
            self._tags[tag] = (OrderedDict(), 0, stats)
            return stats

    def tag_stats(self, tag) -> IOStats:
        with self._lock:
            if tag not in self._tags:
                self._tags[tag] = (OrderedDict(), 1, IOStats())
            return self._tags[tag][2]

    def all_tag_stats(self) -> dict:
        """Every tag partition's ``IOStats`` (closed tags included —
        ``close_tag`` keeps the ledger readable). The observability
        registry mirrors this into ``io.*{tag=...}`` series; per-tag
        counters sum to ``stats`` minus whatever ran unattributed."""
        with self._lock:
            return {tag: ent[2] for tag, ent in self._tags.items()}

    @contextmanager
    def attributed(self, tag):
        """Attribute this thread's accesses to ``tag`` (nestable; restores
        the previous tag on exit). The tag must have been ``open_tag``-ed
        for its partition frames to apply; an unknown tag only accumulates
        stats."""
        prev = getattr(self._tls, "tag", None)
        self._tls.tag = tag
        try:
            yield
        finally:
            self._tls.tag = prev

    # -- registration -------------------------------------------------------

    def register(self, arr: np.ndarray) -> None:
        base = _nd_base(arr)
        ptr = base.__array_interface__["data"][0]
        with self._lock:
            if ptr in self._regions:
                return
            n_words = base.size
            self._regions[ptr] = (self._next_word, n_words, base.itemsize)
            # round region starts to block boundaries (file layout)
            self._next_word += n_words
            self._next_word = ((self._next_word + self.B - 1) // self.B) * self.B

    def register_triearray(self, ta) -> None:
        for a in list(ta.val) + list(ta.idx):
            if len(a):
                self.register(a)

    def _word_addr(self, arr: np.ndarray, i: int) -> int:
        base = _nd_base(arr)
        bptr = base.__array_interface__["data"][0]
        start, n, itemsize = self._regions[bptr]
        off_bytes = arr.__array_interface__["data"][0] - bptr
        return start + off_bytes // itemsize + i

    # -- accounting ---------------------------------------------------------

    def _tag_entry(self):
        """(frames, budget, stats) of this thread's active tag partition,
        or ``None`` when untagged / the tag has no partition."""
        tag = getattr(self._tls, "tag", None)
        if tag is None:
            return None
        ent = self._tags.get(tag)
        if ent is None or ent[1] <= 0:
            return None
        return ent

    def _touch_block(self, blk: int) -> None:
        ent = self._tag_entry()
        if ent is not None:
            frames, budget, stats = ent
            if blk in frames:
                frames.move_to_end(blk)
                return
            stats.block_reads += 1
            self.stats.block_reads += 1
            frames[blk] = True
            if len(frames) > budget:
                frames.popitem(last=False)
            return
        cache = self._cache
        if blk in cache:
            cache.move_to_end(blk)
            return
        self.stats.block_reads += 1
        cache[blk] = True
        if len(cache) > self.cache_blocks:
            cache.popitem(last=False)

    def _tag_words(self, n: int) -> None:
        ent = self._tag_entry()
        if ent is not None:
            ent[2].word_reads += n

    def touch(self, arr: np.ndarray, i: int) -> None:
        """Random access to element i of a registered (view of an) array."""
        with self._lock:
            self.stats.word_reads += 1
            self._tag_words(1)
            self._touch_block(self._word_addr(arr, i) // self.B)

    def read_range(self, arr: np.ndarray, lo: int, hi: int) -> None:
        """Sequential read of arr[lo:hi] (slice provisioning DMA)."""
        if hi <= lo:
            return
        with self._lock:
            a = self._word_addr(arr, lo) // self.B
            b = self._word_addr(arr, hi - 1) // self.B
            for blk in range(a, b + 1):
                self._touch_block(blk)
            self.stats.word_reads += hi - lo
            self._tag_words(hi - lo)

    def write_words(self, n_words: int) -> None:
        """Append-only output stream (counts ceil(n/B) over time)."""
        blocks = (n_words + self.B - 1) // self.B
        with self._lock:
            self.stats.block_writes += blocks
            ent = self._tag_entry()
            if ent is not None:
                ent[2].block_writes += blocks

    def serve_from_cache(self, n_words: int) -> None:
        """Record ``n_words`` served by a cache layer above the device —
        traffic that would have been ``read_range`` calls without it."""
        with self._lock:
            self.stats.cache_served_words += n_words
            ent = self._tag_entry()
            if ent is not None:
                ent[2].cache_served_words += n_words

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()


class CountingReader:
    """Accessor handed to TrieIterators: reads an element, charging the device.

    ``None`` device = pure in-memory execution (no accounting).
    """

    def __init__(self, device: BlockDevice | None = None):
        self.device = device

    def get(self, arr: np.ndarray, i: int):
        if self.device is not None:
            self.device.touch(arr, i)
        return int(arr[i])
