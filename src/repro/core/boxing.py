"""Boxing for LFTJ (paper §3, Algorithm 2).

Partitions the n-dimensional variable search space into boxes whose
provisioned TrieArraySlices fit a memory budget, then runs in-memory LFTJ
per box. Faithful to Algorithm 2 including:

  * per-dimension probe -> provision -> recurse loop,
  * budget split across dimensions that own atoms (paper §5: no budget for
    dimensions with no atom having x_j as first variable; configurable
    ratios, default 4:1 for the triangle query's x:y as in §5),
  * leftoverMem pass-down,
  * slice dedup for atoms sharing (relation, first variable) (§5),
  * SPILL handling: a value whose single-value slice exceeds its budget pins
    the box at that value and defers the atom to the dimension of its next
    variable (§3.3 "General joins"); deferral is sound because a full
    conjunctive query has no results where the spilling atom has no data,
  * monotone pruning hook (§5: skip provisioning boxes that provably cannot
    contain results, e.g. x < y < z for the triangle query),
  * block-I/O accounting on a simulated device (core.iomodel) validating
    Thm. 10 / Thm. 13 / Cor. 15.

TPU mapping: each box is independent (boxes partition the search space), so
the box list produced by ``plan_boxes`` is exactly the work-list that
``repro.parallel`` shards over the device mesh.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from .iomodel import BlockDevice, CountingReader
from .leapfrog import Atom, LeapfrogTriejoin
from .triearray import SPILL, TrieArray, TrieArraySlice

INF = float("inf")


@dataclass
class BoxingConfig:
    mem_words: int                       # available memory M (words)
    block_words: int = 4096              # B
    dim_ratio: Optional[dict] = None     # var -> relative budget weight
    monotone_prune: bool = False         # x<y<z style pruning (triangle DAG)
    count_only: bool = True


@dataclass
class BoxStats:
    n_boxes: int = 0
    n_spills: int = 0
    provisioned_words: int = 0
    probe_ios: int = 0
    results: int = 0
    max_box_words: int = 0


@dataclass
class _Pending:
    """An atom waiting to be provisioned at dimension ``dim``.

    ``prefix`` holds values already bound for the atom's leading variables
    (non-empty only after spills). ``vars_left`` are the atom's unbound
    variables, the first of which is ``var_order[dim]``.
    """

    atom: Atom
    rel: TrieArray
    prefix: tuple
    vars_left: tuple
    atom_id: int


class BoxedLFTJ:
    """Algorithm 2. ``relations``: name -> TrieArray on 'secondary storage'."""

    def __init__(self, atoms: Sequence[Atom], var_order: Sequence[str],
                 relations: dict, config: BoxingConfig,
                 device: Optional[BlockDevice] = None,
                 emit: Optional[Callable] = None,
                 prune: Optional[Callable] = None):
        self.atoms = list(atoms)
        self.var_order = list(var_order)
        self.relations = relations
        self.cfg = config
        self.device = device
        self.emit = emit
        self.prune = prune  # prune(low, high) -> True to skip the box
        self.stats = BoxStats()
        self.n = len(self.var_order)
        if device is not None:
            for ta in relations.values():
                device.register_triearray(ta)

        # group atoms by the dimension of their first variable
        self._initial: list = [[] for _ in range(self.n)]
        for aid, a in enumerate(self.atoms):
            d = self.var_order.index(a.vars[0])
            self._initial[d].append(
                _Pending(a, relations[a.rel], (), tuple(a.vars), aid))

        # budget weights (paper §5): only dims owning atoms get budget
        ratio = config.dim_ratio or {}
        weights = []
        for d in range(self.n):
            if self._initial[d]:
                weights.append(ratio.get(self.var_order[d], 1.0))
            else:
                weights.append(0.0)
        wsum = sum(weights) or 1.0
        self.budget = [int(config.mem_words * w / wsum) for w in weights]

    # -- probing helpers -----------------------------------------------------

    def _probe_reader(self):
        """Reader charging probe touches on the device (Prop. 8 honest cost:
        the binary-search path; upper levels stay LRU-cached)."""
        if self.device is None:
            return None
        from .iomodel import CountingReader
        return CountingReader(self.device)

    def _charge_probe(self, rel: TrieArray) -> None:
        self.stats.probe_ios += 1

    def _charge_provision(self, slc: TrieArraySlice) -> None:
        self.stats.provisioned_words += slc.words_loaded
        if self.device is not None:
            for arr in list(slc.val) + list(slc.idx):
                if len(arr):
                    self.device.read_range(arr, 0, len(arr))

    # -- the recursion (BoxUp) ------------------------------------------------

    def run(self) -> int:
        pend0 = {d: list(self._initial[d]) for d in range(self.n)}
        self._box_up(0, 0, {}, pend0, {})
        return self.stats.results

    def _box_up(self, dim: int, leftover: int, low_high: dict,
                pending: dict, slices: dict) -> None:
        """Iterate boxes along ``dim``; recurse; run LFTJ at the last dim."""
        if dim == self.n:
            self._run_box(low_high, slices)
            return
        atms = pending.get(dim, [])
        if not atms:
            # no atom owns this dim: single unbounded box along it
            lh = dict(low_high)
            lh[dim] = (-INF, INF)
            self._box_up(dim + 1, leftover, lh, pending, slices)
            return

        mem = self.budget[dim] + leftover
        per_atom = max(1, mem // max(1, len(atms)))
        low = -np.iinfo(np.int64).max
        while True:
            # ---- probe all atoms owned by this dim (Alg. 2 line 12)
            plan = []   # (pending, h_or_SPILL, first_val)
            rd = self._probe_reader()
            for p in atms:
                self._charge_probe(p.rel)
                res, _w = p.rel.probe(p.prefix, low, per_atom, reader=rd)
                first = self._first_value(p.rel, p.prefix, low)
                plan.append((p, res, first))
            if all(first is None for _p, _r, first in plan):
                break  # no atom has data >= low: dimension exhausted

            spills = [(p, first) for p, r, first in plan
                      if r == SPILL and first is not None]
            if not spills:
                hs = [r for _p, r, _f in plan if r != SPILL]
                high = min(hs) if hs else INF
                self._emit_boxes_normal(dim, low, high, plan, leftover,
                                        low_high, pending, slices, mem)
                if high == INF or high == np.inf:
                    break
                low = int(high) + 1
            else:
                pin = min(first for _p, first in spills)
                self.stats.n_spills += 1
                ok = self._emit_box_pinned(dim, pin, atms, per_atom, leftover,
                                           low_high, pending, slices, mem)
                low = pin + 1
                del ok

    @staticmethod
    def _first_value(rel: TrieArray, prefix: tuple, low):
        rng = rel._locate_prefix(prefix)
        if rng is None:
            return None
        lo, hi = rng
        arr = rel.val[len(prefix)]
        a = lo + int(np.searchsorted(arr[lo:hi], low, side="left"))
        if a >= hi:
            return None
        return int(arr[a])

    def _emit_boxes_normal(self, dim, low, high, plan, leftover,
                           low_high, pending, slices, mem) -> None:
        lh = dict(low_high)
        lh[dim] = (low, high)
        if self.prune is not None and self.prune(self.var_order, lh):
            return
        used = 0
        new_slices = dict(slices)
        owner = {}  # dedup (§5): same (rel, prefix) at this dim => one slice
        for p, _r, first in plan:
            key = (id(p.rel), p.prefix)
            if key in owner:
                # share the slice object but keep THIS atom's variable tuple
                new_slices[p.atom_id] = (new_slices[owner[key]][0], p)
                continue
            hi = np.iinfo(np.int64).max if high in (INF, np.inf) else int(high)
            slc = p.rel.make_slice(p.prefix, low, hi)
            self._charge_provision(slc)
            used += slc.words_loaded
            new_slices[p.atom_id] = (slc, p)
            owner[key] = p.atom_id
        self.stats.max_box_words = max(self.stats.max_box_words, used)
        self._box_up(dim + 1, max(0, mem - used), lh, pending, new_slices)

    def _emit_box_pinned(self, dim, pin, atms, per_atom, leftover,
                         low_high, pending, slices, mem) -> bool:
        """Box pinned at x_dim == pin; defer oversized atoms (spill path)."""
        lh = dict(low_high)
        lh[dim] = (pin, pin)
        if self.prune is not None and self.prune(self.var_order, lh):
            return True
        new_slices = dict(slices)
        new_pending = {d: list(v) for d, v in pending.items()}
        new_pending[dim] = []
        used = 0
        rd = self._probe_reader()
        for p in atms:
            self._charge_probe(p.rel)
            res, w = p.rel.probe(p.prefix, pin, per_atom, reader=rd)
            first = self._first_value(p.rel, p.prefix, pin)
            if first is None or first != pin:
                return True  # this atom has no data at pin -> box empty, skip
            if res == SPILL:
                # defer to the dimension of the atom's next variable
                rest = p.vars_left[1:]
                if not rest:
                    # unary relation spilling cannot happen (single value is
                    # one word); guard anyway
                    continue
                tgt = self.var_order.index(rest[0])
                q = _Pending(p.atom, p.rel, p.prefix + (pin,), rest, p.atom_id)
                new_pending.setdefault(tgt, []).append(q)
                new_slices[p.atom_id] = ("DEFERRED", q)
            else:
                slc = p.rel.make_slice(p.prefix, pin, pin)
                self._charge_provision(slc)
                used += slc.words_loaded
                new_slices[p.atom_id] = (slc, p)
        self.stats.max_box_words = max(self.stats.max_box_words, used)
        self._box_up(dim + 1, max(0, mem - used), lh, new_pending, new_slices)
        return False

    # -- leaf: run in-memory LFTJ on the box's slices -------------------------

    def _run_box(self, low_high: dict, slices: dict) -> None:
        self.stats.n_boxes += 1
        atoms, rels = [], {}
        pinned_vars = {}
        for aid, a in enumerate(self.atoms):
            entry = slices.get(aid)
            if entry is None or entry[0] == "DEFERRED":
                return  # defensive: nothing provisioned => treat as empty box
            slc, p = entry
            vars_left = p.vars_left
            name = f"{a.rel}#{aid}"
            rels[name] = slc
            atoms.append(Atom(name, tuple(vars_left)))
            for v, val in zip(a.vars, p.prefix):
                pinned_vars[v] = val
        # variables pinned by spills participate via 1-tuple constant atoms
        for v, val in pinned_vars.items():
            name = f"__pin_{v}"
            rels[name] = TrieArray.from_tuples(np.asarray([[val]]))
            atoms.append(Atom(name, (v,)))
        order = [v for v in self.var_order
                 if any(v in a.vars for a in atoms)]
        if len(order) != self.n:
            return  # some variable wholly unconstrained in this box: no atoms
        if any(len(r.val[0]) == 0 for r in rels.values()):
            return  # an empty slice: box has no results
        j = LeapfrogTriejoin(atoms, order, rels)
        emitted = []

        def _emit(t):
            if self.emit is not None:
                self.emit(t)
            if self.device is not None:
                emitted.append(t)

        cnt = j.run(emit=_emit if (self.emit or self.device) else None)
        self.stats.results += cnt
        if self.device is not None:
            self.device.write_words(3 * cnt)


def plan_boxes(edges_ta: TrieArray, mem_words: int,
               ratio_xy: float = 4.0, monotone_prune: bool = True) -> list:
    """Triangle-query box plan [(lx,hx,ly,hy)] without running LFTJ.

    This is the host-side planner the distributed triangle engine shards over
    devices: boxes are independent work items (§3.3: the partitioning is
    overlap-free). ``monotone_prune`` drops boxes with hy < lx, which is
    sound only when every oriented edge has x < y numerically (the minmax
    orientation); pass False for orientations that break that invariant
    (e.g. 'degree').
    """
    boxes = []
    n_max = np.iinfo(np.int64).max
    bx = int(mem_words * ratio_xy / (1 + ratio_xy))
    by = max(1, mem_words - bx)
    lx = -n_max
    while True:
        hx, _ = edges_ta.probe((), lx, max(1, bx))
        if hx == SPILL:
            first = BoxedLFTJ._first_value(edges_ta, (), lx)
            hx = first  # pinned box (degenerate; no deferral needed for plan)
        fv = BoxedLFTJ._first_value(edges_ta, (), lx)
        if fv is None:
            break
        hx_i = n_max if hx in (INF, np.inf) else int(hx)
        ly = -n_max
        while True:
            hy, _ = edges_ta.probe((), ly, max(1, by))
            if hy == SPILL:
                hy = BoxedLFTJ._first_value(edges_ta, (), ly)
            fy = BoxedLFTJ._first_value(edges_ta, (), ly)
            if fy is None:
                break
            hy_i = n_max if hy in (INF, np.inf) else int(hy)
            if hy_i >= lx or not monotone_prune:
                boxes.append((lx, hx_i, ly, hy_i))
            if hy_i == n_max:
                break
            ly = hy_i + 1
        if hx_i == n_max:
            break
        lx = hx_i + 1
    return boxes


def _greedy_degree_cuts(cost: np.ndarray, budget: int) -> list:
    """Contiguous row ranges [(lo, hi)] with Σ cost ≤ budget each.

    The degree-prefix-sum analogue of ``TrieArray.probe``: ranges grow until
    the next row would overflow the budget; a single row whose cost exceeds
    the budget becomes its own pinned range (the plan-level spill, matching
    ``plan_boxes``). Zero-cost rows are absorbed for free, so the ranges
    always cover [0, n)."""
    n = len(cost)
    cum = np.concatenate([[0], np.cumsum(cost, dtype=np.int64)])
    cuts = []
    lo = 0
    while lo < n:
        # largest hi with cum[hi+1] - cum[lo] <= budget
        hi = int(np.searchsorted(cum, cum[lo] + budget, side="right")) - 2
        hi = max(hi, lo)  # pinned row when a single row overflows
        cuts.append((lo, hi))
        lo = hi + 1
    if not cuts:
        cuts = [(0, max(0, n - 1))]
    return cuts


# public name: the generic n-dimensional planner (repro.query.planner) cuts
# every owned dimension of a conjunctive query with the same primitive the
# triangle plan uses, so its 2-D special case reproduces plan_boxes_from_
# degrees cut for cut (the I/O-parity contract the query tests pin).
greedy_degree_cuts = _greedy_degree_cuts


def plan_boxes_from_degrees(indptr: np.ndarray, mem_words: int,
                            ratio_xy: float = 4.0,
                            monotone_prune: bool = True,
                            row_overhead: int = 2) -> list:
    """Triangle-query box plan from the resident degree index alone.

    The out-of-core analogue of ``plan_boxes``: instead of probing a
    TrieArray (which requires the whole relation in memory), the plan is
    derived from the (V+1)-word ``indptr`` prefix sums — the only structure
    the streaming engine keeps resident. Slice cost per present row is
    ``deg + row_overhead`` words, mirroring ``TrieArray.slice_words``
    (values + idx entries). Budget split and hy < lx pruning follow §5.
    """
    nv = len(indptr) - 1
    if nv <= 0:
        return []
    deg = np.diff(np.asarray(indptr, dtype=np.int64))
    cost = np.where(deg > 0, deg + row_overhead, 0)
    if int(cost.sum()) <= mem_words:
        return [(0, nv - 1, 0, nv - 1)]
    bx = max(1, int(mem_words * ratio_xy / (1 + ratio_xy)))
    by = max(1, mem_words - bx)
    xcuts = _greedy_degree_cuts(cost, bx)
    ycuts = _greedy_degree_cuts(cost, by)
    boxes = []
    for lx, hx in xcuts:
        for ly, hy in ycuts:
            if hy >= lx or not monotone_prune:
                boxes.append((lx, hx, ly, hy))
    return boxes


# ---------------------------------------------------------------------------
# skew-resistant planning: heavy/light decomposition ("Skew Strikes Back")
# ---------------------------------------------------------------------------

def heavy_threshold_default(total_degree: int) -> int:
    """Default hub threshold: deg >= sqrt(2·|E|) (the √E-style split of
    worst-case-optimal join analyses; ``total_degree`` is Σ deg = |E| for
    an oriented CSR)."""
    return max(2, int(math.isqrt(max(0, 2 * int(total_degree)))))


def classify_heavy(indptr: np.ndarray,
                   threshold: Optional[int] = None
                   ) -> tuple[np.ndarray, int]:
    """(heavy mask, threshold) from a resident degree index.

    A vertex is *heavy* (a hub) when its out-degree reaches the threshold
    (default ``heavy_threshold_default``); everything else — including
    zero-degree rows — is light.
    """
    deg = np.diff(np.asarray(indptr, dtype=np.int64))
    thr = heavy_threshold_default(int(deg.sum())) if threshold is None \
        else max(1, int(threshold))
    return deg >= thr, thr


def class_cuts(cost: np.ndarray, budget: int,
               heavy: np.ndarray) -> list:
    """``greedy_degree_cuts`` that never mixes heavy and light rows.

    Returns ``[(lo, hi, is_heavy)]``: the same contiguous mass-budgeted
    ranges as the uniform cutter, with an additional break at every
    heavy/light class transition so each range is pure-class. Zero-cost
    rows carry no class (they are absorbed free into whichever range they
    fall in), so an isolated hub between absent rows still gets its own
    pinned range without fragmenting the plan.
    """
    n = len(cost)
    if n == 0:
        return []
    cls = np.where(np.asarray(heavy, dtype=bool), 1, 0)
    wild = np.asarray(cost) == 0
    real = np.flatnonzero(~wild)
    if len(real) == 0:
        return [(0, n - 1, False)]
    # forward-fill the wildcard rows with the previous real class (head
    # rows take the first real class), so runs break only on real changes
    last_real = np.maximum.accumulate(np.where(~wild, np.arange(n), -1))
    filled = np.where(last_real >= 0, cls[np.maximum(last_real, 0)],
                      cls[real[0]])
    breaks = np.flatnonzero(np.diff(filled) != 0) + 1
    bounds = np.concatenate([[0], breaks, [n]])
    cuts = []
    for b0, b1 in zip(bounds[:-1], bounds[1:]):
        is_h = bool(filled[b0])
        for lo, hi in _greedy_degree_cuts(cost[b0:b1], budget):
            cuts.append((int(b0 + lo), int(b0 + hi), is_h))
    return cuts


def _pair_lane(x_heavy: Optional[bool], y_heavy: Optional[bool]) -> str:
    if x_heavy and y_heavy:
        return "hub"
    if x_heavy is False and y_heavy is False:
        return "light"
    return "mixed"


@dataclass
class SkewPlan:
    """A heavy/light box plan plus its per-box lane metadata.

    ``lanes[i]`` classifies ``boxes[i]``: ``"hub"`` (both ranges heavy —
    near-dense by construction, routed to the dense/Pallas lanes),
    ``"light"`` (both ranges light — routed to the host searchsorted lane,
    which never materializes a padded matrix), or ``"mixed"``.
    """

    boxes: list = field(default_factory=list)
    lanes: list = field(default_factory=list)
    threshold: int = 0
    n_heavy: int = 0

    def lane_of(self, box) -> Optional[str]:
        try:
            return self.lanes[self.boxes.index(box)]
        except ValueError:
            return None


def plan_boxes_heavy_light(indptr: np.ndarray,
                           mem_words: Optional[int],
                           ratio_xy: float = 4.0,
                           monotone_prune: bool = True,
                           row_overhead: int = 2,
                           heavy_threshold: Optional[int] = None) -> SkewPlan:
    """Skew-resistant triangle box plan (``skew="heavy_light"``).

    Same contract as ``plan_boxes_from_degrees`` — contiguous
    ``(lx, hx, ly, hy)`` boxes partitioning the oriented edge set, sized by
    actual slice mass (Σ deg + overhead ≤ budget per range) — but every cut
    additionally breaks at heavy/light class transitions
    (``classify_heavy``), so each box is pure hub-hub, pure light-light, or
    a hub×light mixture, and the per-box lane is known at plan time. Hubs
    whose single row overflows the budget become pinned ranges exactly as
    in the uniform planner (the plan-level spill).
    """
    nv = len(indptr) - 1
    if nv <= 0:
        return SkewPlan()
    deg = np.diff(np.asarray(indptr, dtype=np.int64))
    heavy, thr = classify_heavy(indptr, heavy_threshold)
    n_heavy = int(heavy.sum())
    cost = np.where(deg > 0, deg + row_overhead, 0)
    if mem_words is None or int(cost.sum()) <= mem_words:
        any_h, any_l = n_heavy > 0, bool((~heavy[deg > 0]).any())
        lane = _pair_lane(any_h and not any_l, any_h and not any_l) \
            if not (any_h and any_l) else "mixed"
        return SkewPlan(boxes=[(0, nv - 1, 0, nv - 1)], lanes=[lane],
                        threshold=thr, n_heavy=n_heavy)
    bx = max(1, int(mem_words * ratio_xy / (1 + ratio_xy)))
    by = max(1, mem_words - bx)
    xcuts = class_cuts(cost, bx, heavy)
    ycuts = class_cuts(cost, by, heavy)
    plan = SkewPlan(threshold=thr, n_heavy=n_heavy)
    for lx, hx, xh in xcuts:
        for ly, hy, yh in ycuts:
            if hy >= lx or not monotone_prune:
                plan.boxes.append((lx, hx, ly, hy))
                plan.lanes.append(_pair_lane(xh, yh))
    return plan


def boxed_triangle_count(edges_ta: TrieArray, mem_words: int,
                         block_words: int = 4096,
                         device: Optional[BlockDevice] = None,
                         emit: Optional[Callable] = None,
                         monotone_prune: bool = True):
    """Boxed LFTJ-Δ (paper §4.1). Returns (count, BoxStats)."""
    from .leapfrog import triangle_query_atoms

    def prune(var_order, lh):
        # x < y < z in the DAG orientation: a box with hy < lx is empty (§5)
        if not monotone_prune:
            return False
        if 0 in lh and 1 in lh:
            _lx, _hx = lh[0]
            _ly, _hy = lh[1]
            return _hy < _lx
        return False

    cfg = BoxingConfig(mem_words=mem_words, block_words=block_words,
                       dim_ratio={"x": 4.0, "y": 1.0})
    bj = BoxedLFTJ(triangle_query_atoms(), ["x", "y", "z"],
                   {"E": edges_ta}, cfg, device=device, emit=emit,
                   prune=prune)
    count = bj.run()
    return count, bj.stats
