"""Core library: the paper's contribution (LFTJ + boxing + triangle listing)."""

from .triearray import SPILL, TrieArray, TrieArraySlice
from .leapfrog import (Atom, LeapfrogJoin, LeapfrogTriejoin, TrieIterator,
                       lftj_triangle_count, triangle_query_atoms)
from .boxing import (BoxedLFTJ, BoxingConfig, BoxStats, SkewPlan,
                     boxed_triangle_count, class_cuts, classify_heavy,
                     greedy_degree_cuts, heavy_threshold_default, plan_boxes,
                     plan_boxes_from_degrees, plan_boxes_heavy_light)
from .executor import BoxSlice, SliceCache, StreamingExecutor
from .iomodel import BlockDevice, CountingReader, IOStats
from .lftj_jax import (csr_from_edges, orient_edges, pad_neighbors,
                       pad_neighbors_binned, triangle_count_boxed_vectorized,
                       triangle_count_dense, triangle_count_vectorized)
from .engine import (EngineStats, TriangleEngine, engine_count, engine_list,
                     measure_dense_crossover, measure_pallas_crossover)
from .mgt import mgt_triangle_count
from .queries import (Query, best_order, best_rank, build_indexes, rank,
                      rank_for_order, reordered_index, run_query, validate)
from .triangle import brute_force_count, count_triangles, list_triangles
from .adversarial import adversarial_graph

__all__ = [
    "SPILL", "TrieArray", "TrieArraySlice", "Atom", "LeapfrogJoin",
    "LeapfrogTriejoin", "TrieIterator", "lftj_triangle_count",
    "triangle_query_atoms", "BoxedLFTJ", "BoxingConfig", "BoxStats",
    "boxed_triangle_count", "plan_boxes", "BlockDevice", "CountingReader",
    "IOStats", "csr_from_edges", "orient_edges", "pad_neighbors",
    "triangle_count_boxed_vectorized", "triangle_count_dense",
    "triangle_count_vectorized", "mgt_triangle_count", "Query", "best_rank",
    "build_indexes", "rank_for_order", "run_query", "brute_force_count",
    "count_triangles", "list_triangles", "adversarial_graph",
    "pad_neighbors_binned", "EngineStats", "TriangleEngine", "engine_count",
    "engine_list", "measure_dense_crossover", "plan_boxes_from_degrees",
    "BoxSlice", "SliceCache", "StreamingExecutor", "rank", "validate",
    "best_order", "reordered_index", "greedy_degree_cuts",
    "measure_pallas_crossover", "SkewPlan", "class_cuts", "classify_heavy",
    "heavy_threshold_default", "plan_boxes_heavy_light",
]
