"""Streaming box executor: out-of-core per-box slice pipeline.

The planner half of the engine (``core.engine.TriangleEngine``) produces a
box plan; this module executes it as a stream. For each box (lx,hx,ly,hy)
the executor

  1. pulls the box from the work queue,
  2. *materializes* a vertex-renumbered, compacted neighbor slice: only the
     rows referenced by in-box edges, padded to the box-local max degree —
     never the global (V, K) ``npad`` matrix (the paper's "feed input data
     to LFTJ" boxing idea applied at the storage layer),
  3. dispatches the slice to a backend (binary-search scan, dense MXU
     formulation, or the Pallas intersect kernel) chosen by the planner's
     density rule.

Slices are built host-side from an EdgeSource (``data.edgestore.EdgeStore``
on disk, or ``InMemoryEdgeSource``); construction overlaps device compute.
Every source read is charged to the attached ``core.iomodel.BlockDevice``,
giving measured block I/Os per run.

Two execution modes share the per-box machinery:

* ``workers=1`` (the sequential oracle): the box stream runs through a
  single ``data.pipeline.Prefetcher`` — one box in flight, host DMA of the
  next box overlapping device compute of the current one. This is the
  seed behavior every parallel configuration is pinned to.
* ``workers>1`` (async scheduler): a bounded pool of worker threads drains
  a shared work queue. The queue is ordered LPT-first
  (``repro.parallel.sharding.lpt_order`` — the same priority order the
  shard_map schedule uses), so the long-pole box starts first; an idle
  worker "steals" the next-heaviest box by popping the shared queue. Slice
  *builds* are serialized in queue order behind an in-flight (boxes, words)
  budget — the source read stream is therefore identical to a serial walk
  of the same order, which is what makes the I/O ledger (and the
  ``SliceCache`` hit pattern, which folds the queue back to plan order)
  byte-comparable to the ``workers=1`` run. Backend compute runs in
  parallel across workers, and results are reduced in *fixed box order*
  (never arrival order): counts sum and listings concatenate exactly as
  the sequential oracle would.

Peak host memory is bounded by the in-flight window: at most
``inflight_boxes`` materialized slices resident at once, their raw words
capped at ``inflight_words`` (the engine sizes this window from its memory
budget); a single slice's raw words are bounded by the planner's budget
(plus pinned-row spill boxes), which is the Thm. 10 working-set guarantee.

Device shapes are bucketed (rows to multiples of 64, widths and edge counts
to powers of two) so the number of distinct jit traces stays logarithmic in
the graph size instead of linear in the box count.
"""

from __future__ import annotations

import inspect
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher
from repro.kernels import ledger as kernel_ledger
from repro.obs import metrics as obs_metrics
from repro.obs.trace import wrap_stage

from .lftj_jax import (SENTINEL, _count_chunked, _count_rows_chunked,
                       _list_chunked, pad_neighbors_binned)

_ROW_BUCKET = 64


class BoxQueueCancelled(RuntimeError):
    """Raised by ``run_box_queue`` when its ``cancel`` event fires before
    the queue drains: remaining boxes are abandoned, in-progress stages
    finish, every worker is joined. Boxes are idempotent, so a cancelled
    queue can simply be re-run (the serving layer's cancellation path)."""


def _pow2(n: int, lo: int = 1) -> int:
    return max(lo, 1 << int(np.ceil(np.log2(max(1, n)))))


@dataclass
class BoxSlice:
    """One box's renumbered, compacted work item.

    ``rows`` maps local row id -> global vertex id (sorted);
    ``row_off``/``row_vals`` are the slice's compact CSR form (offsets +
    concatenated sorted neighbor values per local row); ``eu``/``ev`` are
    *local* row ids of the in-box edges. ``words_read`` counts raw CSR
    words DMA'd from the source.

    ``npad`` — the (R, K) box-local padded neighbor matrix with one
    all-SENTINEL pad row at index ``len(rows)`` — is built lazily on first
    access and cached: the jax lanes need it, but the host backend probes
    the CSR form directly, so a host-lane run never pays the padded
    memset/scatter (the padded write traffic, not the probe math, is what
    limits worker-thread scaling on bandwidth-starved CPU hosts).
    """

    box: Tuple[int, int, int, int]
    rows: np.ndarray
    eu: np.ndarray
    ev: np.ndarray
    n_edges: int
    wx: int
    wy: int
    words_read: int
    row_off: np.ndarray
    row_vals: np.ndarray
    pad_shape: Tuple[int, int]
    _npad: Optional[np.ndarray] = None

    @property
    def npad(self) -> np.ndarray:
        if self._npad is None:
            n_rows, k = self.pad_shape
            npad = np.full((n_rows, k), SENTINEL, dtype=np.int32)
            deg = np.diff(self.row_off)
            if deg.sum() > 0:
                rr = np.repeat(np.arange(len(deg)), deg)
                cc = np.arange(int(deg.sum())) \
                    - np.repeat(self.row_off[:-1], deg)
                npad[rr, cc] = self.row_vals
            self._npad = npad
        return self._npad

    @property
    def padded_words(self) -> int:
        return int(self.pad_shape[0] * self.pad_shape[1])


def _gather_rows(rows: np.ndarray, slabs: list) -> Tuple[np.ndarray, np.ndarray]:
    """(deg, concat values) for sorted global ``rows`` out of range slabs.

    ``slabs`` is [(lo, hi, indptr_local, values)] with disjoint row ranges
    covering every requested row.
    """
    deg = np.zeros(len(rows), dtype=np.int64)
    starts = np.zeros(len(rows), dtype=np.int64)
    slab_of = np.full(len(rows), -1, dtype=np.int64)
    for si, (lo, hi, ip, _vals) in enumerate(slabs):
        m = (rows >= lo) & (rows <= hi)
        if not m.any():
            continue
        r = rows[m] - lo
        starts[m] = ip[r]
        deg[m] = ip[r + 1] - ip[r]
        slab_of[m] = si
    parts = []
    for si, (_lo, _hi, _ip, vals) in enumerate(slabs):
        m = slab_of == si
        if not m.any():
            continue
        s, d = starts[m], deg[m]
        total = int(d.sum())
        if total == 0:
            continue
        idx = np.repeat(s, d) + np.arange(total) \
            - np.repeat(np.cumsum(d) - d, d)
        parts.append((np.flatnonzero(m), vals[idx], d))
    # reassemble in row order (one vectorized scatter per slab)
    out = np.zeros(int(deg.sum()), dtype=np.int32)
    offs = np.concatenate([[0], np.cumsum(deg)])
    for where, vals, d in parts:
        tgt = np.repeat(offs[where], d) + np.arange(int(d.sum())) \
            - np.repeat(np.cumsum(d) - d, d)
        out[tgt] = vals
    return deg, out


class SliceCache:
    """LRU cache of row-range slices over an EdgeSource, budgeted in words.

    The box plan walks a grid: every box in one x-stripe re-reads the same
    x-slab, and boxes in adjacent x-stripes re-read the same y-slices. The
    cache exploits that locality *above* the ``iomodel.BlockDevice``. A
    ``read_rows(lo, hi)`` request is decomposed against row blocks of
    ``block_rows`` rows (aligned, sized to ~1/16 of the budget by default):

    * **interior blocks** (fully inside the request) are the cacheable
      unit. Cached ones are served from host memory — no source read, no
      block I/O charged, which is how hits visibly reduce
      ``EngineStats.block_reads``. Runs of consecutive *missing* interior
      blocks are fetched with ONE source read (the cold path keeps the
      sequential DMA pattern of the uncached engine) and split into
      per-block entries.
    * **partial edge blocks** pass straight through to the source, trimmed
      to the request. The cache therefore never reads a word the uncached
      engine would not have read — worst case (zero reuse) costs the same
      I/O, never more.

    Eviction is LRU past ``budget_words`` (raw CSR words: values + one
    indptr word per row); a single block wider than the whole budget is
    still cached alone (the pinned-row analogue at the cache layer). Words
    served by hits are also recorded on the attached device
    (``IOStats.cache_served_words``) so the modeled I/O ledger shows where
    the avoided traffic went.

    Exposes the EdgeSource interface; everything else (``n_nodes``,
    ``indptr``, ``degrees``, ...) proxies to the wrapped source.
    ``read_rows`` serializes on an internal lock, so the cache ledger (LRU
    order, word totals, hit counters) stays consistent when the async box
    scheduler's workers share one cache; the scheduler additionally
    serializes slice *builds* in plan order whenever a cache is attached,
    so the hit/miss sequence — not just the totals — matches the serial
    run's.
    """

    def __init__(self, source, budget_words: int,
                 block_rows: Optional[int] = None,
                 tracer=None):
        self.source = source
        # obs.trace.Tracer emitting cache.hit/miss/evict instant events;
        # None (default) keeps the hot path at one attribute check
        self.tracer = tracer
        self._lock = threading.RLock()
        self.budget_words = max(1, int(budget_words))
        if block_rows is None:
            # fine granularity maximizes interior coverage of the planner's
            # small y-segment reads (hits only happen on fully-covered
            # blocks); ~32 words per block measured best across budgets.
            # The budget/4096 floor bounds the entry count so a huge cache
            # doesn't drown in per-block bookkeeping.
            chunk = int(getattr(source, "chunk_rows", 256))
            avg = source.n_edges / max(1, source.n_nodes) + 2.0
            target = max(32, self.budget_words // 4096)
            block_rows = int(min(chunk, max(2.0, round(target / avg))))
        self.block_rows = max(1, int(block_rows))
        self._blocks: OrderedDict = OrderedDict()  # block id -> (ip, vals)
        self._words = 0
        self.hits = 0
        self.misses = 0
        self.hit_words = 0      # words served from cache
        self.miss_words = 0     # words read from the source into the cache
        self.passthrough_words = 0   # partial-edge words (never cached)

    # -- EdgeSource interface ------------------------------------------------

    def __getattr__(self, name):
        return getattr(self.source, name)

    def _read_through(self, lo: int, hi: int):
        """Uncached trimmed read (partial edge blocks)."""
        ip, vals = self.source.read_rows(lo, hi)
        self.passthrough_words += len(vals)
        return ip, vals

    def _hit(self, bid: int, ent) -> None:
        """Bookkeeping hook for one block served from cache (subclasses —
        the serving layer's multi-tenant cache — attribute per tenant)."""
        self.hits += 1
        self.hit_words += len(ent[1])
        tr = self.tracer
        if tr is not None:
            tr.event("cache.hit", block=bid, words=len(ent[1]))

    def _miss(self, n_blocks: int, n_words: int) -> None:
        """Bookkeeping hook for a missing-block run read from the source."""
        self.misses += n_blocks
        self.miss_words += n_words
        tr = self.tracer
        if tr is not None:
            tr.event("cache.miss", blocks=n_blocks, words=n_words)

    def _fetch_run(self, b0: int, b1: int) -> list:
        """One sequential source read covering missing blocks b0..b1, split
        into per-block cache entries. Returns the entries in block order
        (the caller assembles from them directly, so an insert-time
        eviction inside this very request never forces a re-read)."""
        br = self.block_rows
        ip, vals = self.source.read_rows(b0 * br, b1 * br + br - 1)
        self._miss(b1 - b0 + 1, len(vals))
        entries = []
        for bid in range(b0, b1 + 1):
            r0 = (bid - b0) * br
            s, e = int(ip[r0]), int(ip[r0 + br])
            ent = (np.asarray(ip[r0:r0 + br + 1] - ip[r0]),
                   np.asarray(vals[s:e]))
            self._insert(bid, ent)
            entries.append(ent)
        return entries

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        with self._lock:
            return self._read_rows_locked(lo, hi)

    def _read_rows_locked(self, lo: int,
                          hi: int) -> Tuple[np.ndarray, np.ndarray]:
        nv = self.source.n_nodes
        lo = max(0, int(lo))
        hi = min(nv - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        br = self.block_rows
        # interior = the aligned blocks fully covered by [lo, hi]
        ib0 = -(-lo // br)                   # first block starting >= lo
        ib1 = (hi + 1) // br - 1             # last block ending <= hi
        if ib1 < ib0:
            return self._read_through(lo, hi)
        dev = getattr(self.source, "device", None)
        parts = []                            # (ip_local, vals) in row order
        if lo < ib0 * br:
            parts.append(self._read_through(lo, ib0 * br - 1))
        bid = ib0
        while bid <= ib1:
            ent = self._blocks.get(bid)
            if ent is not None:
                self._blocks.move_to_end(bid)
                self._hit(bid, ent)
                if dev is not None:
                    dev.serve_from_cache(len(ent[1]))
                parts.append(ent)
                bid += 1
            else:
                run_end = bid
                while run_end + 1 <= ib1 \
                        and run_end + 1 not in self._blocks:
                    run_end += 1
                parts.extend(self._fetch_run(bid, run_end))
                bid = run_end + 1
        if hi >= (ib1 + 1) * br:
            parts.append(self._read_through((ib1 + 1) * br, hi))
        if len(parts) == 1:
            return parts[0]
        deg = np.concatenate([np.diff(p[0]) for p in parts])
        ip_out = np.concatenate([np.zeros(1, np.int64),
                                 np.cumsum(deg, dtype=np.int64)])
        return ip_out, np.concatenate([p[1] for p in parts])

    # -- LRU bookkeeping -----------------------------------------------------

    @staticmethod
    def _entry_words(ent) -> int:
        return len(ent[1]) + len(ent[0])

    def _insert(self, bid: int, ent) -> None:
        self._blocks[bid] = ent
        self._words += self._entry_words(ent)
        tr = self.tracer
        while self._words > self.budget_words and len(self._blocks) > 1:
            old_bid, old = self._blocks.popitem(last=False)
            self._words -= self._entry_words(old)
            if tr is not None:
                tr.event("cache.evict", block=old_bid,
                         words=self._entry_words(old))

    def clear(self) -> None:
        with self._lock:
            self._blocks.clear()
            self._words = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def run_box_serial(items: List, *,
                   fetch: Callable[[object], Tuple[object, int]],
                   build: Callable[[object], object],
                   work: Callable[[object], object],
                   prefetch_depth: int = 2,
                   cancel: Optional[threading.Event] = None,
                   tracer=None) -> List:
    """The ``workers=1`` oracle drain: one ``Prefetcher`` pipeline (fetch
    + build of the next item overlap the current item's ``work``), items
    strictly in list order, per-item results in list order (``None`` for
    skipped items). This is the serial counterpart of ``run_box_queue``
    and the reference every ledger contract in the repo is pinned against
    — the generic ``QueryEngine`` delegates its serial path here, and
    ``parallel.fabric`` re-runs any shard's restricted plan through it to
    reproduce the shard's device ledger byte for byte. ``cancel`` aborts
    with ``BoxQueueCancelled`` exactly like the pooled scheduler.
    ``tracer`` wraps each stage in ``box.fetch``/``box.build``/
    ``box.compute`` spans (``obs.trace``); tracing is read-only — stage
    order, prefetch depth and every ledger are untouched."""
    fetch = wrap_stage(tracer, "box.fetch", fetch)
    build = wrap_stage(tracer, "box.build", build)
    work = wrap_stage(tracer, "box.compute", work)
    results: List = [None] * len(items)
    pf = Prefetcher((build(fetch(it)[0]) for it in items),
                    depth=max(1, int(prefetch_depth)))
    try:
        for i, built in enumerate(pf):
            if cancel is not None and cancel.is_set():
                raise BoxQueueCancelled(
                    "query cancelled before draining its boxes")
            if built is None:
                continue
            results[i] = work(built)
    finally:
        pf.close()
    return results


def run_box_queue(items: List, *, order: List[int],
                  est_words: Callable[[object], int],
                  fetch: Callable[[object], Tuple[object, int]],
                  build: Callable[[object], object],
                  work: Callable[[object], object],
                  workers: int,
                  inflight_items: int,
                  inflight_words: Optional[int] = None,
                  cancel: Optional[threading.Event] = None,
                  tracer=None):
    """Drain a box work queue on a bounded worker pool (the PR-4 scheduler).

    This is the shared queue machinery of every boxed executor in the repo
    (the triangle ``StreamingExecutor`` and the generic
    ``repro.query.QueryEngine``): a pool of ``workers`` threads (clamped to
    the hardware parallelism and the item count) drains ``items`` in
    ``order``, with the three per-item stages split so the determinism
    contract holds for ANY workload:

    * ``fetch(item) -> (payload, actual_words)`` — all *source reads* of
      one item. Serialized in queue order behind the in-flight
      (items, words) window, so the read stream — and every ledger derived
      from it (``BlockDevice`` I/Os, ``SliceCache`` hit sequence) — is
      identical to a serial walk of ``order``.
    * ``build(payload) -> obj | None`` — pure host-side construction (no
      source access); runs concurrently across workers. ``None`` skips the
      item (empty box).
    * ``work(obj) -> result`` — the backend; concurrent across workers.

    Admission charges ``est_words(item)`` against the window up front and
    corrects to the fetch's actual words once known; an item wider than the
    whole window is admitted alone (pinned-spill rule) so the queue cannot
    deadlock on it. A stage exception cancels the remaining queue, every
    worker is joined, and the first error re-raises here. An optional
    ``cancel`` event aborts the same way from outside: no new item is
    claimed once it is set, in-progress stages finish, workers join, and
    ``BoxQueueCancelled`` raises (unless a stage error got there first).

    Returns ``(results, telemetry)``: per-item results in *item order*
    (``None`` for skipped items) for deterministic reduction, plus the
    telemetry dict (wait/build/compute worker-seconds, in-flight peaks,
    wall time, pool size) the caller folds into its stats object.

    ``tracer`` (an ``obs.trace.Tracer``) wraps the three stages in
    ``box.fetch`` / ``box.build`` / ``box.compute`` spans — one pair per
    item per stage, emitted from the worker thread running it, so the
    exported timeline shows one lane per pool thread. Tracing is
    strictly read-only: the turnstile, the admission window and every
    derived ledger behave identically with it attached.
    """
    import os as _os

    fetch = wrap_stage(tracer, "box.fetch", fetch)
    build = wrap_stage(tracer, "box.build", build)
    work = wrap_stage(tracer, "box.compute", work)

    n = len(items)
    results: List = [None] * n
    max_boxes = max(1, int(inflight_items))
    max_words = inflight_words
    # the pool never exceeds the hardware parallelism: beyond it, extra
    # runnable threads only thrash caches and the GIL (measured
    # monotonic slowdown on 2-core hosts)
    pool = max(1, min(workers, n, _os.cpu_count() or workers))
    cond = threading.Condition()
    state = {"next": 0, "building": False, "res_boxes": 0,
             "res_words": 0, "err": None, "stop": False}
    tele = {"wait": 0.0, "build": 0.0, "compute": 0.0,
            "hi_boxes": 0, "hi_words": 0, "wall": 0.0, "pool": 0}

    def loop():
        try:
            _loop_body()
        except BaseException as e:  # noqa: BLE001 — never strand waiters
            with cond:
                if state["err"] is None:
                    state["err"] = e
                state["stop"] = True
                state["building"] = False
                cond.notify_all()

    def _loop_body():
        while True:
            t0 = time.perf_counter()
            with cond:
                while True:
                    if cancel is not None and cancel.is_set():
                        state["stop"] = True
                        cond.notify_all()
                    if state["stop"] or state["next"] >= n:
                        tele["wait"] += time.perf_counter() - t0
                        return
                    if not state["building"]:
                        est = est_words(items[order[state["next"]]])
                        fits = (state["res_boxes"] < max_boxes
                                and (max_words is None
                                     or state["res_words"] + est
                                     <= max_words))
                        # an item wider than the whole window (pinned
                        # spill row) is admitted alone, or the queue
                        # would deadlock on it
                        if fits or state["res_boxes"] == 0:
                            break
                    # poll so an externally-set cancel event is noticed even
                    # when no stage completion notifies the condition
                    cond.wait(timeout=0.05 if cancel is not None else None)
                bi = order[state["next"]]
                state["next"] += 1
                state["building"] = True
                state["res_boxes"] += 1
                state["res_words"] += est
                tele["wait"] += time.perf_counter() - t0
                tele["hi_boxes"] = max(tele["hi_boxes"],
                                       state["res_boxes"])
            actual = 0
            try:
                t1 = time.perf_counter()
                # serialized stage: only the source reads. build and work
                # run outside the turnstile, concurrently across workers.
                payload, actual = fetch(items[bi])
                with cond:
                    state["building"] = False
                    state["res_words"] += actual - est
                    tele["hi_words"] = max(tele["hi_words"],
                                           state["res_words"])
                    cond.notify_all()
                obj = build(payload)
                t3 = time.perf_counter()
                with cond:
                    tele["build"] += t3 - t1
                if obj is not None:
                    out = work(obj)
                    with cond:
                        tele["compute"] += time.perf_counter() - t3
                    results[bi] = out
                with cond:
                    state["res_boxes"] -= 1
                    state["res_words"] -= actual
                    cond.notify_all()
            except BaseException as e:  # noqa: BLE001
                with cond:
                    if state["err"] is None:
                        state["err"] = e
                    state["stop"] = True      # cancel remaining items
                    state["building"] = False
                    state["res_boxes"] -= 1
                    state["res_words"] -= actual
                    cond.notify_all()
                return

    t_start = time.perf_counter()
    threads = [threading.Thread(target=loop, daemon=True,
                                name=f"box-worker-{i}")
               for i in range(pool)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tele["wall"] = time.perf_counter() - t_start
    tele["pool"] = len(threads)
    if state["err"] is not None:
        raise state["err"]
    if cancel is not None and cancel.is_set():
        raise BoxQueueCancelled("box queue cancelled before draining")
    return results, tele


def merge_queue_telemetry(stats, tele: dict, lock: threading.Lock,
                          inflight_boxes: int,
                          metrics=None, lane: str = "all") -> None:
    """Fold one ``run_box_queue`` telemetry dict into a stats object that
    carries the PR-4 scheduler fields (``EngineStats`` and
    ``repro.query.QueryStats`` both do).

    ``worker_utilization`` is ``busy / (pool * wall)``; a sub-millisecond
    run can finish with ``wall == 0.0`` (perf_counter granularity) or a
    degenerate pool, in which case the ratio is undefined — it is
    reported as ``None``, never a garbage division.

    ``metrics`` (an ``obs.metrics.MetricsRegistry``) additionally folds
    the telemetry into the ``box.*{lane=...}`` series; the process-wide
    default registry (benchmark harness opt-in) is used when none is
    passed.
    """
    busy = tele["build"] + tele["compute"]
    wall = tele["wall"]
    with lock:
        stats.n_workers = tele["pool"]
        stats.inflight_boxes = inflight_boxes
        stats.queue_wait_s += tele["wait"]
        stats.build_s += tele["build"]
        stats.compute_s += tele["compute"]
        stats.overlap_s += max(0.0, busy - wall)
        stats.worker_utilization = busy / (tele["pool"] * wall) \
            if wall > 0.0 and tele["pool"] > 0 else None
        stats.max_inflight_boxes = max(stats.max_inflight_boxes,
                                       tele["hi_boxes"])
        stats.max_inflight_words = max(stats.max_inflight_words,
                                       tele["hi_words"])
    reg = metrics if metrics is not None \
        else obs_metrics.default_registry()
    if reg is not None:
        reg.note_queue(tele, lane=lane)


class StreamingExecutor:
    """Pulls boxes from a work queue, materializes slices, runs backends.

    ``workers=1`` is the sequential oracle (single Prefetcher pipeline);
    ``workers>1`` runs the async scheduler described in the module
    docstring. ``inflight_boxes``/``inflight_words`` bound the window of
    materialized-but-unreduced slices (defaults: ``2*workers`` boxes,
    unbounded words — the engine passes a word cap derived from its memory
    budget).
    """

    def __init__(self, source, *,
                 pick_backend: Callable[[int, int, int], str],
                 chunk: int = 2048,
                 prefetch_depth: int = 2,
                 use_pallas_kernels: bool = False,
                 dense_words_cap: int = 64_000_000,
                 stats=None,
                 workers: int = 1,
                 degree_bins: bool = False,
                 inflight_boxes: Optional[int] = None,
                 inflight_words: Optional[int] = None,
                 tracer=None,
                 metrics=None):
        self.source = source
        # observability (both optional, both None by default — the traced-
        # off path is one attribute check per site): span/event recorder
        # and the cross-layer MetricsRegistry kernel/queue series feed
        self.tracer = tracer
        self.metrics = metrics
        self.pick_backend = pick_backend
        # a box-aware dispatcher (the skew-routing engine) takes the box as
        # a fourth argument; plain (n_edges, wx, wy) callables keep working
        try:
            params = inspect.signature(pick_backend).parameters.values()
            self._backend_takes_box = any(
                p.name == "box"
                or p.kind is inspect.Parameter.VAR_POSITIONAL
                for p in params)
        except (TypeError, ValueError):
            self._backend_takes_box = False
        self.degree_bins = bool(degree_bins)
        self.chunk = int(chunk)
        self.prefetch_depth = max(1, int(prefetch_depth))
        self.use_pallas_kernels = bool(use_pallas_kernels)
        self.dense_words_cap = int(dense_words_cap)
        self.stats = stats
        self.workers = max(1, int(workers))
        self.inflight_boxes = max(1, int(inflight_boxes)) \
            if inflight_boxes is not None else max(2, 2 * self.workers)
        self.inflight_words = int(inflight_words) \
            if inflight_words is not None else None
        # serializes every EngineStats mutation: workers note slices and
        # backend counters concurrently against the one shared stats object
        self._stats_lock = threading.Lock()

    # -- slice materialization (host side, overlapped via Prefetcher) --------

    def _fetch(self, box, x_slab=None):
        """All *source reads* of one box — the stage the async scheduler
        serializes in queue order, so the read stream (and every derived
        ledger: device I/Os, cache hits) is identical to a serial walk.
        ``x_slab`` is an optional pre-read ``read_rows(lx, hx)`` result so
        a caller that already extracted the box's edges (backend selection,
        shard scheduling) doesn't charge the x-range DMA twice. Returns
        ``None`` for a degenerate box, else the raw slabs + in-box edges
        for ``_compact``."""
        nv = self.source.n_nodes
        lx, hx, ly, hy = box
        lx_, hx_ = max(int(lx), 0), min(int(hx), nv - 1)
        ly_, hy_ = max(int(ly), 0), min(int(hy), nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            return None
        ip_x, vx = x_slab if x_slab is not None \
            else self.source.read_rows(lx_, hx_)
        words = len(vx)
        eu_g = np.repeat(np.arange(lx_, hx_ + 1), np.diff(ip_x))
        ev_g = vx.astype(np.int64)
        sel = (ev_g >= ly_) & (ev_g <= hy_)
        eu_g, ev_g = eu_g[sel], ev_g[sel]
        slabs = [(lx_, hx_, ip_x, vx)]
        if len(eu_g):
            # provision the y slice too (E(y, z) rows); dedup the x
            # overlap (§5)
            for seg_lo, seg_hi in ((ly_, min(hy_, lx_ - 1)),
                                   (max(ly_, hx_ + 1), hy_)):
                if seg_hi >= seg_lo:
                    ip_s, vs = self.source.read_rows(seg_lo, seg_hi)
                    words += len(vs)
                    slabs.append((seg_lo, seg_hi, ip_s, vs))
        return (box, (lx_, hx_, ly_, hy_), slabs, eu_g, ev_g, words)

    def _compact(self, fetched) -> Optional[BoxSlice]:
        """Pure-numpy renumber/compact/pad of a fetched box — no source
        access, so the scheduler runs it concurrently across workers
        (numpy's sort/unique/searchsorted kernels release the GIL)."""
        if fetched is None:
            return None
        box, (lx_, hx_, ly_, hy_), slabs, eu_g, ev_g, words = fetched
        if len(eu_g) == 0:
            return BoxSlice(box, np.zeros(0, np.int64),
                            np.zeros(0, np.int32), np.zeros(0, np.int32),
                            0, hx_ - lx_ + 1, hy_ - ly_ + 1, words,
                            row_off=np.zeros(1, np.int64),
                            row_vals=np.zeros(0, np.int32),
                            pad_shape=(0, 0))
        rows = np.unique(np.concatenate([eu_g, ev_g]))
        deg, vals = _gather_rows(rows, slabs)
        k = _pow2(int(deg.max(initial=1)), lo=8)
        n_rows = -(-(len(rows) + 1) // _ROW_BUCKET) * _ROW_BUCKET
        eu = np.searchsorted(rows, eu_g).astype(np.int32)
        ev = np.searchsorted(rows, ev_g).astype(np.int32)
        off = np.concatenate([np.zeros(1, np.int64),
                              np.cumsum(deg, dtype=np.int64)])
        return BoxSlice(box, rows, eu, ev, len(eu),
                        hx_ - lx_ + 1, hy_ - ly_ + 1, words,
                        row_off=off, row_vals=vals, pad_shape=(n_rows, k))

    def _materialize(self, box, x_slab=None) -> Optional[BoxSlice]:
        """Build the box slice (fetch + compact in one step — the serial
        pipeline and one-off ``count_box`` path)."""
        return self._compact(self._fetch(box, x_slab=x_slab))

    def _stream(self, boxes) -> Iterator[Optional[BoxSlice]]:
        mat = wrap_stage(self.tracer, "box.fetch", self._materialize)
        return Prefetcher((mat(b) for b in boxes),
                          depth=self.prefetch_depth)

    def _note(self, slc: BoxSlice) -> None:
        s = self.stats
        if s is None:
            return
        with self._stats_lock:
            s.n_streamed_boxes += 1
            s.slice_words_read += slc.words_read
            s.max_slice_words = max(s.max_slice_words, slc.words_read)
            s.max_slice_padded_words = max(s.max_slice_padded_words,
                                           slc.padded_words)

    def _note_padding(self, slc: BoxSlice, extra: int = 0) -> None:
        """Charge the padded-vs-actual ledger for one finished slice.

        ``padded_words`` counts only *materialized* padded neighbor-matrix
        words: the lazy ``slc.npad`` is charged iff some backend forced it,
        plus any per-bin matrices a binned backend built (``extra``). The
        host and dense lanes never materialize ``npad``, which is exactly
        the waste the skew-aware planner's A/B measures.
        """
        s = self.stats
        if s is None:
            return
        with self._stats_lock:
            if slc._npad is not None:
                s.padded_words += slc.padded_words
            s.padded_words += int(extra)
            if slc.row_vals is not None:
                s.actual_words += len(slc.row_vals)

    def _backend_for(self, slc: BoxSlice) -> str:
        if self._backend_takes_box:
            return self.pick_backend(slc.n_edges, slc.wx, slc.wy, slc.box)
        return self.pick_backend(slc.n_edges, slc.wx, slc.wy)

    # -- edge padding to bucketed device shapes ------------------------------

    def _bucket_edges(self, slc: BoxSlice, chunk: int):
        """Pad (eu, ev) to a power-of-two length with pad-row references.

        The pad row is all-SENTINEL, so padded slots intersect to zero —
        no validity mask needed, and jit traces are shared across boxes.
        """
        m = slc.n_edges
        mb = _pow2(m, lo=min(chunk, 256))
        pad_row = np.int32(len(slc.rows))
        eu = np.full(mb, pad_row, np.int32)
        ev = np.full(mb, pad_row, np.int32)
        eu[:m] = slc.eu
        ev[:m] = slc.ev
        return eu, ev

    # -- backends ------------------------------------------------------------

    def _count_binary(self, slc: BoxSlice) -> int:
        chunk = min(self.chunk, _pow2(slc.n_edges, lo=256))
        eu, ev = self._bucket_edges(slc, chunk)
        return int(_count_chunked(jnp.asarray(slc.npad), jnp.asarray(eu),
                                  jnp.asarray(ev), chunk=chunk))

    def _count_host(self, slc: BoxSlice) -> int:
        """Σ_edges |N(u) ∩ N(v)| on the host, pure numpy.

        Same binary-search probing as ``_count_chunked``, vectorized as ONE
        ``searchsorted`` per edge chunk: each edge's b-row is lifted into a
        disjoint int64 key range (row_id · (SENTINEL+1) + value), so the
        flattened key array stays sorted and a row-local probe becomes a
        global one. numpy's searchsorted/compare kernels release the GIL,
        which makes this the backend that scales across the async
        scheduler's workers on CPU hosts — XLA's CPU client serializes
        concurrent executions, so the jax lanes cannot (on TPU the device
        lanes overlap asynchronously instead).
        """
        m = slc.n_edges
        if m == 0:
            return 0
        off, vals = slc.row_off, slc.row_vals
        if off is None:
            # externally-built slices: recover the compact CSR from npad
            mask = slc.npad != SENTINEL
            deg = mask.sum(axis=1).astype(np.int64)
            off = np.concatenate([np.zeros(1, np.int64), np.cumsum(deg)])
            vals = slc.npad[mask]
        deg = np.diff(off)
        # keys lift each edge's sorted neighbor run into a disjoint range
        # (edge_pos · stride + value), so the concatenation stays sorted
        # and ONE global lower-bound probes every edge at once. stride only
        # has to clear the value domain — int32 keys when (chunk_edges ·
        # stride) fits, halving the memory traffic of the lift
        stride = np.int64(max(int(vals.max(initial=0)) + 1, 1))
        max32 = int((np.iinfo(np.int32).max - stride + 1) // stride)

        def lift(rows: np.ndarray) -> np.ndarray:
            d = deg[rows]
            n = int(d.sum())
            if n == 0:
                return np.zeros(0, np.int64)
            r0 = np.repeat(off[rows], d)
            within = np.arange(n) - np.repeat(np.cumsum(d) - d, d)
            if len(rows) <= max32:
                rid = np.repeat(
                    np.arange(len(rows), dtype=np.int32)
                    * np.int32(stride), d)
                return vals[r0 + within] + rid
            rid = np.repeat(np.arange(len(rows), dtype=np.int64), d)
            return vals[r0 + within].astype(np.int64) + rid * stride

        # chunk the edge list so the lifted key arrays stay ~bounded; the
        # probe work scales with real neighbor entries (CSR), never the
        # padded width a box hub row inflates
        load = np.cumsum(deg[slc.eu] + deg[slc.ev])
        total = 0
        s = 0
        while s < m:
            base = int(load[s - 1]) if s else 0
            e = int(np.searchsorted(load, base + 4_000_000, side="right"))
            e = min(max(e, s + 1), s + max(1, max32))
            ak = lift(slc.eu[s:e])
            bk = lift(slc.ev[s:e])
            if len(ak) > len(bk):
                ak, bk = bk, ak          # probe the smaller into the larger
            if len(ak) and len(bk):
                pos = np.searchsorted(bk, ak)
                np.minimum(pos, bk.size - 1, out=pos)
                total += int((bk[pos] == ak).sum())
            s = e
        return total

    def _count_dense(self, slc: BoxSlice) -> Optional[int]:
        """Σ mask ⊙ (Ax Ayᵀ) over the *compacted* z domain.

        Columns span only the z values that actually occur in the slice's
        neighbor lists (renumbered), so the one-hot rows scale with the box,
        not with V. The one-hots are scattered straight from the slice's
        compact CSR (``row_off``/``row_vals``) — the dense lane never
        materializes the padded ``npad`` matrix, so a hub box routed here
        pays zero padded words. Returns ``None`` when the exact one-hot
        footprint would exceed ``dense_words_cap`` (e.g. a pinned hub row
        whose z domain is its full million-neighbor list) — the
        dispatcher's pre-materialize estimate cannot see the z domain, so
        the hard cap is enforced here and the caller falls back to the
        binary backend.
        """
        off, vals = slc.row_off, slc.row_vals
        if off is None:
            # externally-built slices: recover the compact CSR from npad
            mask = slc.npad != SENTINEL
            d = mask.sum(axis=1).astype(np.int64)
            off = np.concatenate([np.zeros(1, np.int64), np.cumsum(d)])
            vals = slc.npad[mask]
        zdom = np.unique(vals)
        if len(zdom) == 0:
            return 0
        rows_x = np.unique(slc.eu)
        rows_y = np.unique(slc.ev)
        if (len(rows_x) + len(rows_y)) * len(zdom) > self.dense_words_cap:
            return None
        deg_all = np.diff(off)

        def one_hot(rows_local):
            a = np.zeros((len(rows_local), len(zdom)), dtype=np.float32)
            d = deg_all[rows_local]
            n = int(d.sum())
            if n:
                rr = np.repeat(np.arange(len(rows_local)), d)
                idx = np.repeat(off[rows_local], d) + np.arange(n) \
                    - np.repeat(np.cumsum(d) - d, d)
                a[rr, np.searchsorted(zdom, vals[idx])] = 1.0
            return a

        ax, ay = one_hot(rows_x), one_hot(rows_y)
        mask = np.zeros((len(rows_x), len(rows_y)), dtype=np.float32)
        mask[np.searchsorted(rows_x, slc.eu),
             np.searchsorted(rows_y, slc.ev)] = 1.0
        if self.use_pallas_kernels:  # MXU tiling pays off on real hardware
            from repro.kernels.triangle_dense.ops import triangle_count
            return int(triangle_count(ax, ay, mask, use_pallas=True))
        return int((mask * (ax @ ay.T)).sum())

    def _count_pallas(self, slc: BoxSlice) -> int:
        from repro.kernels.intersect.ops import intersect_count
        out = intersect_count(slc.npad[slc.eu], slc.npad[slc.ev],
                              use_pallas=True,
                              interpret=not self.use_pallas_kernels)
        return int(jnp.sum(out))

    def _count_binned_slice(self, slc: BoxSlice) -> int:
        """Per-box degree-binned counting: the out-of-core analogue of the
        engine's global binned path (the ``degree_bins=True`` contract for
        store-backed sources). The slice's compact CSR rows are grouped into
        power-of-4 width classes (``pad_neighbors_binned``) and each edge
        probes its (bin_u, bin_v) pair's matrices via
        ``_count_rows_chunked`` — pad waste per row is bounded by the bin
        growth factor instead of the box-local max degree, and the global
        ``npad`` is never touched."""
        if slc.n_edges == 0:
            return 0
        row_bin, bins = pad_neighbors_binned(slc.row_off, slc.row_vals)
        bin_pos = np.zeros(max(1, len(row_bin)), dtype=np.int64)
        extra = 0
        for rows_b, npad_b in bins:
            bin_pos[rows_b] = np.arange(len(rows_b))
            extra += int(npad_b.size)
        bu = row_bin[slc.eu]
        bv = row_bin[slc.ev]
        live = (bu >= 0) & (bv >= 0)   # deg-0 rows intersect to nothing
        total = 0
        for i, j in sorted(set(zip(bu[live].tolist(), bv[live].tolist()))):
            sel = np.flatnonzero(live & (bu == i) & (bv == j))
            a_rows = bins[i][1][bin_pos[slc.eu[sel]]]
            b_rows = bins[j][1][bin_pos[slc.ev[sel]]]
            chunk = min(self.chunk, _pow2(len(sel), lo=256))
            total += int(_count_rows_chunked(jnp.asarray(a_rows),
                                             jnp.asarray(b_rows),
                                             chunk=chunk))
        self._note_padding(slc, extra=extra)
        return total

    def _count_fused(self, slc: BoxSlice) -> Optional[int]:
        """Whole-box triangle count in ONE device invocation: the fused
        Pallas frontier megakernel (``kernels.lftj_fused``). The triangle
        query ships as three box-restricted atoms in compact CSR form —
        the in-box edge list as R(x, y) plus the slice's neighbor lists
        re-keyed by the edge endpoints as S(x, z) and T(y, z) — so the
        entire per-level frontier leapfrog runs on-device instead of one
        staged launch per chunk. Returns ``None`` when the padded box
        falls outside the kernel's VMEM envelope; the caller falls back
        to the staged lanes."""
        if slc.n_edges == 0:
            return 0
        from repro.kernels.lftj_fused.ops import FusedUnsupported, fused_count
        off, vals = slc.row_off, slc.row_vals
        if off is None:
            # externally-built slices: recover the compact CSR from npad
            mask = slc.npad != SENTINEL
            deg = mask.sum(axis=1).astype(np.int64)
            off = np.concatenate([np.zeros(1, np.int64), np.cumsum(deg)])
            vals = slc.npad[mask]
        deg = np.diff(off)

        def sub_csr(local_rows: np.ndarray):
            d = deg[local_rows]
            n = int(d.sum())
            so = np.concatenate([np.zeros(1, np.int64),
                                 np.cumsum(d, dtype=np.int64)])
            if n == 0:
                return so, vals[:0]
            r0 = np.repeat(off[local_rows], d)
            within = np.arange(n) - np.repeat(np.cumsum(d) - d, d)
            return so, vals[r0 + within]

        # R(x, y): the in-box edges, grouped by global source id (rows is
        # sorted, so local-id order == global-id order)
        gu = slc.rows[slc.eu]
        gv = slc.rows[slc.ev]
        order = np.lexsort((gv, gu))
        gu_s, gv_s = gu[order], gv[order]
        keys0, counts0 = np.unique(gu_s, return_counts=True)
        off0 = np.concatenate([np.zeros(1, np.int64),
                               np.cumsum(counts0, dtype=np.int64)])
        uniq_u = np.unique(slc.eu)
        uniq_v = np.unique(slc.ev)
        off1, vals1 = sub_csr(uniq_u)
        off2, vals2 = sub_csr(uniq_v)
        csrs = ((keys0, off0, gv_s),
                (slc.rows[uniq_u], off1, vals1),
                (slc.rows[uniq_v], off2, vals2))
        try:
            return fused_count(((0, 1), (0, 2), (1, 2)), csrs, 3,
                               interpret=not self.use_pallas_kernels)
        except FusedUnsupported:
            return None

    def _count_slice(self, slc: BoxSlice) -> int:
        with kernel_ledger.attach(tracer=self.tracer) as kl:
            out, op = self._count_slice_dispatch(slc)
        if self.stats is not None and kl.invocations:
            with self._stats_lock:
                self.stats.device_invocations += kl.invocations
                self.stats.device_transfer_bytes += kl.transfer_bytes
                self.stats.max_box_device_invocations = max(
                    self.stats.max_box_device_invocations, kl.invocations)
        if self.metrics is not None:
            self.metrics.note_kernel(kl, op=op)
        return out

    def _count_slice_dispatch(self, slc: BoxSlice) -> Tuple[int, str]:
        """Counts one slice; returns ``(count, backend_op)`` so the
        caller can label the box's kernel launches (``kernel.*{op=..}``)
        with the backend that actually ran, fallbacks included."""
        be = self._backend_for(slc)
        if be == "fused":
            out = self._count_fused(slc)
            if out is not None:
                if self.stats is not None:
                    with self._stats_lock:
                        self.stats.n_fused_boxes += 1
                self._note_padding(slc)
                return out, "fused"
            # box outside the fused VMEM envelope: fall back to the
            # staged kernel lane (same launch cadence as before the
            # megakernel existed)
            be = "pallas" if self.use_pallas_kernels else "binary"
        if be == "dense":
            out = self._count_dense(slc)
            if out is not None:
                if self.stats is not None:
                    with self._stats_lock:
                        self.stats.n_dense_boxes += 1
                self._note_padding(slc)
                return out, "dense"
            # one-hot footprint over the cap: fall back. The box is above
            # the dense crossover, hence inside the pallas mid-band — keep
            # the kernel backend when the platform supports it
            be = "pallas" if self.use_pallas_kernels else "binary"
        if self.stats is not None:
            with self._stats_lock:
                if be == "pallas":
                    self.stats.n_pallas_boxes += 1
                elif be == "host":
                    self.stats.n_host_boxes += 1
                else:
                    self.stats.n_binary_boxes += 1
        if be == "pallas":
            out = self._count_pallas(slc)
        elif be == "host":
            out = self._count_host(slc)
        elif self.degree_bins:
            # binned backends self-record their padded extra
            return self._count_binned_slice(slc), "binned"
        else:
            out = self._count_binary(slc)
        self._note_padding(slc)
        return out, be

    def _list_slice(self, slc: BoxSlice,
                    capacity: Optional[int]) -> Optional[np.ndarray]:
        """One box's triangles (global vertex ids), bounded buffer +
        overflow→rescan. Deterministic per slice, so serial and parallel
        runs produce identical per-box arrays."""
        # listing always runs the intersection path (dense is count-only),
        # so no backend counters are recorded here
        chunk = min(self.chunk, 1024)
        eu, ev = self._bucket_edges(slc, chunk)
        chunk = min(chunk, len(eu))
        cap = _pow2(capacity if capacity is not None
                    else max(256, slc.n_edges))
        while True:
            total, buf = _list_chunked(jnp.asarray(slc.npad),
                                       jnp.asarray(eu),
                                       jnp.asarray(ev),
                                       cap=cap, chunk=chunk)
            total = int(total)
            if total <= cap:
                break
            if self.stats is not None:
                with self._stats_lock:
                    self.stats.n_rescans += 1
            cap *= 2
        self._note_padding(slc)
        if total == 0:
            return None
        tris = np.asarray(buf[:total], dtype=np.int64)
        tris[:, 0] = slc.rows[tris[:, 0]]   # local -> global ids
        tris[:, 1] = slc.rows[tris[:, 1]]   # (z is already global)
        device = getattr(self.source, "device", None)
        if device is not None:
            device.write_words(3 * total)
        return tris

    # -- async scheduler (workers > 1) ----------------------------------------
    # The pool/queue machinery itself lives in the module-level
    # ``run_box_queue`` so the generic ``repro.query.QueryEngine`` drains
    # its n-dimensional box queue through the exact same turnstile (same
    # serialized-fetch determinism contract, same in-flight window, same
    # telemetry) — this class only supplies the triangle-specific stages.

    def _est_slice_words(self, box) -> int:
        """Raw CSR words ``_materialize`` will read for ``box``, estimated
        from the resident degree index (exact for the uncached source: the
        same row ranges are summed that the materializer reads)."""
        ip = np.asarray(self.source.indptr)
        nv = self.source.n_nodes
        lx, hx, ly, hy = box
        lx_, hx_ = max(int(lx), 0), min(int(hx), nv - 1)
        ly_, hy_ = max(int(ly), 0), min(int(hy), nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            return 0
        words = int(ip[hx_ + 1] - ip[lx_])
        for seg_lo, seg_hi in ((ly_, min(hy_, lx_ - 1)),
                               (max(ly_, hx_ + 1), hy_)):
            if seg_hi >= seg_lo:
                words += int(ip[seg_hi + 1] - ip[seg_lo])
        return words

    def _queue_order(self, boxes: List) -> List[int]:
        """Priority order the shared queue is drained in — the
        ``sharding.box_queue_order`` policy: LPT-first for pure in-memory
        sources (only makespan matters), plan order when a ``SliceCache``
        or charged ``BlockDevice`` is attached (adjacent boxes share row
        blocks in plan order, and — because builds are serialized in queue
        order — this keeps the device's LRU frame hits and the cache's
        hit/miss *sequence* identical to the ``workers=1`` run; LPT order
        measured ~1.6x the block reads on the out-of-core smoke workload).
        """
        from repro.parallel.sharding import box_queue_order
        ledger = isinstance(self.source, SliceCache) \
            or getattr(self.source, "device", None) is not None
        return box_queue_order([self._est_slice_words(b) for b in boxes],
                               ledger_sensitive=ledger)

    def _fetch_with_words(self, box) -> Tuple[object, int]:
        """``run_box_queue`` fetch stage: the box's source reads + their
        raw word count (the window-admission correction)."""
        fetched = self._fetch(box)
        return fetched, (fetched[-1] if fetched is not None else 0)

    def _build_slice(self, fetched) -> Optional[BoxSlice]:
        """``run_box_queue`` build stage: numpy compaction (no source
        access); ``None`` drops empty boxes before the backend runs."""
        slc = self._compact(fetched)
        if slc is None or slc.n_edges == 0:
            return None
        self._note(slc)
        return slc

    def _run_parallel(self, boxes: List, work: Callable) -> List:
        """Run ``work(slc)`` for every box on the shared worker pool
        (``run_box_queue``): per-box results in *plan order* (``None`` for
        empty boxes) so callers reduce deterministically regardless of
        completion order."""
        results, tele = run_box_queue(
            boxes, order=self._queue_order(boxes),
            est_words=self._est_slice_words,
            fetch=self._fetch_with_words,
            build=self._build_slice,
            work=work,
            workers=self.workers,
            inflight_items=self.inflight_boxes,
            inflight_words=self.inflight_words,
            tracer=self.tracer)
        if self.stats is not None:
            merge_queue_telemetry(self.stats, tele, self._stats_lock,
                                  inflight_boxes=self.inflight_boxes,
                                  metrics=self.metrics)
        return results

    # -- public entry points --------------------------------------------------

    def count_box(self, box, x_slab=None) -> int:
        """One-off execution of a single box (no prefetch pipeline)."""
        slc = self._materialize(box, x_slab=x_slab)
        if slc is None or slc.n_edges == 0:
            return 0
        self._note(slc)
        return self._count_slice(slc)

    def run_count(self, boxes) -> int:
        boxes = list(boxes)
        if self.workers > 1 and len(boxes) > 1:
            results = self._run_parallel(boxes, self._count_slice)
            # deterministic reduction: fixed box order, not arrival order
            return sum(r for r in results if r is not None)
        total = 0
        count = wrap_stage(self.tracer, "box.compute", self._count_slice)
        pf = self._stream(boxes)
        try:
            for slc in pf:
                if slc is None or slc.n_edges == 0:
                    continue
                self._note(slc)
                total += count(slc)
        finally:
            # a consumer-side error must not leave the producer thread
            # reading the store (and charging the device) in the background
            pf.close()
        return total

    def run_list(self, boxes, capacity: Optional[int] = None) -> np.ndarray:
        """Enumerate triangles across the box stream (global vertex ids).

        Per box, a bounded buffer holds candidates; the kernel returns the
        exact per-box total alongside, so overflow is resolved by rescanning
        *that box* at doubled capacity (the engine's overflow→rescan
        protocol, now box-granular). With ``workers>1`` boxes run on the
        async scheduler and the per-box arrays concatenate in fixed box
        order — identical output to the sequential run.
        """
        boxes = list(boxes)
        if self.workers > 1 and len(boxes) > 1:
            parts = self._run_parallel(
                boxes, lambda slc: self._list_slice(slc, capacity))
            parts = [p for p in parts if p is not None]
            if not parts:
                return np.zeros((0, 3), dtype=np.int64)
            return np.concatenate(parts)
        out: List[np.ndarray] = []
        lst = wrap_stage(self.tracer, "box.compute",
                         lambda slc: self._list_slice(slc, capacity))
        pf = self._stream(boxes)
        try:
            for slc in pf:
                if slc is None or slc.n_edges == 0:
                    continue
                self._note(slc)
                tris = lst(slc)
                if tris is not None:
                    out.append(tris)
        finally:
            pf.close()
        if not out:
            return np.zeros((0, 3), dtype=np.int64)
        return np.concatenate(out)
