"""Faithful Leapfrog Triejoin (paper Apx. A, Algorithms 3 & 4; [Veldhuizen'14]).

This is the *reference altitude*: the exact sequential algorithm with
TrieIterators over TrieArrays, generic in the query (any arity, any number of
atoms, any consistent variable order). All element accesses go through a
``CountingReader`` so the same code runs in-memory (no accounting) or on the
simulated block device (out-of-core accounting for Prop. 4 / Fig. 9).

Complexities honoured (paper §2.1): VALUE/ATEND O(1); SEEK amortized
O(1 + log(N/m)) via galloping (exponential probe 1,4,16,.. then bisect),
NEXT O(1) amortized.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from .iomodel import CountingReader
from .triearray import TrieArray


class TrieIterator:
    """Navigates the trie of a TrieArray (paper Apx. A.1)."""

    __slots__ = ("ta", "rd", "depth", "_lo", "_hi", "_pos")

    def __init__(self, ta: TrieArray, reader: Optional[CountingReader] = None):
        self.ta = ta
        self.rd = reader or CountingReader(None)
        self.depth = -1                      # -1 == at root
        self._lo = [0] * ta.arity            # sibling range per depth
        self._hi = [0] * ta.arity
        self._pos = [0] * ta.arity

    # -- vertical -----------------------------------------------------------

    def open(self) -> None:
        ta, d = self.ta, self.depth
        if d == -1:
            lo, hi = 0, len(ta.val[0])
        else:
            j = self._pos[d]
            # child range: idx[d][j] .. idx[d][j+1] (offset-adjusted)
            raw_lo = self.rd.get(ta.idx[d], j)
            raw_hi = self.rd.get(ta.idx[d], j + 1)
            lo = raw_lo - ta.idx_offset[d]
            hi = raw_hi - ta.idx_offset[d]
        d += 1
        self.depth = d
        self._lo[d], self._hi[d], self._pos[d] = lo, hi, lo

    def close(self) -> None:
        self.depth -= 1

    # -- linear iterator (current depth) --------------------------------------

    def at_end(self) -> bool:
        d = self.depth
        return self._pos[d] >= self._hi[d]

    def value(self) -> int:
        d = self.depth
        return self.rd.get(self.ta.val[d], self._pos[d])

    def next(self) -> None:
        self._pos[self.depth] += 1

    def seek(self, v: int) -> None:
        """Forward-position to the least element >= v (galloping search)."""
        d = self.depth
        arr = self.ta.val[d]
        pos, hi = self._pos[d], self._hi[d]
        if pos >= hi:
            return
        # gallop: probe pos+1, pos+4, pos+16, ... until >= v or past end
        step = 1
        lo_b = pos
        hi_b = pos
        while hi_b < hi and self.rd.get(arr, hi_b) < v:
            lo_b = hi_b + 1
            step *= 4
            hi_b = min(pos + step, hi - 1) if pos + step < hi else hi - 1
            if lo_b > hi_b:
                break
        if hi_b >= hi or (hi_b == hi - 1 and self.rd.get(arr, hi_b) < v):
            self._pos[d] = hi
            return
        # binary search in [lo_b, hi_b]
        while lo_b < hi_b:
            mid = (lo_b + hi_b) // 2
            if self.rd.get(arr, mid) < v:
                lo_b = mid + 1
            else:
                hi_b = mid
        self._pos[d] = lo_b


class LeapfrogJoin:
    """Intersection of the current levels of k TrieIterators (Alg. 3)."""

    __slots__ = ("iters", "i", "at_end")

    def __init__(self, iters: Sequence[TrieIterator]):
        self.iters = list(iters)
        self.i = 0
        self.at_end = False

    def init(self) -> None:
        self.at_end = False
        for it in self.iters:
            if it.at_end():
                self.at_end = True
                return
        self.iters.sort(key=lambda it: it.value())
        self.i = 0
        self.search()

    def search(self) -> None:
        iters, k = self.iters, len(self.iters)
        i = self.i
        max_val = iters[(i - 1) % k].value() if not iters[(i - 1) % k].at_end() else None
        if max_val is None:
            self.at_end = True
            return
        while True:
            it = iters[i]
            if it.at_end():
                self.at_end = True
                return
            v = it.value()
            if v == max_val:
                self.i = i
                return  # all k agree
            it.seek(max_val)
            if it.at_end():
                self.at_end = True
                return
            max_val = it.value()
            i = (i + 1) % k

    def next(self) -> None:
        it = self.iters[self.i]
        it.next()
        if it.at_end():
            self.at_end = True
            return
        self.i = (self.i + 1) % len(self.iters)
        self.search()

    def seek(self, v: int) -> None:
        it = self.iters[self.i]
        it.seek(v)
        if it.at_end():
            self.at_end = True
            return
        self.i = (self.i + 1) % len(self.iters)
        self.search()

    def value(self) -> int:
        return self.iters[self.i].value()


@dataclass
class Atom:
    """A body atom: relation name + variable tuple, e.g. E(x, y)."""

    rel: str
    vars: tuple

    def __post_init__(self):
        if len(set(self.vars)) != len(self.vars):
            raise ValueError(
                f"atom {self.rel}{self.vars}: repeated variable in one atom; "
                "rewrite with Eq() per paper §2.1")


class LeapfrogTriejoin:
    """Generic LFTJ over a full-conjunctive query (Alg. 4).

    ``relations`` maps relation name -> TrieArray whose attribute order is
    consistent with ``var_order`` (create reordered indexes upstream if not;
    paper §2.1 'Leapfrog TrieJoin Restrictions').
    """

    def __init__(self, atoms: Sequence[Atom], var_order: Sequence[str],
                 relations: dict, reader: Optional[CountingReader] = None,
                 bounds: Optional[dict] = None):
        self.atoms = list(atoms)
        self.var_order = list(var_order)
        self.reader = reader or CountingReader(None)
        self.bounds = bounds or {}
        for a in self.atoms:
            positions = [self.var_order.index(v) for v in a.vars]
            if positions != sorted(positions):
                raise ValueError(
                    f"atom {a.rel}{a.vars} inconsistent with order {var_order}; "
                    "pre-create a reordered index for it")
        # One TrieIterator per atom (paper: even for repeated relations).
        self.iters = [TrieIterator(relations[a.rel], self.reader) for a in self.atoms]
        n = len(self.var_order)
        self.openers: list = [[] for _ in range(n)]
        for a, it in zip(self.atoms, self.iters):
            for v in a.vars:
                self.openers[self.var_order.index(v)].append(it)
        self.lfjs = [LeapfrogJoin(self.openers[d]) for d in range(n)]
        for d in range(n):
            if not self.openers[d]:
                raise ValueError(f"variable {self.var_order[d]} appears in no atom")

    def run(self, emit: Callable[[tuple], None] | None = None,
            count_only: bool = False) -> int:
        """DFS over the binding trie; returns #results, optionally emitting."""
        n = len(self.var_order)
        binding = [0] * n
        count = 0
        d = 0
        self._open(0)
        self._apply_lower_bound(0)
        while True:
            if self.lfjs[d].at_end:
                self._close(d)
                d -= 1
                if d < 0:
                    break
                self.lfjs[d].next()
                continue
            v = self.lfjs[d].value()
            ub = self.bounds.get(self.var_order[d])
            if ub is not None and v > ub[1]:
                # monotone pruning: past the box's upper bound at this level
                self.lfjs[d].at_end = True
                continue
            binding[d] = v
            if d == n - 1:
                count += 1
                if emit is not None and not count_only:
                    emit(tuple(binding))
                self.lfjs[d].next()
            else:
                d += 1
                self._open(d)
                self._apply_lower_bound(d)
        return count

    def _apply_lower_bound(self, d: int) -> None:
        lb = self.bounds.get(self.var_order[d])
        if lb is not None and not self.lfjs[d].at_end:
            if self.lfjs[d].value() < lb[0]:
                self.lfjs[d].seek(lb[0])

    def _open(self, d: int) -> None:
        for it in self.openers[d]:
            it.open()
        self.lfjs[d].init()

    def _close(self, d: int) -> None:
        for it in self.openers[d]:
            it.close()


def triangle_query_atoms() -> list:
    """T(x,y,z) <- E(x,y), E(x,z), E(y,z)   (paper eq. Δ)."""
    return [Atom("E", ("x", "y")), Atom("E", ("x", "z")), Atom("E", ("y", "z"))]


def lftj_query_count(atoms: Sequence[Atom], var_order: Sequence[str],
                     relations: dict, device=None,
                     emit: Optional[Callable] = None) -> int:
    """Scalar LFTJ over any consistent atom list, optionally charging every
    element access to a ``core.iomodel.BlockDevice``.

    The reference-altitude I/O measurement for general queries: registers
    each relation's arrays on the device and routes all trie navigation
    through a ``CountingReader``, so the measured block reads are the
    vanilla (un-boxed) cost the Thm. 13 boxed bound is compared against
    (``benchmarks/query_patterns.py``; ``repro.query.QueryEngine`` is the
    production path)."""
    reader = None
    if device is not None:
        for ta in relations.values():
            device.register_triearray(ta)
        reader = CountingReader(device)
    j = LeapfrogTriejoin(atoms, list(var_order), relations, reader=reader)
    return j.run(emit=emit)


def lftj_triangle_count(edges_ta: TrieArray,
                        reader: Optional[CountingReader] = None,
                        emit: Optional[Callable] = None) -> int:
    """In-memory LFTJ-Δ on a DAG-oriented edge TrieArray."""
    j = LeapfrogTriejoin(triangle_query_atoms(), ["x", "y", "z"],
                         {"E": edges_ta}, reader=reader)
    return j.run(emit=emit)
