"""Public triangle-listing API (the paper's workload, all altitudes).

    count_triangles(src, dst, method=...)   -> int
    list_triangles(src, dst)                -> (m, 3) array

methods:
  'faithful'    exact sequential LFTJ-Δ (paper Alg. 1/4) — reference
  'boxed'       boxed LFTJ-Δ (paper Alg. 2) with memory budget
  'vectorized'  batched searchsorted intersections (TPU-native altitude)
  'boxed_vec'   box plan from the paper's prober + vectorized per-box engine
  'dense'       Σ A ⊙ (A Aᵀ) (MXU formulation; small/dense graphs)
  'mgt'         the specialized out-of-core competitor [10]
  'auto'        vectorized, falling back to boxed_vec when a memory budget
                is given and the input exceeds it
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .boxing import boxed_triangle_count
from .iomodel import BlockDevice
from .leapfrog import lftj_triangle_count
from .lftj_jax import (dense_adjacency, orient_edges, triangle_count_boxed_vectorized,
                       triangle_count_dense, triangle_count_vectorized)
from .mgt import mgt_triangle_count
from .triearray import TrieArray


def _oriented_ta(src, dst, orientation="minmax") -> TrieArray:
    a, b = orient_edges(src, dst, orientation)
    return TrieArray.from_edges(a, b)


def count_triangles(src: np.ndarray, dst: np.ndarray,
                    method: str = "auto",
                    mem_words: Optional[int] = None,
                    device: Optional[BlockDevice] = None,
                    orientation: str = "minmax") -> int:
    src = np.asarray(src)
    dst = np.asarray(dst)
    if method == "auto":
        ta_words = 0
        if mem_words is not None:
            ta_words = _oriented_ta(src, dst, orientation).words()
        if mem_words is not None and ta_words > mem_words:
            method = "boxed_vec"
        else:
            method = "vectorized"
    if method == "faithful":
        from .iomodel import CountingReader
        ta = _oriented_ta(src, dst, orientation)
        if device is not None:
            device.register_triearray(ta)
        return lftj_triangle_count(ta, reader=CountingReader(device))
    if method == "boxed":
        ta = _oriented_ta(src, dst, orientation)
        mw = mem_words if mem_words is not None else max(64, ta.words())
        cnt, _ = boxed_triangle_count(ta, mw, device=device)
        return cnt
    if method == "vectorized":
        return triangle_count_vectorized(src, dst, orientation)
    if method == "boxed_vec":
        mw = mem_words if mem_words is not None else 1 << 20
        cnt, _ = triangle_count_boxed_vectorized(src, dst, mw, orientation)
        return cnt
    if method == "dense":
        a, b = orient_edges(src, dst, orientation)
        n = int(max(a.max(initial=0), b.max(initial=0))) + 1
        return int(triangle_count_dense(dense_adjacency(a, b, n)))
    if method == "mgt":
        mw = mem_words if mem_words is not None else 1 << 20
        cnt, _ = mgt_triangle_count(src, dst, mw, device=device)
        return cnt
    raise ValueError(f"unknown method {method!r}")


def list_triangles(src: np.ndarray, dst: np.ndarray,
                   mem_words: Optional[int] = None) -> np.ndarray:
    """Enumerate triangles (a < b < c) via (boxed) LFTJ-Δ."""
    out = []
    ta = _oriented_ta(src, dst)
    if mem_words is None or ta.words() <= mem_words:
        lftj_triangle_count(ta, emit=out.append)
    else:
        boxed_triangle_count(ta, mem_words, emit=out.append)
    return np.asarray(out, dtype=np.int64).reshape(-1, 3)


def brute_force_count(src: np.ndarray, dst: np.ndarray) -> int:
    """O(V³)-ish oracle for tests (small graphs only)."""
    a, b = orient_edges(src, dst)
    n = int(max(a.max(initial=0), b.max(initial=0))) + 1
    adj = np.zeros((n, n), dtype=bool)
    adj[a, b] = True
    cnt = 0
    for x, y in zip(a, b):
        cnt += int(np.sum(adj[x] & adj[y]))
    return cnt
