"""TriangleEngine: planner + facade over the streaming box executor.

The engine is split into two layers (out-of-core refactor):

  * **planner** (this module)    — orientation/CSR preparation, the box plan
    (``core.boxing.plan_boxes`` in memory, ``plan_boxes_from_degrees`` from
    the resident degree index when the graph lives in a
    ``data.edgestore.EdgeStore``), per-box backend dispatch by edge density,
    and shard scheduling. The public ``TriangleEngine`` API is unchanged.
  * **streaming executor** (``core.executor.StreamingExecutor``) — pulls
    boxes from a work queue and materializes, per box, a vertex-renumbered
    *compacted* neighbor slice (never the global V×K ``npad``), overlapping
    host-side slice construction with device compute via
    ``data.pipeline.Prefetcher``. Source reads are charged to a
    ``core.iomodel.BlockDevice`` so ``EngineStats`` carries measured block
    I/Os (comparable against the paper's Thm. 10 bound).

Sharded execution (the "Boxes" rule of ``repro.parallel.sharding``) no
longer replicates the padded neighbor matrix: each shard receives only the
renumbered neighbor rows its boxes reference (``shard_local_slices``), so
per-device memory scales with the box slice, not the graph. With
``degree_bins=True`` the shard path runs one kernel per degree-bin pair on
``pad_neighbors_binned``-width matrices.

With ``cache_words > 0`` the source is wrapped in an LRU
``core.executor.SliceCache``: row blocks that adjacent boxes re-read
(same-stripe x-slabs, shared y-slices) are served from host memory instead
of re-charging the block device, so ``EngineStats.block_reads`` drops while
counts stay identical. ``TriangleEngine.ingest`` closes the remaining gap
to "graphs larger than RAM": it builds the store itself with bounded
memory (``data.edgestore.EdgeStoreWriter`` external-sort ingest).

Usage::

    eng = TriangleEngine(src, dst, mem_words=1 << 16)   # in-memory
    eng = TriangleEngine(store="graph.csr", mem_words=1 << 16)  # out-of-core
    eng = TriangleEngine(store="graph.csr", mem_words=1 << 16,
                         workers=4)    # async box scheduler (same output)
    eng = TriangleEngine.ingest("graph.csr", batch_iter,         # bounded-
                                ingest_budget_words=1 << 20,     # memory
                                mem_words=1 << 16,               # ingest
                                cache_words=1 << 14)
    n   = eng.count()
    tri = eng.list()          # (n, 3) canonical (min, mid, max) rows
    eng.stats                 # boxes, backends, shards, cache, block I/Os
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.data.edgestore import EdgeStore, InMemoryEdgeSource
from repro.parallel.sharding import (balanced_box_schedule, box_mesh,
                                     shard_local_slices)

from .executor import SliceCache, StreamingExecutor, _pow2
from .iomodel import BlockDevice
from .lftj_jax import (SENTINEL, _count_chunked, _count_rows_chunked,
                       _list_chunked, _list_pairs_chunked,
                       _row_intersect_count, csr_from_edges, orient_edges,
                       pad_neighbors, pad_neighbors_binned)

BACKENDS = ("auto", "binary", "dense", "pallas", "host", "fused")

# dense-path feasibility guard: one-hot words per box (slice-scaled estimate)
_DENSE_WORDS_CAP = 64_000_000


@dataclass
class EngineStats:
    """What one ``count()`` / ``list()`` call actually executed.

    The engine resets this on every ``count()`` / ``list()`` entry and
    fills it as the run proceeds, so after a call it is a faithful record
    of *that* run: the box plan size, the backend mix the density dispatch
    chose, shard shapes, streaming working-set peaks, slice-cache hits, and
    the block I/Os measured on the attached ``iomodel.BlockDevice`` (the
    numbers ``benchmarks/outofcore.py`` compares against the paper's
    Thm. 10 bound). All counters are plain ints/lists — cheap to snapshot
    or serialize.
    """

    n_boxes: int = 0
    n_dense_boxes: int = 0
    n_binary_boxes: int = 0
    n_pallas_boxes: int = 0
    n_host_boxes: int = 0
    n_fused_boxes: int = 0             # whole box on the fused megakernel
    # per-box device ledger (kernels/ledger): launches + padded transfer
    # bytes across every kernel lane — the measured basis of the fused
    # kernel's >=10x launch-reduction claim
    device_invocations: int = 0
    device_transfer_bytes: int = 0
    max_box_device_invocations: int = 0
    n_shards: int = 1
    n_rescans: int = 0
    dense_threshold: float = 0.0
    shard_edges: List[int] = field(default_factory=list)
    # skew-aware planning (skew="heavy_light"): the plan's lane mix plus
    # the padded-vs-actual word ledger the uniform/heavy-light A/B compares
    skew: str = "uniform"
    heavy_threshold: int = 0           # hub degree cut the plan used
    n_hub_boxes: int = 0               # both ranges heavy -> dense/pallas
    n_light_boxes: int = 0             # both ranges light -> host lane
    n_mixed_boxes: int = 0             # one heavy side   -> host lane
    padded_words: int = 0              # materialized padded-matrix words
    actual_words: int = 0              # real neighbor entries processed
    # async box scheduler (workers > 1): queue-wait/overlap/utilization
    # telemetry plus the observed in-flight peaks (the budget the window
    # promises to respect)
    n_workers: int = 1
    inflight_boxes: int = 0            # configured window (0 = serial run)
    queue_wait_s: float = 0.0          # worker-seconds spent waiting
    build_s: float = 0.0               # worker-seconds building slices
    compute_s: float = 0.0             # worker-seconds in backends
    overlap_s: float = 0.0             # busy-seconds hidden by overlap
    # busy / (workers * wall); None when the run finished too fast to
    # measure (wall == 0 at perf_counter granularity — never a 0/0)
    worker_utilization: Optional[float] = None
    max_inflight_boxes: int = 0        # peak resident materialized slices
    max_inflight_words: int = 0        # peak resident raw slice words
    # streaming executor (out-of-core) accounting
    n_streamed_boxes: int = 0
    slice_words_read: int = 0          # raw CSR words DMA'd across all boxes
    max_slice_words: int = 0           # largest single-box DMA (working set)
    max_slice_padded_words: int = 0    # largest box-local padded matrix
    # measured block I/O on the attached BlockDevice (edge-store runs)
    block_reads: int = 0
    block_writes: int = 0
    word_reads: int = 0
    # LRU slice cache (cache_words > 0): hits skip the device entirely,
    # so they show up as *missing* block_reads relative to a cache-off run
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_words: int = 0
    # sharded-path device array shapes (non-replicated slices)
    local_npad_shape: Optional[Tuple[int, int, int]] = None
    shard_rows: List[int] = field(default_factory=list)
    source: str = "memory"

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def padding_ratio(self) -> float:
        """Materialized padded words per actual neighbor word (1.0 = no
        padded matrix was ever built beyond the real entries)."""
        return self.padded_words / self.actual_words \
            if self.actual_words else 0.0

    def as_info(self) -> dict:
        """Legacy info dict (triangle_count_boxed_vectorized compat)."""
        return {"n_boxes": self.n_boxes, "n_dense_boxes": self.n_dense_boxes,
                "n_shards": self.n_shards, "n_rescans": self.n_rescans}


# ---------------------------------------------------------------------------
# measured density crossover (binary-search vs dense MXU formulation),
# persisted per (jax backend, device kind) under ~/.cache/repro
# ---------------------------------------------------------------------------

_crossover_memo: dict = {}


def _crossover_cache_file() -> str:
    base = os.environ.get("REPRO_CACHE_DIR") \
        or os.path.join(os.path.expanduser("~"), ".cache", "repro")
    return os.path.join(base, "crossover.json")


class _crossover_file_lock:
    """Inter-process lock for the crossover cache's read-modify-write.

    The JSON store itself is written atomically (tmp + ``os.replace``), but
    two processes remeasuring concurrently still race load→merge→store and
    the slower one clobbers the faster one's entries (lost update — exactly
    what happens when pytest workers calibrate side by side under one
    ``REPRO_CACHE_DIR``). An ``flock`` on a sibling ``.lock`` file
    serializes the whole read-modify-write; platforms without ``fcntl``
    (or unwritable cache dirs) degrade to the old lock-free behaviour
    rather than failing execution."""

    def __init__(self):
        self._f = None

    def __enter__(self):
        try:
            import fcntl
            path = _crossover_cache_file() + ".lock"
            os.makedirs(os.path.dirname(path), exist_ok=True)
            self._f = open(path, "a+")
            fcntl.flock(self._f.fileno(), fcntl.LOCK_EX)
        except (ImportError, OSError):
            if self._f is not None:
                self._f.close()
                self._f = None
        return self

    def __exit__(self, *exc):
        if self._f is not None:
            try:
                import fcntl
                fcntl.flock(self._f.fileno(), fcntl.LOCK_UN)
            except (ImportError, OSError):
                pass
            self._f.close()
            self._f = None
        return False


def _crossover_load() -> dict:
    try:
        with open(_crossover_cache_file()) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def _crossover_store(data: dict) -> None:
    path = _crossover_cache_file()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # a read-only home must never break execution


def _active_prefix() -> str:
    """Calibration namespace of the hardware this process runs on: JAX
    backend + device kind (e.g. ``cpu:cpu``, ``tpu:TPU v4``). Every
    crossover entry is keyed under it, so CPU-measured values never leak
    onto real TPU and vice versa."""
    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(dev, 'device_kind', '?')}"


_remeasure_handled = False


def _maybe_clear_remeasure() -> None:
    """``REPRO_CROSSOVER_REMEASURE=1``: drop the *active* backend's
    cached entries once per process (other backends' calibrations in the
    shared file survive), then fall through to normal measure-and-persist
    — so a forced remeasure happens once, not on every call."""
    global _remeasure_handled
    if _remeasure_handled:
        return
    _remeasure_handled = True
    if os.environ.get("REPRO_CROSSOVER_REMEASURE", "") in ("", "0"):
        return
    prefix = _active_prefix() + ":"
    with _crossover_file_lock():
        data = _crossover_load()
        kept = {k: v for k, v in data.items() if not k.startswith(prefix)}
        if len(kept) != len(data):
            _crossover_store(kept)
    for k in list(_crossover_memo):
        if k.startswith(prefix):
            del _crossover_memo[k]


def _cached_crossover(suffix: str, nv: int, measure) -> float:
    """Process-memoized, file-persisted crossover for the active backend:
    ``measure()`` runs only when neither the memo nor the JSON cache has a
    valid entry for ``<backend>:<device_kind>:nv<nv><suffix>``."""
    _maybe_clear_remeasure()
    key = f"{_active_prefix()}:nv{nv}{suffix}"
    if key in _crossover_memo:
        return _crossover_memo[key]
    cached = _crossover_load().get(key)
    if isinstance(cached, (int, float)) and 0.0 < cached <= 1.0:
        _crossover_memo[key] = float(cached)
        return float(cached)
    value = measure()
    _crossover_memo[key] = value
    # merge-under-lock: re-load inside the file lock so a concurrent
    # process's freshly-persisted keys survive this store (the two-process
    # remeasure race regression-tested in tests/test_crossover_cache.py)
    with _crossover_file_lock():
        data = _crossover_load()
        data[key] = value
        _crossover_store(data)
    return value


def measure_dense_crossover(nv: int = 256, repeats: int = 3,
                            seed: int = 0) -> float:
    """Lowest box density where the dense MXU formulation beats the
    binary-search backend, measured once per (jax backend, device kind).

    The measurement is persisted to a JSON cache
    (``$REPRO_CACHE_DIR/crossover.json``, default ``~/.cache/repro``)
    keyed by backend + device kind so a fleet of processes on the same
    hardware calibrates once, not per process — and a CPU-measured value
    is never consulted on TPU. Set ``REPRO_CROSSOVER_REMEASURE=1`` to
    drop the active backend's entries and measure fresh (e.g. after a
    driver/runtime upgrade); other backends' entries are untouched. Falls
    back to 1.0 (never dense) only if dense never wins on the sampled
    grid.
    """
    return _cached_crossover(
        "", nv, lambda: _measure_dense_crossover(nv, repeats, seed))


def _measure_dense_crossover(nv: int, repeats: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    densities = (0.01, 0.02, 0.05, 0.10, 0.20, 0.40)
    crossover = 1.0
    for d in densities:
        adj = np.triu(rng.random((nv, nv)) < d, k=1)
        src, dst = np.nonzero(adj)
        if len(src) == 0:
            continue
        indptr, indices = csr_from_edges(src, dst, n_nodes=nv)
        npad = jnp.asarray(pad_neighbors(indptr, indices))
        eu = jnp.asarray(src, jnp.int32)
        ev = jnp.asarray(dst, jnp.int32)
        a = jnp.asarray(adj, jnp.float32)

        def t_binary():
            _count_chunked(npad, eu, ev, chunk=2048).block_until_ready()

        def t_dense():
            jnp.sum(a * (a @ a.T)).block_until_ready()

        t_binary(); t_dense()  # compile outside the timed region
        tb = min(_time(t_binary) for _ in range(repeats))
        td = min(_time(t_dense) for _ in range(repeats))
        if td < tb:
            crossover = d
            break
    return crossover


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure_pallas_crossover(nv: int = 256, repeats: int = 3,
                             seed: int = 0) -> float:
    """Lowest box density where the Pallas rotation-intersect kernel beats
    the binary-search backend — the measured lower edge of the mid-density
    'pallas band' (static default: dense crossover / 4).

    Calibrated the same way as ``measure_dense_crossover`` and persisted
    next to it in the same JSON cache (key suffix ``:pallas``), once per
    (jax backend, device kind); ``REPRO_CROSSOVER_REMEASURE=1`` refreshes.
    Off-TPU the kernel only runs in interpret mode — orders of magnitude
    slower than any alternative — so the measurement short-circuits to 1.0
    (band never active) without timing the interpreter; 'auto' dispatch
    additionally gates the band on ``use_pallas_kernels``, so this value
    only steers dispatch on real TPU hardware.
    """
    return _cached_crossover(
        ":pallas", nv,
        lambda: 1.0 if jax.default_backend() != "tpu"
        else _measure_pallas_crossover(nv, repeats, seed))


def _measure_pallas_crossover(nv: int, repeats: int, seed: int) -> float:
    from repro.kernels.intersect.ops import intersect_count

    rng = np.random.default_rng(seed)
    densities = (0.005, 0.01, 0.02, 0.05, 0.10, 0.20)
    crossover = 1.0
    for d in densities:
        adj = np.triu(rng.random((nv, nv)) < d, k=1)
        src, dst = np.nonzero(adj)
        if len(src) == 0:
            continue
        indptr, indices = csr_from_edges(src, dst, n_nodes=nv)
        npad_h = pad_neighbors(indptr, indices)
        npad = jnp.asarray(npad_h)
        eu = jnp.asarray(src, jnp.int32)
        ev = jnp.asarray(dst, jnp.int32)
        a_rows = npad_h[src]
        b_rows = npad_h[dst]

        def t_binary():
            _count_chunked(npad, eu, ev, chunk=2048).block_until_ready()

        def t_pallas():
            intersect_count(a_rows, b_rows, use_pallas=True,
                            interpret=False).block_until_ready()

        t_binary(); t_pallas()  # compile outside the timed region
        tb = min(_time(t_binary) for _ in range(repeats))
        tp = min(_time(t_pallas) for _ in range(repeats))
        if tp < tb:
            crossover = d
            break
    return crossover


def measure_fused_crossover(nv: int = 256, repeats: int = 3,
                            seed: int = 0) -> float:
    """Lowest box density where the fused per-box LFTJ megakernel
    (``kernels/lftj_fused``) beats the binary-search backend on a whole
    triangle box — the calibration behind the ``fused_threshold``
    dispatch knob.

    Persisted next to the dense and pallas crossovers in the same
    backend-keyed JSON cache (key suffix ``:fused``);
    ``REPRO_CROSSOVER_REMEASURE=1`` refreshes the active backend only.
    Off-TPU the megakernel runs in interpret mode, never competitively,
    so the measurement short-circuits to 1.0 (band never active) without
    timing the interpreter.
    """
    return _cached_crossover(
        ":fused", nv,
        lambda: 1.0 if jax.default_backend() != "tpu"
        else _measure_fused_crossover(nv, repeats, seed))


def _measure_fused_crossover(nv: int, repeats: int, seed: int) -> float:
    from repro.kernels.lftj_fused.ops import fused_count

    rng = np.random.default_rng(seed)
    densities = (0.005, 0.01, 0.02, 0.05, 0.10, 0.20)
    crossover = 1.0
    dims = ((0, 1), (0, 2), (1, 2))
    for d in densities:
        adj = np.triu(rng.random((nv, nv)) < d, k=1)
        src, dst = np.nonzero(adj)
        if len(src) == 0:
            continue
        indptr, indices = csr_from_edges(src, dst, n_nodes=nv)
        npad = jnp.asarray(pad_neighbors(indptr, indices))
        eu = jnp.asarray(src, jnp.int32)
        ev = jnp.asarray(dst, jnp.int32)
        keys = np.flatnonzero(np.diff(indptr) > 0).astype(np.int64)
        off = np.concatenate(
            [[0], np.cumsum(np.diff(indptr)[keys])]).astype(np.int64)
        csr = (keys, off, np.asarray(indices, np.int32))
        csrs = [csr, csr, csr]

        def t_binary():
            _count_chunked(npad, eu, ev, chunk=2048).block_until_ready()

        def t_fused():
            fused_count(dims, csrs, 3, interpret=False)

        t_binary(); t_fused()  # compile outside the timed region
        tb = min(_time(t_binary) for _ in range(repeats))
        tf = min(_time(t_fused) for _ in range(repeats))
        if tf < tb:
            crossover = d
            break
    return crossover


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class TriangleEngine:
    """Unified boxed/sharded/streaming triangle counting + listing.

    Parameters
    ----------
    src, dst : undirected edge endpoints (host numpy); omit when ``store``
        is given.
    store : path to a ``data.edgestore`` file (or an open ``EdgeStore``):
        the out-of-core source. The engine then keeps only the (V+1)-word
        degree index resident and streams per-box slices from disk, with
        block I/Os measured on ``device``.
    device : optional ``core.iomodel.BlockDevice`` charging source reads.
        Defaults to a fresh device for store-backed runs (block size
        ``io_block_words``, cache sized to the memory budget); ``None``
        for in-memory runs (no accounting).
    mem_words : memory budget for the box planner; ``None`` = one box.
    cache_words : LRU slice-cache budget (``core.executor.SliceCache``),
        split out of the overall host budget: plan with ``mem_words`` and
        spend ``cache_words`` *on top* caching row blocks that adjacent
        boxes re-read (same-stripe x-slabs, shared y-slices). Keeping it a
        separate knob leaves the box plan unchanged, so cache-on vs
        cache-off runs are directly comparable; total host footprint is
        ``mem_words + cache_words``. 0 disables the cache.
    orientation : 'minmax' (paper §2.3) or 'degree' (√|E| out-degree cap).
        Store-backed graphs carry their orientation in the file header.
    backend : 'auto' (density dispatch), or force 'binary' / 'dense' /
        'pallas' / 'host' / 'fused' for every box ('host' is the
        pure-numpy binary-search lane — the GIL-releasing backend the
        async scheduler's worker threads scale with on CPU hosts, where
        XLA serializes concurrent executions; 'fused' dispatches each
        whole box to the ``kernels/lftj_fused`` megakernel — one device
        invocation per box, interpret mode off-TPU — falling back per box
        to pallas/binary when outside the kernel's envelope).
    dense_threshold : box edge-density above which 'auto' picks the dense
        MXU formulation; the string 'measured' uses the persisted
        calibration (``measure_dense_crossover``).
    pallas_threshold : lower edge of the mid-density band 'auto' routes to
        the Pallas intersect kernel (only on TPU — see backend). Default
        ``dense_threshold / 4``; the string 'measured' uses the persisted
        calibration (``measure_pallas_crossover``, cached in the same
        ``crossover.json`` as the dense crossover).
    fused_threshold : density above which 'auto' prefers the fused
        per-box megakernel over the staged pallas band (TPU only).
        Default ``None`` keeps density dispatch off the fused lane
        (heavy/light hub boxes still route to it on TPU); the string
        'measured' uses the persisted ``measure_fused_crossover``
        calibration (key suffix ``:fused`` in the same cache).
    degree_bins : bin vertices by degree (power-of-4 widths) so padding is
        per-bin instead of global K = max degree (skewed graphs). In-memory
        engines run the global binned layout; store-backed engines bin
        *per box slice* inside the streaming executor (the out-of-core
        analogue — same counts, padding bounded by the bin growth factor
        instead of the box-local max degree). Sharded listing runs the
        per-bin-pair listing kernel. Never ignored, never a silent
        fallback.
    skew : 'uniform' (default, the mass-budgeted grid cutter) or
        'heavy_light': classify vertices heavy (degree >= heavy_threshold)
        vs light from the resident degree index and break every box range
        at class transitions, so each box is pure-class per axis. Hub-hub
        boxes (near-dense by construction) route to the dense lane, or to
        the fused megakernel when the one-hot footprint cannot fit and the
        platform compiles Pallas; light and mixed boxes route to the host
        searchsorted lane,
        which never materializes a padded matrix. Lane decisions are
        recorded in ``EngineStats`` (``n_hub_boxes`` / ``n_light_boxes`` /
        ``n_mixed_boxes``, ``padded_words`` vs ``actual_words``) for exact
        A/B against the uniform planner.
    heavy_threshold : hub degree cut for ``skew='heavy_light'``; default
        ``heavy_threshold_default`` (√(2·|E|)-style).
    devices : devices for box sharding; default ``jax.devices()``.
    chunk : edge-chunk length of the scan (peak memory O(chunk · K)).
    prefetch_depth : how many box slices the host builds ahead of the
        device (``data.pipeline.Prefetcher`` double-buffering).
    workers : worker threads of the async box scheduler. 1 (default) is
        the sequential oracle — one box in flight behind a Prefetcher.
        With ``workers > 1`` the box work-queue drains LPT-first across a
        thread pool (plan order when a slice cache is attached, preserving
        the serial read stream); counts and listings are reduced in fixed
        box order, so the output is identical to the ``workers=1`` run.
        The spawned pool is clamped to the hardware parallelism
        (``os.cpu_count()``): threads beyond the cores measurably thrash.
    inflight_boxes : in-flight window of the async scheduler — at most
        this many materialized slices resident at once (default
        ``2 * workers``), with total resident raw words additionally
        capped at ``inflight_boxes * mem_words`` when a budget is set.
        Host memory of a parallel run is therefore bounded by the window,
        not the box count.
    use_pallas_kernels : run kernels compiled (TPU) vs interpret; default
        only compiles on TPU.
    """

    def __init__(self, src: Optional[np.ndarray] = None,
                 dst: Optional[np.ndarray] = None, *,
                 store=None,
                 device: Optional[BlockDevice] = None,
                 io_block_words: int = 4096,
                 mem_words: Optional[int] = None,
                 cache_words: int = 0,
                 orientation: str = "minmax",
                 backend: str = "auto",
                 dense_threshold=0.05,
                 pallas_threshold=None,
                 fused_threshold=None,
                 degree_bins: bool = False,
                 skew: str = "uniform",
                 heavy_threshold: Optional[int] = None,
                 devices: Optional[Sequence] = None,
                 shard: str | bool = "auto",
                 chunk: int = 2048,
                 prefetch_depth: int = 2,
                 workers: int = 1,
                 inflight_boxes: Optional[int] = None,
                 use_pallas_kernels: Optional[bool] = None,
                 tracer=None,
                 metrics=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if skew not in ("uniform", "heavy_light"):
            raise ValueError(
                f"skew {skew!r} not in ('uniform', 'heavy_light')")
        self.backend = backend
        # observability: span/event recorder (obs.trace.Tracer) and the
        # cross-layer MetricsRegistry; None by default — the traced-off
        # path is a single attribute check per site
        self.tracer = tracer
        self.metrics = metrics
        self.degree_bins = degree_bins
        self.skew = skew
        self.heavy_threshold = heavy_threshold
        self.chunk = int(chunk)
        self.mem_words = mem_words
        self.prefetch_depth = int(prefetch_depth)
        self.workers = max(1, int(workers))
        self.inflight_boxes = max(1, int(inflight_boxes)) \
            if inflight_boxes is not None else max(2, 2 * self.workers)
        if use_pallas_kernels is None:
            use_pallas_kernels = jax.default_backend() == "tpu"
        self.use_pallas_kernels = bool(use_pallas_kernels)

        self.devices = list(jax.devices()) if devices is None else list(devices)
        if shard == "auto":
            self.shard = len(self.devices) > 1
        else:
            self.shard = bool(shard)

        if dense_threshold == "measured":
            dense_threshold = measure_dense_crossover()
        self.dense_threshold = float(dense_threshold)
        # lower edge of the mid-density band 'auto' routes to the Pallas
        # intersect kernel (TPU only): static crossover/4 by default,
        # 'measured' uses the persisted calibration
        if pallas_threshold == "measured":
            pallas_threshold = measure_pallas_crossover()
        self.pallas_threshold = self.dense_threshold / 4.0 \
            if pallas_threshold is None else float(pallas_threshold)
        # density gate of the fused megakernel lane: None disables the
        # density route (hub boxes still take it on TPU), 'measured' uses
        # the :fused calibration from the same backend-keyed cache
        if fused_threshold == "measured":
            fused_threshold = measure_fused_crossover()
        self.fused_threshold = None if fused_threshold is None \
            else float(fused_threshold)

        if store is not None:
            if src is not None or dst is not None:
                raise ValueError("pass either (src, dst) or store=, not both")
            self.source = store if isinstance(store, EdgeStore) \
                else EdgeStore(store)
            if device is None:
                cache = max(2, (mem_words or (1 << 22)) // io_block_words)
                device = BlockDevice(block_words=io_block_words,
                                     cache_blocks=cache)
            self.source.attach_device(device)
            self.device = device
            self.orientation = self.source.orientation
            self.nv = self.source.n_nodes
            self.indptr = self.source.indptr
            self.indices = None          # never resident: streamed per box
            self.a = self.b = None
        else:
            if src is None or dst is None:
                raise ValueError(
                    "TriangleEngine needs either (src, dst) edge arrays or "
                    "store=<edge store path>")
            self.orientation = orientation
            a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
            self.a, self.b = a, b
            self.nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
            self.indptr, self.indices = csr_from_edges(a, b, n_nodes=self.nv) \
                if self.nv else (np.zeros(1, np.int64), np.zeros(0, np.int32))
            self.device = device
            self.source = InMemoryEdgeSource(self.indptr, self.indices,
                                             device=device,
                                             orientation=self.orientation)
        self.cache_words = int(cache_words)
        self._slice_cache: Optional[SliceCache] = None
        if self.cache_words > 0:
            self._slice_cache = SliceCache(self.source, self.cache_words,
                                           tracer=tracer)
            self.source = self._slice_cache
        if self.shard and self.indices is None:
            warnings.warn(
                "sharded execution stages the store-backed neighbor stream "
                "through host memory (one full sequential pass); for graphs "
                "larger than host RAM pass shard=False to keep the "
                "bounded-memory streaming path.", stacklevel=2)
        self._npad = None
        self._npad_host = None
        self._bins = None
        self._plan_cache: Optional[Tuple[Optional[int], list]] = None
        # box -> lane ("hub"/"light"/"mixed"), filled by the heavy_light
        # planner; the lane steers _pick_backend for planned boxes
        self._box_lane: dict = {}
        self._skew_threshold = 0
        self.stats = EngineStats(dense_threshold=self.dense_threshold,
                                 skew=self.skew)

    # -- lazy derived state --------------------------------------------------

    @property
    def npad_host(self) -> np.ndarray:
        """Global padded neighbor matrix — legacy accessor. The streaming
        paths never touch this; building it for a store-backed graph pages
        the whole neighbor stream in."""
        if self._npad_host is None:
            indptr, indices = self._resident_csr()
            self._npad_host = pad_neighbors(indptr, indices)
        return self._npad_host

    @property
    def npad(self) -> jnp.ndarray:
        if self._npad is None:
            self._npad = jnp.asarray(self.npad_host)
        return self._npad

    @property
    def bins(self):
        if self._bins is None:
            indptr, indices = self._resident_csr()
            self._bins = pad_neighbors_binned(indptr, indices)
        return self._bins

    def _resident_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        if self.indices is not None:
            return self.indptr, self.indices
        # whole-graph staging read: bypass the slice cache — one sequential
        # pass can't benefit from it and would churn the entire LRU
        src = self._slice_cache.source if self._slice_cache is not None \
            else self.source
        _, indices = src.read_rows(0, self.nv - 1)
        return self.indptr, indices

    # -- streaming ingest ------------------------------------------------------

    @classmethod
    def ingest(cls, store_path, edges, *,
               orientation: str = "minmax",
               chunk_rows: int = 4096,
               align_words: int = 1024,
               ingest_budget_words: int = 1 << 22,
               prefetch_batches: bool = True,
               **engine_kw) -> "TriangleEngine":
        """Stream undirected edges into a chunked-CSR store, bounded-memory,
        and return a store-backed engine over it.

        ``edges`` is either an iterable of ``(src, dst)`` array batches
        (e.g. a generator parsing a file too big for RAM) or a single
        ``(src, dst)`` pair of arrays (sliced into batches internally).
        The batches flow through ``data.edgestore.EdgeStoreWriter``: spill
        runs under ``ingest_budget_words`` (4-byte words), then an external
        merge — peak ingest allocations stay ~2x the budget plus the O(V)
        degree index, so the graph never has to fit in RAM, *including*
        during ingest. With ``prefetch_batches`` the producer runs one
        batch ahead on a ``data.pipeline.Prefetcher`` thread, overlapping
        batch parsing with sort-and-spill.

        Remaining keyword arguments (``mem_words``, ``cache_words``,
        ``backend``, ...) are forwarded to the ``TriangleEngine``
        constructor for the returned engine.
        """
        from repro.data.edgestore import EdgeStoreWriter
        from repro.data.pipeline import Prefetcher, edge_batches

        if isinstance(edges, tuple) and len(edges) == 2 \
                and np.ndim(edges[0]) == 1:
            edges = edge_batches(*edges)
        writer = EdgeStoreWriter(store_path, orientation=orientation,
                                 chunk_rows=chunk_rows,
                                 align_words=align_words,
                                 budget_words=ingest_budget_words)
        it = Prefetcher(iter(edges), depth=1) if prefetch_batches \
            else iter(edges)
        try:
            with writer:
                for src, dst in it:
                    writer.add_edges(src, dst)
        finally:
            if isinstance(it, Prefetcher):
                it.close()
        return cls(store=writer.path, **engine_kw)

    # -- box planning ---------------------------------------------------------

    def plan(self) -> List[Tuple[int, int, int, int]]:
        """Box plan [(lx, hx, ly, hy)]; one unbounded box without a budget.

        Cached per ``mem_words`` — the probe/provision pass is the expensive
        host-side step and the plan is deterministic. In-memory graphs use
        the faithful TrieArray prober; store-backed graphs plan from the
        resident degree index (``plan_boxes_from_degrees``) so planning
        itself stays out-of-core.
        """
        if self._plan_cache is not None \
                and self._plan_cache[0] == self.mem_words:
            return self._plan_cache[1]
        boxes = self._plan_uncached()
        self._plan_cache = (self.mem_words, boxes)
        return boxes

    def _plan_uncached(self) -> List[Tuple[int, int, int, int]]:
        if self.nv == 0 or self.source.n_edges == 0:
            self._box_lane = {}
            return []
        # hy < lx pruning is only sound when every edge has x < y (minmax)
        prune = self.orientation == "minmax"
        if self.skew == "heavy_light":
            # skew-resistant plan straight from the resident degree index
            # (works identically in-memory and store-backed): pure-class
            # ranges per axis, lane metadata per box
            from .boxing import plan_boxes_heavy_light
            sp = plan_boxes_heavy_light(self.indptr, self.mem_words,
                                        monotone_prune=prune,
                                        heavy_threshold=self.heavy_threshold)
            self._box_lane = dict(zip(sp.boxes, sp.lanes))
            self._skew_threshold = sp.threshold
            return sp.boxes
        self._box_lane = {}
        if self.mem_words is None:
            return [(0, self.nv - 1, 0, self.nv - 1)]
        if self.indices is None:
            from .boxing import plan_boxes_from_degrees
            return plan_boxes_from_degrees(self.indptr, self.mem_words,
                                           monotone_prune=prune)
        from .boxing import plan_boxes
        from .triearray import TrieArray
        ta = TrieArray.from_edges(self.a, self.b)
        if ta.words() <= self.mem_words:
            return [(0, self.nv - 1, 0, self.nv - 1)]
        return plan_boxes(ta, self.mem_words, monotone_prune=prune)

    def _box_edges(self, box, source=None) -> Tuple[np.ndarray, np.ndarray,
                                                    int, int]:
        """In-box oriented edges (x ∈ [lx,hx], y ∈ [ly,hy]) + box widths."""
        eu, ev, wx, wy, _slab = self._box_edges_full(box, source)
        return eu, ev, wx, wy

    def _box_edges_full(self, box, source=None):
        """`_box_edges` plus the raw x-range slab, so a follow-up
        ``StreamingExecutor.count_box`` can reuse the already-charged DMA
        instead of re-reading the rows from the source."""
        src = self.source if source is None else source
        lx, hx, ly, hy = box
        lx_, hx_ = max(lx, 0), min(hx, self.nv - 1)
        ly_, hy_ = max(ly, 0), min(hy, self.nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64), 0, 0, None)
        ip, vals = src.read_rows(lx_, hx_)
        eu = np.repeat(np.arange(lx_, hx_ + 1), np.diff(ip))
        ev = vals.astype(np.int64)
        sel = (ev >= ly_) & (ev <= hy_)
        return (eu[sel], ev[sel], hx_ - lx_ + 1, hy_ - ly_ + 1, (ip, vals))

    def _staged_source(self):
        """Source for the *sharded* paths.

        Sharded execution concatenates every box's edges on the host anyway
        (the work-list is built before shard_map), so a store-backed graph
        is staged through host memory with ONE sequential charged pass
        (|E|/B block reads) instead of re-reading overlapping x-slabs per
        box and again per shard gather. Bounded-memory execution is the
        non-sharded streaming path.
        """
        if self.indices is not None:
            return self.source
        indptr, indices = self._resident_csr()   # one charged full read
        return InMemoryEdgeSource(indptr, indices,
                                  orientation=self.orientation)

    def _pick_backend(self, n_edges: int, wx: int, wy: int,
                      box=None) -> str:
        """Density dispatch: dense above the crossover, Pallas for the
        mid-density band, binary-search otherwise.

        With ``skew="heavy_light"`` a planned ``box`` overrides density:
        hub-hub boxes go to the dense MXU lane (Pallas/binary when the
        one-hot footprint cannot fit), light and mixed boxes to the host
        searchsorted lane — neither ever materializes a padded matrix.

        The Pallas rotation-intersect kernel is only profitable compiled on
        real TPU hardware, so 'auto' routes mid-density boxes (density
        above ``pallas_threshold``, default dense crossover / 4) to it
        **only when**
        ``use_pallas_kernels`` is set (default: running on TPU). On CPU
        backends the kernel would run in interpret mode — orders of
        magnitude slower — so 'auto' never selects it there; force
        ``backend="pallas"`` to test that path explicitly.
        """
        if self.backend != "auto":
            return self.backend
        lane = self._box_lane.get(box) if box is not None else None
        if lane is not None:
            if lane == "hub":
                est_rows = min(wx, n_edges) + min(wy, n_edges)
                est_cols = min(self.nv, 16 * max(1, n_edges))
                if est_rows * est_cols <= _DENSE_WORDS_CAP:
                    return "dense"
                # hub boxes too big for the one-hot footprint dispatch
                # whole to the fused megakernel (compiled TPU only): one
                # launch instead of one per frontier level
                return "fused" if self.use_pallas_kernels else "binary"
            return "host"
        density = n_edges / max(1, wx * wy)
        # feasibility of the dense one-hots: the executor compacts rows to
        # the referenced endpoints (≤ min(width, edges) per side) and
        # columns to the z values occurring in the slice (≤ min(V, slice
        # neighbor entries)), so the cap is slice-scaled, not O(V) — dense
        # dispatch stays live on graphs far larger than memory
        est_rows = min(wx, n_edges) + min(wy, n_edges)
        est_cols = min(self.nv, 16 * max(1, n_edges))
        if density > self.dense_threshold \
                and est_rows * est_cols <= _DENSE_WORDS_CAP:
            return "dense"
        if self.use_pallas_kernels and self.fused_threshold is not None \
                and density > self.fused_threshold:
            return "fused"
        if self.use_pallas_kernels \
                and density > self.pallas_threshold:
            return "pallas"
        return "binary"

    # -- executor / stats plumbing --------------------------------------------

    def _make_executor(self, source=None) -> StreamingExecutor:
        # total resident slice words of the parallel window are bounded by
        # window-size × per-box budget (each planned slice is itself under
        # mem_words, modulo pinned spill rows)
        inflight_words = self.inflight_boxes * self.mem_words \
            if self.mem_words is not None else None
        return StreamingExecutor(self.source if source is None else source,
                                 pick_backend=self._pick_backend,
                                 chunk=self.chunk,
                                 prefetch_depth=self.prefetch_depth,
                                 use_pallas_kernels=self.use_pallas_kernels,
                                 dense_words_cap=_DENSE_WORDS_CAP,
                                 stats=self.stats,
                                 workers=self.workers,
                                 # store-backed binned layout lives in the
                                 # executor (per box slice); in-memory
                                 # engines keep the global binned path
                                 degree_bins=self.degree_bins
                                 and self.indices is None,
                                 inflight_boxes=self.inflight_boxes,
                                 inflight_words=inflight_words,
                                 tracer=self.tracer,
                                 metrics=self.metrics)

    def _reset_stats(self, n_boxes: int) -> None:
        self.stats = EngineStats(dense_threshold=self.dense_threshold,
                                 n_boxes=n_boxes,
                                 n_workers=self.workers,
                                 skew=self.skew,
                                 heavy_threshold=self._skew_threshold,
                                 source="edgestore" if self.indices is None
                                 else "memory")
        if self._box_lane:
            lanes = list(self._box_lane.values())
            self.stats.n_hub_boxes = lanes.count("hub")
            self.stats.n_light_boxes = lanes.count("light")
            self.stats.n_mixed_boxes = lanes.count("mixed")

    def _io_mark(self):
        cache = self._slice_cache
        cm = (cache.hits, cache.misses, cache.hit_words) if cache else None
        if self.device is None:
            return (None, cm)
        s = self.device.stats
        return ((s.block_reads, s.block_writes, s.word_reads), cm)

    def _io_collect(self, mark) -> None:
        io_mark, cm = mark
        if self.device is not None and io_mark is not None:
            s = self.device.stats
            self.stats.block_reads = s.block_reads - io_mark[0]
            self.stats.block_writes = s.block_writes - io_mark[1]
            self.stats.word_reads = s.word_reads - io_mark[2]
        if self._slice_cache is not None and cm is not None:
            cache = self._slice_cache
            self.stats.cache_hits = cache.hits - cm[0]
            self.stats.cache_misses = cache.misses - cm[1]
            self.stats.cache_hit_words = cache.hit_words - cm[2]

    # -- counting -------------------------------------------------------------

    def count(self) -> int:
        if self.tracer is not None:
            with self.tracer.span("engine.count", nv=self.nv,
                                  workers=self.workers):
                total = self._count_impl()
        else:
            total = self._count_impl()
        if self.metrics is not None:
            self.metrics.publish_stats(self.stats, "engine", mode="count")
        return total

    def _count_impl(self) -> int:
        boxes = self.plan()
        self._reset_stats(len(boxes))
        mark = self._io_mark()
        if not self.shard:
            ex = self._make_executor()
            if self.degree_bins and self.indices is not None:
                total = self._count_binned_boxes(boxes, ex)
            else:
                total = ex.run_count(boxes)
            self._io_collect(mark)
            return total
        # sharded: dense/pallas boxes run locally through the executor;
        # binary boxes are the data-parallel work-list. The neighbor stream
        # is staged through host memory once (see _staged_source).
        total = 0
        staged = self._staged_source()
        ex = self._make_executor(source=staged)
        sparse: List[Tuple[np.ndarray, np.ndarray]] = []
        sparse_boxes: List[Tuple[int, int, int, int]] = []
        heavy: List[Tuple[int, int, int, int]] = []
        for box in boxes:
            eu, ev, wx, wy, slab = self._box_edges_full(box, staged)
            if len(eu) == 0:
                continue
            be = self._pick_backend(len(eu), wx, wy, box)
            if be in ("dense", "pallas"):
                if self.workers > 1 \
                        and getattr(staged, "device", None) is None:
                    # the local heavy boxes consume the same async queue as
                    # the non-sharded path; only when the staged source is
                    # uncharged (else the queue's fresh x-slab read would
                    # double-bill the DMA the slab reuse avoids)
                    heavy.append(box)
                else:
                    total += ex.count_box(box, x_slab=slab)
            else:
                sparse.append((eu, ev))
                sparse_boxes.append(box)
                self.stats.n_binary_boxes += 1
        if heavy:
            total += ex.run_count(heavy)
        if sparse:
            if self.degree_bins:
                total += self._count_sharded_binned(sparse, staged,
                                                    boxes=sparse_boxes)
            else:
                total += self._count_sharded(sparse, staged,
                                             boxes=sparse_boxes)
        self._io_collect(mark)
        return total

    def _count_binned_boxes(self, boxes, ex: StreamingExecutor) -> int:
        """Degree-binned single-host path: dense/pallas boxes stream through
        the executor; binary boxes concatenate into the per-bin-pair probe
        (padding waste is per-bin K, not global max degree)."""
        total = 0
        eus, evs = [], []
        for box in boxes:
            eu, ev, wx, wy, slab = self._box_edges_full(box)
            if len(eu) == 0:
                continue
            be = self._pick_backend(len(eu), wx, wy, box)
            if be in ("dense", "pallas"):
                total += ex.count_box(box, x_slab=slab)
            else:
                eus.append(eu)
                evs.append(ev)
                self.stats.n_binary_boxes += 1
        if eus:
            total += self._count_binned(np.concatenate(eus),
                                        np.concatenate(evs))
        return total

    def _count_binned(self, eu, ev) -> int:
        """Degree-binned count: gather per (bin_u, bin_v) pair, probe the
        narrower rows into the wider. Padding waste is per-bin K, not
        global max degree."""
        row_bin, bins = self.bins
        bin_pos = np.zeros(self.nv, dtype=np.int64)
        for rows, _ in bins:
            bin_pos[rows] = np.arange(len(rows))
        bu = row_bin[eu]
        bv = row_bin[ev]
        total = 0
        live = bv >= 0  # sink y-endpoints (out-degree 0) intersect empty
        for i, (_, npad_i) in enumerate(bins):
            for j, (_, npad_j) in enumerate(bins):
                sel = live & (bu == i) & (bv == j)
                if not sel.any():
                    continue
                a_rows = jnp.asarray(npad_i[bin_pos[eu[sel]]])
                b_rows = jnp.asarray(npad_j[bin_pos[ev[sel]]])
                total += int(_count_rows_chunked(a_rows, b_rows,
                                                 chunk=self.chunk))
        return total

    # -- sharded execution (the "Boxes" sharding rule) -------------------------

    def _schedule(self, edge_lists, boxes=None) -> list:
        """LPT shard schedule. The uniform planner balances on in-box edge
        counts; under ``skew="heavy_light"`` the cost is the box's actual
        *slice mass* (Σ member degrees via ``box_mass_costs``) — on skewed
        graphs a hub box's work is dominated by its neighbor mass, not its
        edge count, and edge-count LPT leaves workers idle behind it."""
        if boxes is not None and self.skew == "heavy_light":
            from repro.parallel.sharding import box_mass_costs
            return balanced_box_schedule(
                box_mass_costs(self.indptr, boxes), len(self.devices))
        return balanced_box_schedule([len(eu) for eu, _ in edge_lists],
                                     len(self.devices))

    def _gather(self, rows: np.ndarray, source=None) -> Tuple[np.ndarray,
                                                              np.ndarray]:
        """(deg, concat neighbor values) for sorted global rows, reading
        contiguous runs from the source (charged when store-backed)."""
        src = self.source if source is None else source
        if len(rows) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int32)
        splits = np.flatnonzero(np.diff(rows) > 1) + 1
        degs, vals = [], []
        for run in np.split(rows, splits):
            ip, v = src.read_rows(int(run[0]), int(run[-1]))
            # runs are consecutive ids, so every row in [run0, run-1] is ours
            degs.append(np.diff(ip))
            vals.append(v)
        return np.concatenate(degs), np.concatenate(vals)

    def _shard_slices(self, edge_lists, schedule, pad_multiple, source=None):
        out = shard_local_slices(edge_lists, schedule,
                                 lambda rows: self._gather(rows, source),
                                 pad_multiple=pad_multiple)
        eu_s, ev_s, ok_s, npad_s, rows_s = out
        self.stats.n_shards = len(self.devices)
        self.stats.shard_edges = [int(x) for x in ok_s.sum(axis=1)]
        self.stats.shard_rows = [int((r >= 0).sum()) for r in rows_s]
        self.stats.local_npad_shape = tuple(npad_s.shape)
        return eu_s, ev_s, ok_s, npad_s, rows_s

    def _count_sharded(self, edge_lists, source=None, boxes=None) -> int:
        """Data-parallel box execution with *non-replicated* neighbor data:
        every shard receives only the renumbered rows its boxes touch, so
        per-device memory is O(slice), not O(V·K)."""
        mesh = box_mesh(self.devices)
        schedule = self._schedule(edge_lists, boxes=boxes)
        eu_s, ev_s, ok_s, npad_s, _rows = self._shard_slices(
            edge_lists, schedule, pad_multiple=self.chunk, source=source)
        chunk = self.chunk

        @jax.jit
        @partial(shard_map, mesh=mesh,
                 in_specs=(P("boxes", None, None), P("boxes", None),
                           P("boxes", None), P("boxes", None)),
                 out_specs=P("boxes"), check_rep=False)
        def run(npad, eu, ev, ok):
            npad = npad[0]                      # this shard's local slice
            n_chunks = eu.shape[1] // chunk

            def body(carry, inp):
                u, v, valid = inp
                cnt = jax.vmap(_row_intersect_count)(npad[u], npad[v])
                return carry + jnp.sum(cnt * valid), None

            total, _ = jax.lax.scan(
                body, jnp.int32(0),
                (eu.reshape(n_chunks, chunk), ev.reshape(n_chunks, chunk),
                 ok.reshape(n_chunks, chunk)))
            return total.reshape(1)

        parts = run(jnp.asarray(npad_s), jnp.asarray(eu_s),
                    jnp.asarray(ev_s), jnp.asarray(ok_s))
        return int(jnp.sum(parts))

    def _binned_layout(self, source=None):
        """(row_bin, bins, bin_pos) for the sharded binned kernels.

        In-memory engines use the cached global layout; store-backed
        engines build it from the already-staged source CSR (the sharded
        paths stage the neighbor stream through host memory anyway), so
        ``degree_bins=True`` works sharded for both — never dropped.
        """
        if self.indices is not None:
            row_bin, bins = self.bins
        else:
            src = self.source if source is None else source
            row_bin, bins = pad_neighbors_binned(
                np.asarray(src.indptr), np.asarray(src.indices))
        bin_pos = np.zeros(self.nv, dtype=np.int64)
        for rows, _ in bins:
            bin_pos[rows] = np.arange(len(rows))
        return row_bin, bins, bin_pos

    def _count_sharded_binned(self, edge_lists, source=None,
                              boxes=None) -> int:
        """Sharded count through the degree-binned layout: one kernel per
        (bin_u, bin_v) width pair, each shard holding only the bin rows its
        edges reference. This wires ``pad_neighbors_binned`` into the
        shard_map path — a hub row no longer sets the padded width of every
        device array."""
        row_bin, bins, bin_pos = self._binned_layout(source)
        mesh = box_mesh(self.devices)
        schedule = self._schedule(edge_lists, boxes=boxes)
        n_shards = len(schedule)
        per_shard = []
        for boxes in schedule:
            if boxes:
                eu = np.concatenate([edge_lists[b][0] for b in boxes])
                ev = np.concatenate([edge_lists[b][1] for b in boxes])
            else:
                eu = ev = np.zeros(0, np.int64)
            per_shard.append((eu, ev))
        self.stats.n_shards = n_shards
        self.stats.shard_edges = [len(eu) for eu, _ in per_shard]

        pairs = set()
        for eu, ev in per_shard:
            if len(eu):
                live = row_bin[ev] >= 0
                pairs |= set(zip(row_bin[eu[live]].tolist(),
                                 row_bin[ev[live]].tolist()))
        total = 0
        chunk = self.chunk

        # one function object for every bin pair: jit keys retraces on the
        # (ka, kb, ra, rb, L) shapes, so pairs sharing shapes share a trace
        @jax.jit
        @partial(shard_map, mesh=mesh,
                 in_specs=(P("boxes", None, None), P("boxes", None, None),
                           P("boxes", None), P("boxes", None),
                           P("boxes", None)),
                 out_specs=P("boxes"), check_rep=False)
        def run(npa, npb, eu, ev, ok):
            npa, npb = npa[0], npb[0]
            n_chunks = eu.shape[1] // chunk

            def body(carry, inp):
                u, v, valid = inp
                cnt = jax.vmap(_row_intersect_count)(npa[u], npb[v])
                return carry + jnp.sum(cnt * valid), None

            t, _ = jax.lax.scan(
                body, jnp.int32(0),
                (eu.reshape(n_chunks, chunk),
                 ev.reshape(n_chunks, chunk),
                 ok.reshape(n_chunks, chunk)))
            return t.reshape(1)

        for (i, j) in sorted(pairs):
            npa_i, npb_j = bins[i][1], bins[j][1]
            shard_data = []
            for eu, ev in per_shard:
                if len(eu) == 0:
                    shard_data.append((np.zeros(0, np.int64),) * 4)
                    continue
                sel = (row_bin[eu] == i) & (row_bin[ev] == j)
                eu_s, ev_s = eu[sel], ev[sel]
                ur = np.unique(eu_s)
                vr = np.unique(ev_s)
                shard_data.append((eu_s, ev_s, ur, vr))
            ra = max([len(d[2]) for d in shard_data] + [0]) + 1
            rb = max([len(d[3]) for d in shard_data] + [0]) + 1
            lmax = max([len(d[0]) for d in shard_data] + [1])
            L = -(-lmax // chunk) * chunk
            ka, kb = npa_i.shape[1], npb_j.shape[1]
            npa = np.full((n_shards, ra, ka), SENTINEL, np.int32)
            npb = np.full((n_shards, rb, kb), SENTINEL, np.int32)
            eu_l = np.full((n_shards, L), ra - 1, np.int32)
            ev_l = np.full((n_shards, L), rb - 1, np.int32)
            ok_l = np.zeros((n_shards, L), np.int32)
            for s, (eu_s, ev_s, ur, vr) in enumerate(shard_data):
                if len(eu_s) == 0:
                    continue
                npa[s, :len(ur)] = npa_i[bin_pos[ur]]
                npb[s, :len(vr)] = npb_j[bin_pos[vr]]
                eu_l[s, :len(eu_s)] = np.searchsorted(ur, eu_s)
                ev_l[s, :len(ev_s)] = np.searchsorted(vr, ev_s)
                ok_l[s, :len(eu_s)] = 1

            parts = run(jnp.asarray(npa), jnp.asarray(npb),
                        jnp.asarray(eu_l), jnp.asarray(ev_l),
                        jnp.asarray(ok_l))
            total += int(jnp.sum(parts))
        return total

    # -- listing --------------------------------------------------------------

    def list(self, capacity: Optional[int] = None) -> np.ndarray:
        """Enumerate all triangles; returns canonical sorted (m, 3) rows.

        The output buffer is bounded (``capacity`` triangles per shard/box);
        because the kernels return the *exact* total alongside the buffer,
        overflow is detected and resolved by rescanning with the capacity
        doubled until everything fits (counting is cheap relative to
        materialization, so a rescan costs one extra pass).
        """
        if self.tracer is not None:
            with self.tracer.span("engine.list", nv=self.nv,
                                  workers=self.workers):
                tris = self._list_impl(capacity)
        else:
            tris = self._list_impl(capacity)
        if self.metrics is not None:
            self.metrics.publish_stats(self.stats, "engine", mode="list")
        return tris

    def _list_impl(self, capacity: Optional[int] = None) -> np.ndarray:
        boxes = self.plan()
        self._reset_stats(len(boxes))
        mark = self._io_mark()
        if not self.shard:
            ex = self._make_executor()
            tris = ex.run_list(boxes, capacity)
            self._io_collect(mark)
            return self._canonical(tris)
        staged = self._staged_source()
        edge_lists = []
        kept_boxes = []
        for box in boxes:
            eu, ev, _, _ = self._box_edges(box, staged)
            if len(eu):
                edge_lists.append((eu, ev))
                kept_boxes.append(box)
        if not edge_lists:
            return np.zeros((0, 3), dtype=np.int64)
        if capacity is None:
            m = sum(len(eu) for eu, _ in edge_lists)
            capacity = max(256, m)
        cap = _pow2(max(2, capacity))
        if self.degree_bins:
            # binned sharded listing: per-bin-pair enumeration kernel on
            # the binned widths (same counts/rows as the unbinned kernel,
            # padding bounded by the bin growth factor — no fallback)
            tris = self._list_sharded_binned(edge_lists, cap, staged,
                                             boxes=kept_boxes)
            self._io_collect(mark)
            return self._canonical(tris)
        # the shard slices are identical across capacity rescans: build
        # (and charge) them once, re-run only the kernel on overflow
        mesh = box_mesh(self.devices)
        chunk = min(self.chunk, 1024)
        slices = self._shard_slices(edge_lists,
                                    self._schedule(edge_lists,
                                                   boxes=kept_boxes),
                                    pad_multiple=chunk, source=staged)
        while True:
            tris, ok = self._list_sharded(slices, cap, mesh, chunk)
            if ok:
                break
            self.stats.n_rescans += 1
            cap *= 2
        self._io_collect(mark)
        return self._canonical(tris)

    @staticmethod
    def _canonical(tris: np.ndarray) -> np.ndarray:
        if len(tris) == 0:
            return np.zeros((0, 3), dtype=np.int64)
        tris = np.sort(np.asarray(tris, dtype=np.int64), axis=1)
        order = np.lexsort((tris[:, 2], tris[:, 1], tris[:, 0]))
        return tris[order]

    def _list_sharded(self, slices, cap: int, mesh, chunk: int):
        eu_s, ev_s, ok_s, npad_s, rows_s = slices

        @partial(jax.jit, static_argnames=())
        @partial(shard_map, mesh=mesh,
                 in_specs=(P("boxes", None, None), P("boxes", None),
                           P("boxes", None), P("boxes", None)),
                 out_specs=(P("boxes"), P("boxes", None, None)),
                 check_rep=False)
        def run(npad, eu, ev, ok):
            total, buf = _list_chunked(npad[0], eu[0], ev[0],
                                       cap=cap, chunk=chunk, valid=ok[0])
            return total.reshape(1), buf.reshape(1, cap, 3)

        totals, bufs = run(jnp.asarray(npad_s), jnp.asarray(eu_s),
                           jnp.asarray(ev_s), jnp.asarray(ok_s))
        totals = np.asarray(totals)
        if (totals > cap).any():
            return None, False
        bufs = np.asarray(bufs)
        parts = []
        for s in range(len(totals)):
            t = bufs[s, :totals[s]].astype(np.int64)
            if len(t) == 0:
                continue
            t[:, 0] = rows_s[s][t[:, 0]]   # local row ids -> global vertices
            t[:, 1] = rows_s[s][t[:, 1]]
            parts.append(t)
        tris = np.concatenate(parts) if parts \
            else np.zeros((0, 3), np.int64)
        if self.device is not None:
            self.device.write_words(3 * len(tris))
        return tris, True

    def _list_sharded_binned(self, edge_lists, cap: int, source=None,
                             boxes=None) -> np.ndarray:
        """Sharded listing through the degree-binned layout (the listing
        analogue of ``_count_sharded_binned``): one ``_list_pairs_chunked``
        launch per (bin_u, bin_v) width pair, each shard holding only the
        bin rows its edges reference. The kernel emits *global* (u, v, z)
        triangles directly, so no local-row remap is needed; per-pair
        overflow rescans that pair at doubled capacity."""
        row_bin, bins, bin_pos = self._binned_layout(source)
        mesh = box_mesh(self.devices)
        schedule = self._schedule(edge_lists, boxes=boxes)
        n_shards = len(schedule)
        per_shard = []
        for shard_boxes in schedule:
            if shard_boxes:
                eu = np.concatenate([edge_lists[b][0] for b in shard_boxes])
                ev = np.concatenate([edge_lists[b][1] for b in shard_boxes])
            else:
                eu = ev = np.zeros(0, np.int64)
            per_shard.append((eu, ev))
        self.stats.n_shards = n_shards
        self.stats.shard_edges = [len(eu) for eu, _ in per_shard]

        pairs = set()
        for eu, ev in per_shard:
            if len(eu):
                live = (row_bin[eu] >= 0) & (row_bin[ev] >= 0)
                pairs |= set(zip(row_bin[eu[live]].tolist(),
                                 row_bin[ev[live]].tolist()))
        chunk = min(self.chunk, 1024)
        parts: List[np.ndarray] = []

        def launch(npa, npb, eu_l, ev_l, us_l, vs_l, cap_):
            @jax.jit
            @partial(shard_map, mesh=mesh,
                     in_specs=(P("boxes", None, None),
                               P("boxes", None, None),
                               P("boxes", None), P("boxes", None),
                               P("boxes", None), P("boxes", None)),
                     out_specs=(P("boxes"), P("boxes", None, None)),
                     check_rep=False)
            def run(npa, npb, eu, ev, us, vs):
                total, buf = _list_pairs_chunked(
                    npa[0], npb[0], eu[0], ev[0], us[0], vs[0],
                    cap=cap_, chunk=chunk)
                return total.reshape(1), buf.reshape(1, cap_, 3)

            return run(jnp.asarray(npa), jnp.asarray(npb),
                       jnp.asarray(eu_l), jnp.asarray(ev_l),
                       jnp.asarray(us_l), jnp.asarray(vs_l))

        for (i, j) in sorted(pairs):
            npa_i, npb_j = bins[i][1], bins[j][1]
            shard_data = []
            for eu, ev in per_shard:
                if len(eu) == 0:
                    shard_data.append((np.zeros(0, np.int64),) * 4)
                    continue
                sel = (row_bin[eu] == i) & (row_bin[ev] == j)
                eu_s, ev_s = eu[sel], ev[sel]
                ur = np.unique(eu_s)
                vr = np.unique(ev_s)
                shard_data.append((eu_s, ev_s, ur, vr))
            # one all-SENTINEL pad row on BOTH sides: the kernel may swap
            # the matrices (narrower probes wider) and its pad slots — and
            # ours — must land on an empty row either way
            ra = max([len(d[2]) for d in shard_data] + [0]) + 1
            rb = max([len(d[3]) for d in shard_data] + [0]) + 1
            lmax = max([len(d[0]) for d in shard_data] + [1])
            L = -(-lmax // chunk) * chunk
            ka, kb = npa_i.shape[1], npb_j.shape[1]
            npa = np.full((n_shards, ra, ka), SENTINEL, np.int32)
            npb = np.full((n_shards, rb, kb), SENTINEL, np.int32)
            eu_l = np.full((n_shards, L), ra - 1, np.int32)
            ev_l = np.full((n_shards, L), rb - 1, np.int32)
            us_l = np.zeros((n_shards, L), np.int32)
            vs_l = np.zeros((n_shards, L), np.int32)
            for s, (eu_s, ev_s, ur, vr) in enumerate(shard_data):
                if len(eu_s) == 0:
                    continue
                npa[s, :len(ur)] = npa_i[bin_pos[ur]]
                npb[s, :len(vr)] = npb_j[bin_pos[vr]]
                eu_l[s, :len(eu_s)] = np.searchsorted(ur, eu_s)
                ev_l[s, :len(ev_s)] = np.searchsorted(vr, ev_s)
                us_l[s, :len(eu_s)] = eu_s
                vs_l[s, :len(ev_s)] = ev_s
            cap_p = cap
            while True:
                totals, bufs = launch(npa, npb, eu_l, ev_l, us_l, vs_l,
                                      cap_p)
                totals = np.asarray(totals)
                if not (totals > cap_p).any():
                    break
                self.stats.n_rescans += 1
                cap_p *= 2
            bufs = np.asarray(bufs)
            for s in range(len(totals)):
                if totals[s]:
                    parts.append(bufs[s, :totals[s]].astype(np.int64))
        tris = np.concatenate(parts) if parts \
            else np.zeros((0, 3), np.int64)
        if self.device is not None:
            self.device.write_words(3 * len(tris))
        return tris


# ---------------------------------------------------------------------------
# module-level conveniences
# ---------------------------------------------------------------------------

def engine_count(src, dst, **kw) -> int:
    return TriangleEngine(src, dst, **kw).count()


def engine_list(src, dst, **kw) -> np.ndarray:
    return TriangleEngine(src, dst, **kw).list()
