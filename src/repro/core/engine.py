"""TriangleEngine: the unified execution facade for triangle listing/counting.

Ties together every piece the repo already had but never connected:

  * ``core.boxing.plan_boxes``   — the paper's probe/provision box planner
    (§3, Alg. 2) producing overlap-free (x-range, y-range) work items that
    fit the memory budget;
  * backend dispatch per box      — vectorized binary-search intersection
    (``lftj_jax._count_chunked``), the dense MXU formulation
    Σ mask ⊙ (Ax Ayᵀ) (``kernels.triangle_dense``), or the Pallas rotation
    kernel (``kernels.intersect``), chosen by box edge density against a
    (optionally measured) crossover;
  * box sharding                  — the "Boxes" rule of
    ``repro.parallel.sharding``: a greedy size-balanced (LPT) schedule of
    boxes over a 1-D ``"boxes"`` device mesh executed with ``shard_map``
    (boxes are independent by construction, §3.3, so this is pure data
    parallelism — the paper's "alleviated by parallelization" claim);
  * listing, not just counting    — enumeration into a bounded per-shard
    output buffer with exact total counts, so overflow is detected and
    resolved by a rescan at doubled capacity;
  * degree-binned padding         — ``pad_neighbors_binned`` caps the
    O(V·K_max) padding waste of a single hub row on skewed graphs.

Usage::

    eng = TriangleEngine(src, dst, mem_words=1 << 16)
    n   = eng.count()
    tri = eng.list()          # (n, 3) canonical (min, mid, max) rows
    eng.stats                 # boxes, backends, shards, rescans
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (balanced_box_schedule, box_mesh,
                                     shard_box_edges)

from .lftj_jax import (SENTINEL, _count_chunked, _count_rows_chunked,
                       _list_chunked, _row_intersect_count, csr_from_edges,
                       orient_edges, pad_neighbors, pad_neighbors_binned)

BACKENDS = ("auto", "binary", "dense", "pallas")

# dense-path feasibility guard: (wx + wy) · V one-hot words per box
_DENSE_WORDS_CAP = 64_000_000


@dataclass
class EngineStats:
    """What one ``count()`` / ``list()`` call actually executed."""

    n_boxes: int = 0
    n_dense_boxes: int = 0
    n_binary_boxes: int = 0
    n_pallas_boxes: int = 0
    n_shards: int = 1
    n_rescans: int = 0
    dense_threshold: float = 0.0
    shard_edges: List[int] = field(default_factory=list)

    def as_info(self) -> dict:
        """Legacy info dict (triangle_count_boxed_vectorized compat)."""
        return {"n_boxes": self.n_boxes, "n_dense_boxes": self.n_dense_boxes,
                "n_shards": self.n_shards, "n_rescans": self.n_rescans}


# ---------------------------------------------------------------------------
# measured density crossover (binary-search vs dense MXU formulation)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=4)
def measure_dense_crossover(nv: int = 256, repeats: int = 3,
                            seed: int = 0) -> float:
    """Time both backends on synthetic boxes of rising density and return
    the lowest density where the dense formulation wins.

    Cached per process: the crossover is a property of the backend/hardware,
    not of the input graph. Falls back to 1.0 (never dense) only if dense
    never wins on the sampled grid.
    """
    rng = np.random.default_rng(seed)
    densities = (0.01, 0.02, 0.05, 0.10, 0.20, 0.40)
    crossover = 1.0
    for d in densities:
        adj = np.triu(rng.random((nv, nv)) < d, k=1)
        src, dst = np.nonzero(adj)
        if len(src) == 0:
            continue
        indptr, indices = csr_from_edges(src, dst, n_nodes=nv)
        npad = jnp.asarray(pad_neighbors(indptr, indices))
        eu = jnp.asarray(src, jnp.int32)
        ev = jnp.asarray(dst, jnp.int32)
        a = jnp.asarray(adj, jnp.float32)

        def t_binary():
            _count_chunked(npad, eu, ev, chunk=2048).block_until_ready()

        def t_dense():
            jnp.sum(a * (a @ a.T)).block_until_ready()

        t_binary(); t_dense()  # compile outside the timed region
        tb = min(_time(t_binary) for _ in range(repeats))
        td = min(_time(t_dense) for _ in range(repeats))
        if td < tb:
            crossover = d
            break
    return crossover


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class TriangleEngine:
    """Unified boxed/sharded triangle counting + listing over one graph.

    Parameters
    ----------
    src, dst : undirected edge endpoints (host numpy).
    mem_words : memory budget for the box planner; ``None`` = one box.
    orientation : 'minmax' (paper §2.3) or 'degree' (√|E| out-degree cap).
    backend : 'auto' (density dispatch), or force 'binary' / 'dense' /
        'pallas' for every box.
    dense_threshold : box edge-density above which 'auto' picks the dense
        MXU formulation; the string 'measured' times both backends once per
        process (``measure_dense_crossover``) and uses the result.
    degree_bins : bin vertices by degree (power-of-4 widths) so padding is
        per-bin instead of global K = max degree (skewed graphs).
    devices : devices for box sharding; default ``jax.devices()``. Sharding
        engages whenever more than one device is available (or
        ``shard=True`` forces the shard_map path on a single device).
    chunk : edge-chunk length of the scan (peak memory O(chunk · K)).
    use_pallas_kernels : run kernels compiled (TPU) vs interpret; default
        only compiles on TPU.
    """

    def __init__(self, src: np.ndarray, dst: np.ndarray, *,
                 mem_words: Optional[int] = None,
                 orientation: str = "minmax",
                 backend: str = "auto",
                 dense_threshold=0.05,
                 degree_bins: bool = False,
                 devices: Optional[Sequence] = None,
                 shard: str | bool = "auto",
                 chunk: int = 2048,
                 use_pallas_kernels: Optional[bool] = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        self.orientation = orientation
        self.backend = backend
        self.degree_bins = degree_bins
        self.chunk = int(chunk)
        self.mem_words = mem_words
        if use_pallas_kernels is None:
            use_pallas_kernels = jax.default_backend() == "tpu"
        self.use_pallas_kernels = bool(use_pallas_kernels)

        self.devices = list(jax.devices()) if devices is None else list(devices)
        if shard == "auto":
            self.shard = len(self.devices) > 1
        else:
            self.shard = bool(shard)

        if dense_threshold == "measured":
            dense_threshold = measure_dense_crossover()
        self.dense_threshold = float(dense_threshold)

        a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
        self.a, self.b = a, b
        self.nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        self.indptr, self.indices = csr_from_edges(a, b, n_nodes=self.nv) \
            if self.nv else (np.zeros(1, np.int64), np.zeros(0, np.int32))
        self._npad = None
        self._npad_host = None
        self._bins = None
        self._plan_cache: Optional[Tuple[Optional[int], list]] = None
        self.stats = EngineStats(dense_threshold=self.dense_threshold)

    # -- lazy derived state --------------------------------------------------

    @property
    def npad_host(self) -> np.ndarray:
        if self._npad_host is None:
            self._npad_host = pad_neighbors(self.indptr, self.indices)
        return self._npad_host

    @property
    def npad(self) -> jnp.ndarray:
        if self._npad is None:
            self._npad = jnp.asarray(self.npad_host)
        return self._npad

    @property
    def bins(self):
        if self._bins is None:
            self._bins = pad_neighbors_binned(self.indptr, self.indices)
        return self._bins

    # -- box planning ---------------------------------------------------------

    def plan(self) -> List[Tuple[int, int, int, int]]:
        """Box plan [(lx, hx, ly, hy)]; one unbounded box without a budget.

        Cached per ``mem_words`` — the TrieArray build + probe/provision
        pass is the expensive host-side step and the plan is deterministic.
        """
        if self._plan_cache is not None \
                and self._plan_cache[0] == self.mem_words:
            return self._plan_cache[1]
        boxes = self._plan_uncached()
        self._plan_cache = (self.mem_words, boxes)
        return boxes

    def _plan_uncached(self) -> List[Tuple[int, int, int, int]]:
        if len(self.a) == 0:
            return []
        if self.mem_words is None:
            return [(0, self.nv - 1, 0, self.nv - 1)]
        from .boxing import plan_boxes
        from .triearray import TrieArray
        ta = TrieArray.from_edges(self.a, self.b)
        if ta.words() <= self.mem_words:
            return [(0, self.nv - 1, 0, self.nv - 1)]
        # hy < lx pruning is only sound when every edge has x < y (minmax)
        return plan_boxes(ta, self.mem_words,
                          monotone_prune=self.orientation == "minmax")

    def _box_edges(self, box) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """In-box oriented edges (x ∈ [lx,hx], y ∈ [ly,hy]) + box widths."""
        lx, hx, ly, hy = box
        lx_, hx_ = max(lx, 0), min(hx, self.nv - 1)
        ly_, hy_ = max(ly, 0), min(hy, self.nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            return np.zeros(0, np.int64), np.zeros(0, np.int64), 0, 0
        s0, s1 = self.indptr[lx_], self.indptr[hx_ + 1]
        eu = np.repeat(np.arange(lx_, hx_ + 1),
                       np.diff(self.indptr[lx_:hx_ + 2]))
        ev = self.indices[s0:s1].astype(np.int64)
        sel = (ev >= ly_) & (ev <= hy_)
        return eu[sel], ev[sel], hx_ - lx_ + 1, hy_ - ly_ + 1

    def _pick_backend(self, n_edges: int, wx: int, wy: int) -> str:
        if self.backend != "auto":
            return self.backend
        density = n_edges / max(1, wx * wy)
        if density > self.dense_threshold \
                and (wx + wy) * self.nv <= _DENSE_WORDS_CAP:
            return "dense"
        return "binary"

    # -- counting -------------------------------------------------------------

    def count(self) -> int:
        boxes = self.plan()
        self.stats = EngineStats(dense_threshold=self.dense_threshold,
                                 n_boxes=len(boxes))
        sparse: List[Tuple[np.ndarray, np.ndarray]] = []
        total = 0
        for box in boxes:
            eu, ev, wx, wy = self._box_edges(box)
            if len(eu) == 0:
                continue
            be = self._pick_backend(len(eu), wx, wy)
            if be == "dense":
                total += self._count_dense_box(box, eu, ev, wx, wy)
                self.stats.n_dense_boxes += 1
            elif be == "pallas":
                total += self._count_pallas_box(eu, ev)
                self.stats.n_pallas_boxes += 1
            else:
                sparse.append((eu, ev))
                self.stats.n_binary_boxes += 1
        if sparse:
            if self.shard:
                total += self._count_sharded(sparse)
            else:
                # boxes hold disjoint edge sets and counting is additive, so
                # a single chunked scan over the concatenation beats per-box
                # dispatch (one compile, one device round-trip)
                eu = np.concatenate([e for e, _ in sparse])
                ev = np.concatenate([e for _, e in sparse])
                if self.degree_bins:
                    total += self._count_binned(eu, ev)
                else:
                    total += int(_count_chunked(
                        self.npad, jnp.asarray(eu, jnp.int32),
                        jnp.asarray(ev, jnp.int32), chunk=self.chunk))
        return total

    # dense MXU formulation: z spans the full node range inside a box, so
    # the x-rows / y-rows carry all V columns and count = Σ mask ⊙ (Ax Ayᵀ)
    def _count_dense_box(self, box, eu, ev, wx, wy) -> int:
        from repro.kernels.triangle_dense.ops import triangle_count
        lx_, ly_ = max(box[0], 0), max(box[2], 0)
        hx_, hy_ = lx_ + wx - 1, ly_ + wy - 1
        ax = np.zeros((wx, self.nv), dtype=np.float32)
        ay = np.zeros((wy, self.nv), dtype=np.float32)
        s0, s1 = self.indptr[lx_], self.indptr[hx_ + 1]
        ru = np.repeat(np.arange(lx_, hx_ + 1),
                       np.diff(self.indptr[lx_:hx_ + 2]))
        ax[ru - lx_, self.indices[s0:s1]] = 1.0
        t0, t1 = self.indptr[ly_], self.indptr[hy_ + 1]
        rv = np.repeat(np.arange(ly_, hy_ + 1),
                       np.diff(self.indptr[ly_:hy_ + 2]))
        ay[rv - ly_, self.indices[t0:t1]] = 1.0
        mask = np.zeros((wx, wy), dtype=np.float32)
        mask[eu - lx_, ev - ly_] = 1.0
        if self.use_pallas_kernels:  # MXU tiling pays off on real hardware
            return int(triangle_count(ax, ay, mask, use_pallas=True))
        # host fallback: a plain BLAS matmul beats per-box-shape XLA compiles
        return int((mask * (ax @ ay.T)).sum())

    def _count_pallas_box(self, eu, ev) -> int:
        from repro.kernels.intersect.ops import intersect_count
        npad_np = self.npad_host
        out = intersect_count(npad_np[eu], npad_np[ev], use_pallas=True,
                              interpret=not self.use_pallas_kernels)
        return int(jnp.sum(out))

    def _count_binned(self, eu, ev) -> int:
        """Degree-binned count: gather per (bin_u, bin_v) pair, probe the
        narrower rows into the wider. Padding waste is per-bin K, not
        global max degree."""
        row_bin, bins = self.bins
        bin_pos = np.zeros(self.nv, dtype=np.int64)
        for rows, _ in bins:
            bin_pos[rows] = np.arange(len(rows))
        bu = row_bin[eu]
        bv = row_bin[ev]
        total = 0
        live = bv >= 0  # sink y-endpoints (out-degree 0) intersect empty
        for i, (_, npad_i) in enumerate(bins):
            for j, (_, npad_j) in enumerate(bins):
                sel = live & (bu == i) & (bv == j)
                if not sel.any():
                    continue
                a_rows = jnp.asarray(npad_i[bin_pos[eu[sel]]])
                b_rows = jnp.asarray(npad_j[bin_pos[ev[sel]]])
                total += int(_count_rows_chunked(a_rows, b_rows,
                                                 chunk=self.chunk))
        return total

    # -- sharded execution (the "Boxes" sharding rule) -------------------------

    def _schedule(self, edge_lists) -> list:
        return balanced_box_schedule([len(eu) for eu, _ in edge_lists],
                                     len(self.devices))

    def _count_sharded(self, edge_lists) -> int:
        mesh = box_mesh(self.devices)
        schedule = self._schedule(edge_lists)
        eu_s, ev_s, ok_s = shard_box_edges(edge_lists, schedule,
                                           pad_multiple=self.chunk)
        self.stats.n_shards = len(self.devices)
        self.stats.shard_edges = [int(x) for x in ok_s.sum(axis=1)]
        chunk = self.chunk

        @jax.jit
        @partial(shard_map, mesh=mesh,
                 in_specs=(P(None, None), P("boxes", None),
                           P("boxes", None), P("boxes", None)),
                 out_specs=P("boxes"), check_rep=False)
        def run(npad, eu, ev, ok):
            n_chunks = eu.shape[1] // chunk

            def body(carry, inp):
                u, v, valid = inp
                cnt = jax.vmap(_row_intersect_count)(npad[u], npad[v])
                return carry + jnp.sum(cnt * valid), None

            total, _ = jax.lax.scan(
                body, jnp.int32(0),
                (eu.reshape(n_chunks, chunk), ev.reshape(n_chunks, chunk),
                 ok.reshape(n_chunks, chunk)))
            return total.reshape(1)

        parts = run(self.npad, jnp.asarray(eu_s), jnp.asarray(ev_s),
                    jnp.asarray(ok_s))
        return int(jnp.sum(parts))

    # -- listing --------------------------------------------------------------

    def list(self, capacity: Optional[int] = None) -> np.ndarray:
        """Enumerate all triangles; returns canonical sorted (m, 3) rows.

        The output buffer is bounded (``capacity`` triangles per shard);
        because the kernels return the *exact* total alongside the buffer,
        overflow is detected and resolved by rescanning with the capacity
        doubled until everything fits (counting is cheap relative to
        materialization, so a rescan costs one extra pass).
        """
        boxes = self.plan()
        self.stats = EngineStats(dense_threshold=self.dense_threshold,
                                 n_boxes=len(boxes))
        edge_lists = []
        for box in boxes:
            eu, ev, _, _ = self._box_edges(box)
            if len(eu):
                edge_lists.append((eu, ev))
        if not edge_lists:
            return np.zeros((0, 3), dtype=np.int64)
        if capacity is None:
            m = sum(len(eu) for eu, _ in edge_lists)
            capacity = max(256, m)
        cap = 1 << int(np.ceil(np.log2(max(2, capacity))))
        while True:
            if self.shard:
                tris, ok = self._list_sharded(edge_lists, cap)
            else:
                eu = jnp.asarray(np.concatenate([e for e, _ in edge_lists]),
                                 jnp.int32)
                ev = jnp.asarray(np.concatenate([e for _, e in edge_lists]),
                                 jnp.int32)
                total, buf = _list_chunked(self.npad, eu, ev, cap=cap,
                                           chunk=min(self.chunk, 1024))
                total = int(total)
                ok = total <= cap
                tris = np.asarray(buf[:min(total, cap)])
            if ok:
                break
            self.stats.n_rescans += 1
            cap *= 2
        tris = np.sort(np.asarray(tris, dtype=np.int64), axis=1)
        order = np.lexsort((tris[:, 2], tris[:, 1], tris[:, 0]))
        return tris[order]

    def _list_sharded(self, edge_lists, cap: int):
        mesh = box_mesh(self.devices)
        schedule = self._schedule(edge_lists)
        chunk = min(self.chunk, 1024)
        eu_s, ev_s, ok_s = shard_box_edges(edge_lists, schedule,
                                           pad_multiple=chunk)
        self.stats.n_shards = len(self.devices)
        self.stats.shard_edges = [int(x) for x in ok_s.sum(axis=1)]

        @partial(jax.jit, static_argnames=())
        @partial(shard_map, mesh=mesh,
                 in_specs=(P(None, None), P("boxes", None),
                           P("boxes", None), P("boxes", None)),
                 out_specs=(P("boxes"), P("boxes", None, None)),
                 check_rep=False)
        def run(npad, eu, ev, ok):
            total, buf = _list_chunked(npad, eu[0], ev[0],
                                       cap=cap, chunk=chunk, valid=ok[0])
            return total.reshape(1), buf.reshape(1, cap, 3)

        totals, bufs = run(self.npad, jnp.asarray(eu_s), jnp.asarray(ev_s),
                           jnp.asarray(ok_s))
        totals = np.asarray(totals)
        if (totals > cap).any():
            return None, False
        bufs = np.asarray(bufs)
        tris = np.concatenate([bufs[s, :totals[s]] for s in range(len(totals))])
        return tris, True


# ---------------------------------------------------------------------------
# module-level conveniences
# ---------------------------------------------------------------------------

def engine_count(src, dst, **kw) -> int:
    return TriangleEngine(src, dst, **kw).count()


def engine_list(src, dst, **kw) -> np.ndarray:
    return TriangleEngine(src, dst, **kw).list()
