"""Generic full-conjunctive query utilities (paper §2.1, Def. 12).

Queries are given in a Datalog-ish form: a head variable tuple plus body
atoms over named relations. Utilities here:

  * variable-order validation and automatic index creation — an atom whose
    variables are not a subsequence of the chosen order gets a reordered
    TrieArray index T_{π} built for it (paper: "indexes are created in a
    preprocessing step", O(SORT) each);
  * rank r_π(Q) and r(Q) (Def. 12): the largest position (1-based) of a
    variable that is the *first* variable of some atom; governs the
    no-spill I/O bound O(|I|^r / (M^{r-1} B) + K/B) (Thm. 13);
  * repeated-variable rewrites are rejected with guidance (infinite Eq
    predicates are out of scope for the TrieArray backend).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .leapfrog import Atom
from .triearray import TrieArray


@dataclass
class Query:
    head: Tuple[str, ...]
    atoms: List[Atom]

    def variables(self) -> List[str]:
        seen: List[str] = []
        for a in self.atoms:
            for v in a.vars:
                if v not in seen:
                    seen.append(v)
        return seen


def is_consistent(atom: Atom, order: Sequence[str]) -> bool:
    pos = [order.index(v) for v in atom.vars]
    return pos == sorted(pos)


def rank_for_order(q: Query, order: Sequence[str]) -> int:
    """r_π(Q), 1-based (Def. 12). Triangle query with (x,y,z): 2."""
    r = 0
    for a in q.atoms:
        r = max(r, list(order).index(a.vars[0]) + 1)
    return r


def best_order(q: Query, allow_reorder: bool = True) -> Tuple[int, Tuple[str, ...]]:
    """Minimum-rank variable order; exhaustive (queries are small: data
    complexity treats the query as fixed, paper §1).

    With ``allow_reorder`` any permutation is feasible — an inconsistent
    atom gets a reordered index T_π, so its effective first variable is its
    earliest variable in the order. With ``allow_reorder=False`` (e.g. the
    atom's relation is a disk-resident edge store that cannot be cheaply
    re-sorted) only orders keeping every atom consistent as-written are
    considered; raises if none exists. Ties break lexicographically on the
    order tuple, so the choice is deterministic."""
    vs = q.variables()
    best: Optional[Tuple[int, Tuple[str, ...]]] = None
    for perm in itertools.permutations(vs):
        if allow_reorder:
            r = 0
            for a in q.atoms:
                first = min(perm.index(v) for v in a.vars)
                r = max(r, first + 1)
        else:
            if not all(is_consistent(a, perm) for a in q.atoms):
                continue
            r = rank_for_order(q, perm)
        if best is None or (r, perm) < best:
            best = (r, perm)
    if best is None:
        raise ValueError(
            "no variable order keeps every atom consistent; pass in-memory "
            "relations (reordered indexes can then be built, Prop. 3) or "
            "choose an order and pre-create the reordered stores")
    return best


def best_rank(q: Query) -> Tuple[int, Tuple[str, ...]]:
    """r(Q) = min over key orders (Def. 12), reordered indexes allowed."""
    return best_order(q, allow_reorder=True)


def validate(q: Query, order: Optional[Sequence[str]] = None,
             require_consistent: bool = False) -> Tuple[str, ...]:
    """Check a query is executable and resolve its variable order.

    Raises ``ValueError`` when the query is malformed (a head variable
    missing from the body, an order that is not a permutation of the body
    variables, or — with ``require_consistent`` — an atom inconsistent
    with the order). Returns the resolved order: the given one, or the
    minimum-rank order from ``best_order`` when ``order`` is ``None``.
    """
    vs = q.variables()
    if not q.atoms:
        raise ValueError("query has no body atoms")
    missing = [h for h in q.head if h not in vs]
    if missing:
        raise ValueError(f"head variables {missing} appear in no body atom")
    if order is None:
        return best_order(q, allow_reorder=not require_consistent)[1]
    order = tuple(order)
    if sorted(order) != sorted(vs):
        raise ValueError(
            f"order {order} is not a permutation of the query variables {vs}")
    if require_consistent:
        for a in q.atoms:
            if not is_consistent(a, order):
                raise ValueError(
                    f"atom {a.rel}{a.vars} inconsistent with order {order}; "
                    "pre-create a reordered index for it")
    return order


def rank(q: Query, order: Optional[Sequence[str]] = None) -> int:
    """Rank of a query (Def. 12): ``r_π(Q)`` for the given order, else the
    optimal ``r(Q)`` over all orders (reordered indexes allowed). Governs
    the Thm. 13 no-spill I/O bound O(|I|^r / (M^{r-1} B) + K/B)."""
    if order is not None:
        return rank_for_order(q, order)
    return best_rank(q)[0]


def reordered_index(rel: TrieArray, perm: Tuple[int, ...]) -> TrieArray:
    """T_π for a column permutation of ``rel`` (Prop. 3: one re-sort).

    Built indexes are memoized *on the source TrieArray* keyed by the
    permutation, so multi-atom queries sharing a relation (and repeated
    queries against the same relation) rebuild each T_π once, not per
    atom per call. The cache lives on the relation object itself — it is
    garbage-collected with the relation, and two relations never share
    entries even if one is freed and the other reuses its address."""
    cache = getattr(rel, "_reorder_cache", None)
    if cache is None:
        cache = {}
        rel._reorder_cache = cache
    ta = cache.get(perm)
    if ta is None:
        ta = TrieArray.from_tuples(rel.to_tuples()[:, list(perm)])
        cache[perm] = ta
    return ta


def build_indexes(q: Query, order: Sequence[str],
                  relations: Dict[str, TrieArray]):
    """Return (atoms', relations') where every atom is order-consistent.

    For an inconsistent atom R(y, x) a new index R__pi(x, y) is built by
    column permutation + re-sort (Prop. 3 cost) via ``reordered_index``,
    which memoizes per (relation, permutation): atoms sharing a relation
    and permutation share one T_π, across calls too."""
    out_atoms: List[Atom] = []
    out_rels: Dict[str, TrieArray] = dict(relations)
    for a in q.atoms:
        if is_consistent(a, order):
            out_atoms.append(a)
            continue
        perm = tuple(sorted(range(len(a.vars)),
                            key=lambda i: order.index(a.vars[i])))
        new_vars = tuple(a.vars[i] for i in perm)
        new_name = f"{a.rel}__{''.join(map(str, perm))}"
        if new_name not in out_rels:
            out_rels[new_name] = reordered_index(relations[a.rel], perm)
        out_atoms.append(Atom(new_name, new_vars))
    return out_atoms, out_rels


def run_query(q: Query, order: Sequence[str],
              relations: Dict[str, TrieArray],
              mem_words: Optional[int] = None,
              emit=None, device=None) -> int:
    """Execute a query: in-memory LFTJ, or boxed when mem_words is given.

    With a ``core.iomodel.BlockDevice`` the relations (including any
    reordered indexes) are registered on it and every element access runs
    through a ``CountingReader`` — the scalar-reference I/O measurement the
    Thm. 13 comparison uses (``repro.query`` is the production path)."""
    from .boxing import BoxedLFTJ, BoxingConfig
    from .iomodel import CountingReader
    from .leapfrog import LeapfrogTriejoin

    atoms, rels = build_indexes(q, order, relations)
    if mem_words is None:
        reader = None
        if device is not None:
            for ta in rels.values():
                device.register_triearray(ta)
            reader = CountingReader(device)
        j = LeapfrogTriejoin(atoms, list(order), rels, reader=reader)
        return j.run(emit=emit)
    cfg = BoxingConfig(mem_words=mem_words)
    bj = BoxedLFTJ(atoms, list(order), rels, cfg, emit=emit, device=device)
    return bj.run()
