"""Generic full-conjunctive query utilities (paper §2.1, Def. 12).

Queries are given in a Datalog-ish form: a head variable tuple plus body
atoms over named relations. Utilities here:

  * variable-order validation and automatic index creation — an atom whose
    variables are not a subsequence of the chosen order gets a reordered
    TrieArray index T_{π} built for it (paper: "indexes are created in a
    preprocessing step", O(SORT) each);
  * rank r_π(Q) and r(Q) (Def. 12): the largest position (1-based) of a
    variable that is the *first* variable of some atom; governs the
    no-spill I/O bound O(|I|^r / (M^{r-1} B) + K/B) (Thm. 13);
  * repeated-variable rewrites are rejected with guidance (infinite Eq
    predicates are out of scope for the TrieArray backend).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .leapfrog import Atom
from .triearray import TrieArray


@dataclass
class Query:
    head: Tuple[str, ...]
    atoms: List[Atom]

    def variables(self) -> List[str]:
        seen: List[str] = []
        for a in self.atoms:
            for v in a.vars:
                if v not in seen:
                    seen.append(v)
        return seen


def is_consistent(atom: Atom, order: Sequence[str]) -> bool:
    pos = [order.index(v) for v in atom.vars]
    return pos == sorted(pos)


def rank_for_order(q: Query, order: Sequence[str]) -> int:
    """r_π(Q), 1-based (Def. 12). Triangle query with (x,y,z): 2."""
    r = 0
    for a in q.atoms:
        r = max(r, list(order).index(a.vars[0]) + 1)
    return r


def best_rank(q: Query) -> Tuple[int, Tuple[str, ...]]:
    """r(Q) = min over key orders; exhaustive (queries are small: data
    complexity treats the query as fixed, paper §1)."""
    vs = q.variables()
    best = (len(vs) + 1, tuple(vs))
    for perm in itertools.permutations(vs):
        if all(is_consistent(a, perm) or True for a in q.atoms):
            # any atom may be served by a reordered index, so every
            # permutation is feasible; rank only depends on first variables
            # after reordering each atom's vars to match perm.
            r = 0
            for a in q.atoms:
                first = min(perm.index(v) for v in a.vars)
                r = max(r, first + 1)
            if r < best[0]:
                best = (r, perm)
    return best


def build_indexes(q: Query, order: Sequence[str],
                  relations: Dict[str, TrieArray]):
    """Return (atoms', relations') where every atom is order-consistent.

    For an inconsistent atom R(y, x) a new index R__pi(x, y) is built by
    column permutation + re-sort (Prop. 3 cost)."""
    out_atoms: List[Atom] = []
    out_rels: Dict[str, TrieArray] = dict(relations)
    for a in q.atoms:
        if is_consistent(a, order):
            out_atoms.append(a)
            continue
        perm = sorted(range(len(a.vars)), key=lambda i: order.index(a.vars[i]))
        new_vars = tuple(a.vars[i] for i in perm)
        new_name = f"{a.rel}__{''.join(map(str, perm))}"
        if new_name not in out_rels:
            tuples = relations[a.rel].to_tuples()
            out_rels[new_name] = TrieArray.from_tuples(tuples[:, perm])
        out_atoms.append(Atom(new_name, new_vars))
    return out_atoms, out_rels


def run_query(q: Query, order: Sequence[str],
              relations: Dict[str, TrieArray],
              mem_words: Optional[int] = None,
              emit=None) -> int:
    """Execute a query: in-memory LFTJ, or boxed when mem_words is given."""
    from .boxing import BoxedLFTJ, BoxingConfig
    from .leapfrog import LeapfrogTriejoin

    atoms, rels = build_indexes(q, order, relations)
    if mem_words is None:
        j = LeapfrogTriejoin(atoms, list(order), rels)
        return j.run(emit=emit)
    cfg = BoxingConfig(mem_words=mem_words)
    bj = BoxedLFTJ(atoms, list(order), rels, cfg, emit=emit)
    return bj.run()
