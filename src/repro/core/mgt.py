"""MGT baseline: Massive Graph Triangulation [Hu, Tao, Chung SIGMOD'13].

The specialized out-of-core competitor the paper benchmarks against
(paper §6, Fig. 11). We implement the core in-memory-chunk + edge-stream
pattern that gives MGT its O(|E|²/(MB) + K/B) I/O bound:

  repeat until all pivot nodes processed:
    load into memory the adjacency lists of the next node range R such that
    they fit in M;
    stream every edge (b, c) of E from disk once; for each, report
    |{a ∈ R : b ∈ N(a) ∧ c ∈ N(a)}| triangles (a is the pivot; with the DAG
    orientation a < b < c each triangle is counted exactly once).

The inner membership test uses an inverted index L(v) = {a ∈ R : v ∈ N(a)},
so each streamed edge costs one sorted-list intersection |L(b) ∩ L(c)| —
the same vectorized primitive as lftj_jax (fair CPU comparison).

Simplifications vs [10] (documented per DESIGN.md §7): we omit MGT's
degree-splitting preprocessing (it removes the max-degree ≤ M restriction,
same restriction the paper notes for boxing's no-spill bound) and its
result-dependent optimizations.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .iomodel import BlockDevice
from .lftj_jax import csr_from_edges, orient_edges, pad_neighbors


def mgt_triangle_count(src: np.ndarray, dst: np.ndarray,
                       mem_words: int,
                       device: Optional[BlockDevice] = None,
                       orientation: str = "minmax") -> Tuple[int, dict]:
    """Count triangles; returns (count, info with io/chunk stats)."""
    a, b = orient_edges(src, dst, orientation)
    indptr, indices = csr_from_edges(a, b)
    nv = len(indptr) - 1
    ne = len(indices)
    if device is not None:
        device.register(indices)

    # partition pivots into ranges whose adjacency fits the memory budget
    deg = np.diff(indptr)
    chunks = []
    start = 0
    acc = 0
    for v in range(nv):
        d = int(deg[v])
        if acc + d > mem_words and acc > 0:
            chunks.append((start, v))
            start, acc = v, 0
        acc += d
    chunks.append((start, nv))

    total = 0
    stream_ios = 0
    for (r0, r1) in chunks:
        # "load" adjacency of pivots in [r0, r1): counted as sequential read
        lo, hi = int(indptr[r0]), int(indptr[r1])
        if device is not None and hi > lo:
            device.read_range(indices, lo, hi)
        # inverted index L: for each vertex v, sorted pivots a∈R with v∈N(a)
        piv = np.repeat(np.arange(r0, r1), deg[r0:r1]).astype(np.int64)
        nbr = indices[lo:hi].astype(np.int64)
        order = np.lexsort((piv, nbr))
        nbr_s, piv_s = nbr[order], piv[order]
        l_ptr = np.searchsorted(nbr_s, np.arange(nv + 1))
        l_indptr = l_ptr.astype(np.int64)
        l_indices = piv_s.astype(np.int32)
        if hi == lo:
            l_pad = np.full((nv, 1), np.iinfo(np.int32).max, np.int32)
        else:
            l_pad = pad_neighbors(l_indptr, l_indices)
        # stream all edges (b, c); per edge count |L(b) ∩ L(c)|
        eu, ev = a.astype(np.int64), b.astype(np.int64)
        if device is not None:
            # one full sequential scan of the edge file per chunk
            device.clear_cache()   # streaming evicts; model as cold scan
            device.read_range(indices, 0, ne)
            stream_ios += 1
        lb = l_pad[eu]
        lc = l_pad[ev]
        # vectorized sorted intersection via searchsorted
        k = lb.shape[1]
        pos = np.clip(_batch_searchsorted(lc, lb), 0, k - 1)
        hit = (np.take_along_axis(lc, pos, axis=1) == lb) & \
              (lb != np.iinfo(np.int32).max)
        total += int(hit.sum())
    info = {"n_chunks": len(chunks), "stream_scans": stream_ios,
            "io_reads": device.stats.block_reads if device else None}
    return total, info


def _batch_searchsorted(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Row-wise searchsorted for 2-D arrays (numpy lacks a batched form)."""
    n, k = haystack.shape
    offs = (np.arange(n, dtype=np.int64) * (np.int64(np.iinfo(np.int32).max) + 1))[:, None]
    flat_h = (haystack.astype(np.int64) + offs).ravel()
    flat_n = (needles.astype(np.int64) + offs).ravel()
    pos = np.searchsorted(flat_h, flat_n)
    return pos.reshape(n, -1) - np.arange(n, dtype=np.int64)[:, None] * k
