"""Vectorized LFTJ-Δ in JAX (the TPU-native altitude; DESIGN.md §2.2).

The level-z leapfrog joins of LFTJ-Δ compute |D(x) ∩ D(y)| for every edge
(x, y) of the DAG orientation (paper Alg. 1). We batch *all* of them into a
data-parallel primitive over fixed shapes:

  * neighbor lists padded to K = max out-degree, sorted, sentinel-terminated;
  * per edge, the smaller list is probed into the larger via binary search —
    exactly the min(d_x, d_y) accounting of Thm. 17, so the vectorized form
    inherits the O(|E| · α(G) · log) work bound (the padding waste is bounded
    by degree binning / boxing);
  * a `lax.scan` over edge chunks keeps peak memory at O(chunk · K).

`triangle_count_dense` is the MXU formulation used for dense boxes:
Σ A ⊙ (A Aᵀ) over 0/1 tiles (kernels/triangle_dense implements it in Pallas).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# host-side graph preparation (numpy)
# ---------------------------------------------------------------------------

def orient_edges(src: np.ndarray, dst: np.ndarray,
                 mode: str = "minmax") -> Tuple[np.ndarray, np.ndarray]:
    """Make the undirected graph a DAG (paper §2.3 G*).

    'minmax'  — (min, max) per edge: the paper's orientation.
    'degree'  — lower-degree endpoint first (ties by id): the standard
                out-degree ≤ O(√|E|) bound; a beyond-paper option that caps
                the padded width K (§Perf hillclimb #1).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if mode == "minmax":
        a = np.minimum(src, dst)
        b = np.maximum(src, dst)
    elif mode == "degree":
        n = int(max(src.max(initial=0), dst.max(initial=0))) + 1
        deg = np.bincount(src, minlength=n) + np.bincount(dst, minlength=n)
        key_s = deg[src] * (n + 1) + src
        key_d = deg[dst] * (n + 1) + dst
        swap = key_s > key_d
        a = np.where(swap, dst, src)
        b = np.where(swap, src, dst)
    else:
        raise ValueError(mode)
    e = np.unique(np.stack([a, b], axis=1), axis=0)
    return e[:, 0], e[:, 1]


def csr_from_edges(src: np.ndarray, dst: np.ndarray,
                   n_nodes: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
    """CSR (indptr, indices) with sorted rows — the TrieArray of E."""
    if n_nodes is None:
        n_nodes = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, dst.astype(np.int32)


def pad_neighbors(indptr: np.ndarray, indices: np.ndarray,
                  k: Optional[int] = None) -> np.ndarray:
    """(V, K) padded, sorted neighbor matrix with SENTINEL fill."""
    n = len(indptr) - 1
    deg = np.diff(indptr)
    if k is None:
        k = int(deg.max(initial=1))
    k = max(int(k), 1)
    out = np.full((n, k), SENTINEL, dtype=np.int32)
    for_rows = np.repeat(np.arange(n), deg)
    cols = np.arange(len(indices)) - np.repeat(indptr[:-1], deg)
    ok = cols < k
    out[for_rows[ok], cols[ok]] = indices[ok]
    return out


# ---------------------------------------------------------------------------
# jitted intersection primitives
# ---------------------------------------------------------------------------

def _row_intersect_count(a_row: jnp.ndarray, b_row: jnp.ndarray) -> jnp.ndarray:
    """|a ∩ b| for two sorted sentinel-padded rows (binary-search probing)."""
    pos = jnp.searchsorted(b_row, a_row)
    pos = jnp.clip(pos, 0, b_row.shape[0] - 1)
    hit = (b_row[pos] == a_row) & (a_row != SENTINEL)
    return jnp.sum(hit.astype(jnp.int32))


@partial(jax.jit, static_argnames=("chunk",))
def _count_chunked(npad: jnp.ndarray, eu: jnp.ndarray, ev: jnp.ndarray,
                   chunk: int = 2048) -> jnp.ndarray:
    """Σ_edges |N(u) ∩ N(v)| with a scan over fixed-size edge chunks."""
    m = eu.shape[0]
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    # pad with self-edges on node 0 against an empty sentinel row: count 0
    eu_p = jnp.concatenate([eu, jnp.full((pad,), 0, eu.dtype)])
    ev_p = jnp.concatenate([ev, jnp.full((pad,), 0, ev.dtype)])
    valid = jnp.concatenate([jnp.ones((m,), jnp.int32), jnp.zeros((pad,), jnp.int32)])
    eu_c = eu_p.reshape(n_chunks, chunk)
    ev_c = ev_p.reshape(n_chunks, chunk)
    va_c = valid.reshape(n_chunks, chunk)

    def body(carry, inp):
        u, v, ok = inp
        a = npad[u]            # (chunk, K)
        b = npad[v]
        cnt = jax.vmap(_row_intersect_count)(a, b)
        return carry + jnp.sum(cnt * ok), None

    total, _ = jax.lax.scan(body, jnp.int64(0) if jax.config.jax_enable_x64
                            else jnp.int32(0), (eu_c, ev_c, va_c))
    return total


def triangle_count_vectorized(src: np.ndarray, dst: np.ndarray,
                              orientation: str = "minmax",
                              chunk: int = 2048) -> int:
    """End-to-end vectorized LFTJ-Δ triangle count of an undirected graph."""
    a, b = orient_edges(src, dst, orientation)
    indptr, indices = csr_from_edges(a, b)
    npad = pad_neighbors(indptr, indices)
    return int(_count_chunked(jnp.asarray(npad), jnp.asarray(a, jnp.int32),
                              jnp.asarray(b, jnp.int32), chunk=chunk))


# ---------------------------------------------------------------------------
# dense (MXU) formulation
# ---------------------------------------------------------------------------

@jax.jit
def triangle_count_dense(adj: jnp.ndarray) -> jnp.ndarray:
    """Σ A ⊙ (A Aᵀ) for a dense 0/1 DAG adjacency block.

    On TPU this is a masked SYRK on the MXU: |E_box|·d work at 197 TFLOP/s,
    profitable whenever box density is above the MXU/VPU crossover
    (see kernels/triangle_dense for the Pallas tiling and §Perf for the
    crossover measurement).
    """
    a = adj.astype(jnp.float32)
    paths = a @ a.T
    return jnp.sum(a * paths).astype(jnp.int64) if jax.config.jax_enable_x64 \
        else jnp.sum(a * paths).astype(jnp.int32)


def dense_adjacency(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=np.float32)
    adj[src, dst] = 1.0
    return adj


# ---------------------------------------------------------------------------
# per-box vectorized execution (ties boxing to the TPU path)
# ---------------------------------------------------------------------------

def triangle_count_boxed_vectorized(src: np.ndarray, dst: np.ndarray,
                                    mem_words: int,
                                    orientation: str = "minmax",
                                    dense_threshold: float = 0.05,
                                    chunk: int = 2048) -> Tuple[int, dict]:
    """Boxed execution with the vectorized/dense per-box engines.

    The box plan comes from the paper's probe/provision machinery
    (core.boxing.plan_boxes); each box is solved with the vectorized
    intersection primitive, or the dense MXU formulation when the
    box's edge density crosses ``dense_threshold``. Returns (count, info).
    """
    from .boxing import plan_boxes
    from .triearray import TrieArray

    a, b = orient_edges(src, dst, orientation)
    ta = TrieArray.from_edges(a, b)
    boxes = plan_boxes(ta, mem_words)
    indptr, indices = csr_from_edges(a, b)
    nv = len(indptr) - 1
    npad = jnp.asarray(pad_neighbors(indptr, indices))
    total = 0
    n_dense = 0
    for (lx, hx, ly, hy) in boxes:
        lx_, hx_ = max(lx, 0), min(hx, nv - 1)
        ly_, hy_ = max(ly, 0), min(hy, nv - 1)
        if hx_ < lx_ or hy_ < ly_:
            continue
        # in-box edges (x,y): src in [lx,hx] (the E(x,·) slice), y in [ly,hy]
        s0, s1 = indptr[lx_], indptr[hx_ + 1]
        eu = np.repeat(np.arange(lx_, hx_ + 1),
                       np.diff(indptr[lx_:hx_ + 2]))
        ev = indices[s0:s1].astype(np.int64)
        sel = (ev >= ly_) & (ev <= hy_)
        eu, ev = eu[sel], ev[sel]
        if len(eu) == 0:
            continue
        wx, wy = hx_ - lx_ + 1, hy_ - ly_ + 1
        density = len(eu) / max(1, wx * wy)
        # dense path: z spans the full node range (dim z is unbounded in the
        # box), so rows carry ALL columns: count = Σ mask ⊙ (Ax Ayᵀ).
        if density > dense_threshold and (wx + wy) * nv <= 64_000_000:
            ax = np.zeros((wx, nv), dtype=np.float32)
            ay = np.zeros((wy, nv), dtype=np.float32)
            ru = np.repeat(np.arange(lx_, hx_ + 1), np.diff(indptr[lx_:hx_ + 2]))
            ax[ru - lx_, indices[s0:s1]] = 1.0
            t0, t1 = indptr[ly_], indptr[hy_ + 1]
            rv = np.repeat(np.arange(ly_, hy_ + 1), np.diff(indptr[ly_:hy_ + 2]))
            ay[rv - ly_, indices[t0:t1]] = 1.0
            mask = np.zeros((wx, wy), dtype=np.float32)
            mask[eu - lx_, ev - ly_] = 1.0
            total += int((mask * (ax @ ay.T)).sum())
            n_dense += 1
        else:
            total += int(_count_chunked(npad,
                                        jnp.asarray(eu, jnp.int32),
                                        jnp.asarray(ev, jnp.int32),
                                        chunk=chunk))
    return total, {"n_boxes": len(boxes), "n_dense_boxes": n_dense}
