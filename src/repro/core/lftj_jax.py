"""Vectorized LFTJ-Δ in JAX (the TPU-native altitude; DESIGN.md §2.2).

The level-z leapfrog joins of LFTJ-Δ compute |D(x) ∩ D(y)| for every edge
(x, y) of the DAG orientation (paper Alg. 1). We batch *all* of them into a
data-parallel primitive over fixed shapes:

  * neighbor lists padded to K = max out-degree, sorted, sentinel-terminated;
  * per edge, the smaller list is probed into the larger via binary search —
    exactly the min(d_x, d_y) accounting of Thm. 17, so the vectorized form
    inherits the O(|E| · α(G) · log) work bound (the padding waste is bounded
    by degree binning / boxing);
  * a `lax.scan` over edge chunks keeps peak memory at O(chunk · K).

`triangle_count_dense` is the MXU formulation used for dense boxes:
Σ A ⊙ (A Aᵀ) over 0/1 tiles (kernels/triangle_dense implements it in Pallas).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# host-side graph preparation (numpy)
# ---------------------------------------------------------------------------

def orient_edges(src: np.ndarray, dst: np.ndarray,
                 mode: str = "minmax") -> Tuple[np.ndarray, np.ndarray]:
    """Make the undirected graph a DAG (paper §2.3 G*).

    'minmax'  — (min, max) per edge: the paper's orientation.
    'degree'  — lower-degree endpoint first (ties by id): the standard
                out-degree ≤ O(√|E|) bound; a beyond-paper option that caps
                the padded width K (§Perf hillclimb #1).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if mode == "minmax":
        a = np.minimum(src, dst)
        b = np.maximum(src, dst)
    elif mode == "degree":
        n = int(max(src.max(initial=0), dst.max(initial=0))) + 1
        deg = np.bincount(src, minlength=n) + np.bincount(dst, minlength=n)
        key_s = deg[src] * (n + 1) + src
        key_d = deg[dst] * (n + 1) + dst
        swap = key_s > key_d
        a = np.where(swap, dst, src)
        b = np.where(swap, src, dst)
    else:
        raise ValueError(mode)
    e = np.unique(np.stack([a, b], axis=1), axis=0)
    return e[:, 0], e[:, 1]


def csr_from_edges(src: np.ndarray, dst: np.ndarray,
                   n_nodes: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
    """CSR (indptr, indices) with sorted rows — the TrieArray of E."""
    if n_nodes is None:
        n_nodes = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, dst.astype(np.int32)


def pad_neighbors(indptr: np.ndarray, indices: np.ndarray,
                  k: Optional[int] = None) -> np.ndarray:
    """(V, K) padded, sorted neighbor matrix with SENTINEL fill.

    ``k`` < max degree would silently drop neighbors (and miscount every
    downstream intersection), so it is a hard error; rows that must be
    capped belong in ``pad_neighbors_binned``.
    """
    n = len(indptr) - 1
    deg = np.diff(indptr)
    if k is None:
        k = int(deg.max(initial=1))
    k = max(int(k), 1)
    if deg.max(initial=0) > k:
        raise ValueError(
            f"pad_neighbors: k={k} < max degree {int(deg.max())}; this would "
            "silently truncate neighbor lists. Pass k=None or use "
            "pad_neighbors_binned for degree-capped rows.")
    out = np.full((n, k), SENTINEL, dtype=np.int32)
    for_rows = np.repeat(np.arange(n), deg)
    cols = np.arange(len(indices)) - np.repeat(indptr[:-1], deg)
    out[for_rows, cols] = indices
    return out


def pad_neighbors_binned(indptr: np.ndarray, indices: np.ndarray,
                         bin_growth: int = 4):
    """Degree-binned padding: rows grouped into power-of-``bin_growth`` width
    classes so the per-bin K caps the O(V·K_max) padding waste on skewed
    graphs (a hub no longer forces every row to its width).

    Returns ``(row_bin, bins)`` where ``row_bin[v]`` is the bin id of vertex
    v and ``bins[i] = (rows, npad)`` holds the vertex ids in bin i plus
    their (len(rows), K_i) padded neighbor matrix. Vertices with degree 0
    get bin -1 (they cannot participate in any intersection).
    """
    n = len(indptr) - 1
    deg = np.diff(indptr)
    row_bin = np.full(n, -1, dtype=np.int64)
    bins = []
    nonzero = deg > 0
    if nonzero.any():
        widths = []
        k = 1
        kmax = int(deg.max())
        while True:
            widths.append(k)
            if k >= kmax:
                break
            k *= bin_growth
        edges_lo = [w // bin_growth + 1 if w > 1 else 1 for w in widths]
        for b, (klo, khi) in enumerate(zip(edges_lo, widths)):
            rows = np.flatnonzero((deg >= klo) & (deg <= khi))
            if len(rows) == 0:
                bins.append((rows, np.zeros((0, khi), dtype=np.int32)))
                continue
            row_bin[rows] = b
            npad = np.full((len(rows), khi), SENTINEL, dtype=np.int32)
            d = deg[rows]
            rr = np.repeat(np.arange(len(rows)), d)
            cc = np.arange(int(d.sum())) - np.repeat(np.cumsum(d) - d, d)
            src_idx = np.repeat(indptr[rows], d) + cc
            npad[rr, cc] = indices[src_idx]
            bins.append((rows, npad))
    return row_bin, bins


# ---------------------------------------------------------------------------
# jitted intersection primitives
# ---------------------------------------------------------------------------

def _row_intersect_count(a_row: jnp.ndarray, b_row: jnp.ndarray) -> jnp.ndarray:
    """|a ∩ b| for two sorted sentinel-padded rows (binary-search probing)."""
    pos = jnp.searchsorted(b_row, a_row)
    pos = jnp.clip(pos, 0, b_row.shape[0] - 1)
    hit = (b_row[pos] == a_row) & (a_row != SENTINEL)
    return jnp.sum(hit.astype(jnp.int32))


@partial(jax.jit, static_argnames=("chunk",))
def _count_chunked(npad: jnp.ndarray, eu: jnp.ndarray, ev: jnp.ndarray,
                   chunk: int = 2048) -> jnp.ndarray:
    """Σ_edges |N(u) ∩ N(v)| with a scan over fixed-size edge chunks."""
    m = eu.shape[0]
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    # pad with self-edges on node 0 against an empty sentinel row: count 0
    eu_p = jnp.concatenate([eu, jnp.full((pad,), 0, eu.dtype)])
    ev_p = jnp.concatenate([ev, jnp.full((pad,), 0, ev.dtype)])
    valid = jnp.concatenate([jnp.ones((m,), jnp.int32), jnp.zeros((pad,), jnp.int32)])
    eu_c = eu_p.reshape(n_chunks, chunk)
    ev_c = ev_p.reshape(n_chunks, chunk)
    va_c = valid.reshape(n_chunks, chunk)

    def body(carry, inp):
        u, v, ok = inp
        a = npad[u]            # (chunk, K)
        b = npad[v]
        cnt = jax.vmap(_row_intersect_count)(a, b)
        return carry + jnp.sum(cnt * ok), None

    total, _ = jax.lax.scan(body, jnp.int64(0) if jax.config.jax_enable_x64
                            else jnp.int32(0), (eu_c, ev_c, va_c))
    return total


@partial(jax.jit, static_argnames=("chunk",))
def _count_rows_chunked(a_rows: jnp.ndarray, b_rows: jnp.ndarray,
                        chunk: int = 2048) -> jnp.ndarray:
    """Σ_i |a_rows[i] ∩ b_rows[i]| for pre-gathered row pairs.

    Unlike ``_count_chunked`` the two sides may have different widths
    (degree-binned padding): the narrower row is probed into the wider via
    searchsorted — the min(d_x, d_y) accounting of Thm. 17. Padding rows
    are all-SENTINEL and contribute zero.
    """
    if a_rows.shape[1] > b_rows.shape[1]:  # intersection is symmetric
        a_rows, b_rows = b_rows, a_rows
    e = a_rows.shape[0]
    n_chunks = (e + chunk - 1) // chunk
    pad = n_chunks * chunk - e
    a_p = jnp.concatenate(
        [a_rows, jnp.full((pad, a_rows.shape[1]), SENTINEL, a_rows.dtype)])
    b_p = jnp.concatenate(
        [b_rows, jnp.full((pad, b_rows.shape[1]), SENTINEL, b_rows.dtype)])
    a_c = a_p.reshape(n_chunks, chunk, a_rows.shape[1])
    b_c = b_p.reshape(n_chunks, chunk, b_rows.shape[1])

    def body(carry, inp):
        a, b = inp
        cnt = jax.vmap(_row_intersect_count)(a, b)
        return carry + jnp.sum(cnt), None

    total, _ = jax.lax.scan(body, jnp.int32(0), (a_c, b_c))
    return total


# ---------------------------------------------------------------------------
# listing (enumeration) — bounded output buffer, overflow detected by caller
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cap", "chunk"))
def _list_chunked(npad: jnp.ndarray, eu: jnp.ndarray, ev: jnp.ndarray,
                  cap: int, chunk: int = 1024, valid=None):
    """Enumerate triangles (u, v, z) with z ∈ N(u) ∩ N(v) for each edge.

    Returns ``(total, buf)`` where ``buf`` is a (cap, 3) int32 buffer holding
    the first ``min(total, cap)`` triangles. ``total`` is always the exact
    count: when ``total > cap`` the buffer overflowed and the caller rescans
    with a larger cap (engine's overflow→rescan protocol). ``valid`` masks
    out pre-padded edge slots (sharded layout); ``None`` = all real.
    """
    m = eu.shape[0]
    k = npad.shape[1]
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    eu_p = jnp.concatenate([eu, jnp.full((pad,), 0, eu.dtype)])
    ev_p = jnp.concatenate([ev, jnp.full((pad,), 0, ev.dtype)])
    ok0 = jnp.ones((m,), bool) if valid is None else valid.astype(bool)
    valid = jnp.concatenate([ok0, jnp.zeros((pad,), bool)])
    eu_c = eu_p.reshape(n_chunks, chunk)
    ev_c = ev_p.reshape(n_chunks, chunk)
    va_c = valid.reshape(n_chunks, chunk)
    # one spill row past the end of the buffer swallows overflow writes
    buf0 = jnp.zeros((cap + 1, 3), jnp.int32)

    def body(carry, inp):
        total, buf = carry
        u, v, ok = inp
        a = npad[u]                               # (chunk, K) candidate z's
        b = npad[v]
        pos = jnp.clip(jax.vmap(jnp.searchsorted)(b, a), 0, k - 1)
        hit = (jnp.take_along_axis(b, pos, axis=1) == a) \
            & (a != SENTINEL) & ok[:, None]
        flat = hit.reshape(-1)
        zs = a.reshape(-1)
        us = jnp.repeat(u, k).astype(jnp.int32)
        vs = jnp.repeat(v, k).astype(jnp.int32)
        offs = total + jnp.cumsum(flat) - flat    # exclusive prefix position
        slot = jnp.where(flat, jnp.minimum(offs, cap), cap)
        tri = jnp.stack([us, vs, zs], axis=1)
        buf = buf.at[slot].set(tri, mode="drop")
        return (total + jnp.sum(flat), buf), None

    (total, buf), _ = jax.lax.scan(body, (jnp.int32(0), buf0),
                                   (eu_c, ev_c, va_c))
    return total, buf[:cap]


@partial(jax.jit, static_argnames=("cap", "chunk"))
def _list_pairs_chunked(npa: jnp.ndarray, npb: jnp.ndarray,
                        eu: jnp.ndarray, ev: jnp.ndarray,
                        us: jnp.ndarray, vs: jnp.ndarray,
                        cap: int, chunk: int = 1024):
    """Enumerate (us[i], vs[i], z) with z ∈ npa[eu[i]] ∩ npb[ev[i]].

    The degree-binned listing analogue of ``_count_rows_chunked`` +
    ``_list_chunked``: the two padded neighbor matrices may have different
    widths (per-bin K), the narrower side is probed into the wider, and the
    emitted triangle carries the caller-supplied *global* edge endpoints
    ``us``/``vs`` (so no local-row remap is needed afterwards). Padded edge
    slots must reference an all-SENTINEL row on the probed side — they then
    contribute nothing. Returns ``(total, buf)`` with the exact total and a
    (cap, 3) buffer of the first ``min(total, cap)`` triangles.
    """
    if npa.shape[1] > npb.shape[1]:     # z values are symmetric in a∩b
        npa, npb = npb, npa
        eu, ev = ev, eu
    m = eu.shape[0]
    ka = npa.shape[1]
    kb = npb.shape[1]
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    pad_a = jnp.int32(npa.shape[0] - 1)  # caller guarantees SENTINEL row
    eu_c = jnp.concatenate([eu, jnp.full((pad,), pad_a, eu.dtype)]) \
        .reshape(n_chunks, chunk)
    ev_c = jnp.concatenate([ev, jnp.full((pad,), 0, ev.dtype)]) \
        .reshape(n_chunks, chunk)
    us_c = jnp.concatenate([us, jnp.zeros((pad,), us.dtype)]) \
        .reshape(n_chunks, chunk)
    vs_c = jnp.concatenate([vs, jnp.zeros((pad,), vs.dtype)]) \
        .reshape(n_chunks, chunk)
    buf0 = jnp.zeros((cap + 1, 3), jnp.int32)   # spill row swallows overflow

    def body(carry, inp):
        total, buf = carry
        u, v, gu, gv = inp
        a = npa[u]                                # (chunk, ka) candidates
        b = npb[v]
        pos = jnp.clip(jax.vmap(jnp.searchsorted)(b, a), 0, kb - 1)
        hit = (jnp.take_along_axis(b, pos, axis=1) == a) & (a != SENTINEL)
        flat = hit.reshape(-1)
        zs = a.reshape(-1)
        gus = jnp.repeat(gu, ka).astype(jnp.int32)
        gvs = jnp.repeat(gv, ka).astype(jnp.int32)
        offs = total + jnp.cumsum(flat) - flat
        slot = jnp.where(flat, jnp.minimum(offs, cap), cap)
        buf = buf.at[slot].set(jnp.stack([gus, gvs, zs], axis=1),
                               mode="drop")
        return (total + jnp.sum(flat), buf), None

    (total, buf), _ = jax.lax.scan(body, (jnp.int32(0), buf0),
                                   (eu_c, ev_c, us_c, vs_c))
    return total, buf[:cap]


def triangle_count_vectorized(src: np.ndarray, dst: np.ndarray,
                              orientation: str = "minmax",
                              chunk: int = 2048) -> int:
    """End-to-end vectorized LFTJ-Δ triangle count of an undirected graph."""
    a, b = orient_edges(src, dst, orientation)
    indptr, indices = csr_from_edges(a, b)
    npad = pad_neighbors(indptr, indices)
    return int(_count_chunked(jnp.asarray(npad), jnp.asarray(a, jnp.int32),
                              jnp.asarray(b, jnp.int32), chunk=chunk))


# ---------------------------------------------------------------------------
# dense (MXU) formulation
# ---------------------------------------------------------------------------

@jax.jit
def triangle_count_dense(adj: jnp.ndarray) -> jnp.ndarray:
    """Σ A ⊙ (A Aᵀ) for a dense 0/1 DAG adjacency block.

    On TPU this is a masked SYRK on the MXU: |E_box|·d work at 197 TFLOP/s,
    profitable whenever box density is above the MXU/VPU crossover
    (see kernels/triangle_dense for the Pallas tiling and §Perf for the
    crossover measurement).
    """
    a = adj.astype(jnp.float32)
    paths = a @ a.T
    return jnp.sum(a * paths).astype(jnp.int64) if jax.config.jax_enable_x64 \
        else jnp.sum(a * paths).astype(jnp.int32)


def dense_adjacency(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=np.float32)
    adj[src, dst] = 1.0
    return adj


# ---------------------------------------------------------------------------
# per-box vectorized execution (ties boxing to the TPU path)
# ---------------------------------------------------------------------------

def triangle_count_boxed_vectorized(src: np.ndarray, dst: np.ndarray,
                                    mem_words: int,
                                    orientation: str = "minmax",
                                    dense_threshold: float = 0.05,
                                    chunk: int = 2048) -> Tuple[int, dict]:
    """Boxed execution with the vectorized/dense per-box engines.

    The box plan comes from the paper's probe/provision machinery
    (core.boxing.plan_boxes); per-box backend dispatch (vectorized
    binary-search vs dense MXU vs Pallas), degree binning, and device-mesh
    sharding all live in ``core.engine.TriangleEngine`` — this wrapper is
    the legacy single-host entry point. Returns (count, info).
    """
    from .engine import TriangleEngine

    eng = TriangleEngine(src, dst, mem_words=mem_words,
                         orientation=orientation,
                         dense_threshold=dense_threshold,
                         chunk=chunk, shard=False)
    count = eng.count()
    return count, eng.stats.as_info()
