"""TrieArray: flat-array trie encoding of sorted relations (paper §2.2).

A relation R(x_0, .., x_{n-1}) with arity n is stored as:
  * n value arrays   val[0..n-1]   -- val[i][j] is the value of the j-th trie
                                      node at depth i (depth 0 = children of
                                      the root, i.e. distinct x_0 values).
  * n-1 index arrays idx[0..n-2]   -- children of node j at depth i live at
                                      val[i+1][idx[i][j] : idx[i][j+1]]
                                      (CSR convention, exclusive end; the
                                      paper uses inclusive ends, an encoding
                                      detail only).

For a binary edge relation this is exactly CSR: val[0] = distinct sources,
idx[0] = offset array, val[1] = concatenated sorted neighbor lists.

TrieArraySlice (paper Def. 6 / Prop. 7): a range-restriction of R at level k
for a fixed k-prefix ``s``: { t in R | t[:k] == s and l <= t[k] <= h }.
Slices reference *copies* of contiguous sub-arrays (eager provisioning) and
carry per-level index offsets so idx values can be reused unmodified
("dynamic index-adaptation", Example 5).

All host-side structures are numpy; the JAX/TPU path consumes the same
arrays zero-copy via ``jnp.asarray``.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

# Sentinel returned by probe() when even a single-value slice exceeds the
# memory budget (paper Fig. 3).
SPILL = "SPILL"


def _lexsort_rows(tuples: np.ndarray) -> np.ndarray:
    """Sort rows of a 2-D int array lexicographically."""
    if tuples.size == 0:
        return tuples.reshape(0, tuples.shape[1] if tuples.ndim == 2 else 0)
    keys = tuple(tuples[:, c] for c in range(tuples.shape[1] - 1, -1, -1))
    order = np.lexsort(keys)
    return tuples[order]


def _dedup_sorted_rows(tuples: np.ndarray) -> np.ndarray:
    if len(tuples) == 0:
        return tuples
    keep = np.ones(len(tuples), dtype=bool)
    keep[1:] = np.any(tuples[1:] != tuples[:-1], axis=1)
    return tuples[keep]


@dataclass
class TrieArray:
    """An n-ary relation in TrieArray form.

    ``idx_offset[i]`` is subtracted from raw ``idx[i]`` entries on access;
    0 for a freshly built TrieArray, nonzero for slices (paper Example 5).
    """

    arity: int
    val: list  # list[np.ndarray], one per level
    idx: list  # list[np.ndarray], one per level < arity-1 (len == len(val[i]) + 1)
    idx_offset: list = field(default_factory=list)  # int per idx array

    def __post_init__(self):
        if not self.idx_offset:
            self.idx_offset = [0] * (self.arity - 1)

    # -- construction ------------------------------------------------------

    @staticmethod
    def from_tuples(tuples: np.ndarray, arity: Optional[int] = None) -> "TrieArray":
        """Build from an (m, arity) array of tuples. O(sort) time (Prop. 3)."""
        tuples = np.asarray(tuples)
        if tuples.ndim == 1:
            tuples = tuples.reshape(-1, 1)
        if arity is None:
            arity = tuples.shape[1]
        if tuples.shape[0] == 0:
            val = [np.zeros(0, dtype=np.int64) for _ in range(arity)]
            idx = [np.zeros(1, dtype=np.int64) for _ in range(arity - 1)]
            return TrieArray(arity, val, idx)
        tuples = _dedup_sorted_rows(_lexsort_rows(tuples.astype(np.int64)))

        val: list = []
        idx: list = []
        # Nodes at depth i are the distinct prefixes of length i+1. For each
        # depth compute the "new group" boundary mask w.r.t. prefix i+1.
        m = len(tuples)
        new_at = np.zeros((arity, m), dtype=bool)  # new_at[i] : row starts a new (i+1)-prefix
        prev_diff = np.zeros(m, dtype=bool)
        prev_diff[0] = True
        for i in range(arity):
            diff = prev_diff.copy()
            diff[1:] |= tuples[1:, i] != tuples[:-1, i]
            diff[0] = True
            new_at[i] = diff
            prev_diff = diff
        for i in range(arity):
            sel = new_at[i]
            val.append(tuples[sel, i].copy())
        for i in range(arity - 1):
            # idx[i][j]..idx[i][j+1] : children range of the j-th depth-i node
            # children are depth-(i+1) nodes; map each depth-(i+1) node to its
            # parent group and take group starts.
            parent_starts = np.flatnonzero(new_at[i])          # row index of each depth-i node
            child_rows = np.flatnonzero(new_at[i + 1])          # row index of each depth-(i+1) node
            # idx[i][j] = number of depth-(i+1) nodes strictly before parent j's first row
            starts = np.searchsorted(child_rows, parent_starts, side="left")
            idx.append(np.concatenate([starts, [len(child_rows)]]).astype(np.int64))
        return TrieArray(arity, val, idx)

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray) -> "TrieArray":
        return TrieArray.from_tuples(np.stack([src, dst], axis=1))

    @staticmethod
    def from_csr(indptr: np.ndarray, indices: np.ndarray,
                 sources: Optional[np.ndarray] = None) -> "TrieArray":
        """Zero-copy adoption of a CSR graph (all rows present, possibly empty)."""
        indptr = np.asarray(indptr, dtype=np.int64)
        indices = np.asarray(indices, dtype=np.int64)
        n = len(indptr) - 1
        if sources is None:
            sources = np.arange(n, dtype=np.int64)
        deg = np.diff(indptr)
        keep = deg > 0
        val0 = np.asarray(sources)[keep]
        # rebuild compacted indptr over non-empty rows
        idx0 = np.concatenate([[0], np.cumsum(deg[keep])]).astype(np.int64)
        if not np.array_equal(idx0[-1:], [len(indices)]):
            # rows were compacted but indices must match concatenation order;
            # CSR guarantees that as long as we drop only empty rows.
            pass
        return TrieArray(2, [val0, indices], [idx0])

    # -- basic accessors ----------------------------------------------------

    def n_tuples(self) -> int:
        return int(len(self.val[self.arity - 1]))

    def words(self) -> int:
        """Total storage in words (the paper's unit for |R| and M)."""
        return int(sum(len(v) for v in self.val) + sum(len(x) for x in self.idx))

    def idx_at(self, level: int, j: int) -> int:
        return int(self.idx[level][j]) - self.idx_offset[level]

    def child_range(self, level: int, j: int) -> tuple:
        """Children of node j at ``level`` live in val[level+1][lo:hi]."""
        lo = self.idx_at(level, j)
        hi = int(self.idx[level][j + 1]) - self.idx_offset[level]
        return lo, hi

    def to_tuples(self) -> np.ndarray:
        """Enumerate the represented relation (lexicographic)."""
        out = []

        def rec(level, lo, hi, prefix):
            for j in range(lo, hi):
                v = int(self.val[level][j])
                if level == self.arity - 1:
                    out.append(prefix + [v])
                else:
                    clo, chi = self.child_range(level, j)
                    rec(level + 1, clo, chi, prefix + [v])
        if self.arity > 0 and len(self.val[0]):
            rec(0, 0, len(self.val[0]), [])
        return np.asarray(out, dtype=np.int64).reshape(-1, self.arity)

    # -- slicing (paper Def. 6, Prop. 7) -------------------------------------

    def _bsearch(self, arr, lo: int, hi: int, v, side: str, reader=None) -> int:
        """Binary search with optional block-I/O accounting: when a reader
        is given, every probed element is touched on the simulated device —
        the honest Prop. 7/8 cost (upper search levels stay LRU-cached)."""
        if reader is None:
            return lo + int(np.searchsorted(arr[lo:hi], v, side=side))
        while lo < hi:
            mid = (lo + hi) // 2
            x = reader.get(arr, mid)
            if x < v or (side == "right" and x == v):
                lo = mid + 1
            else:
                hi = mid
        return lo

    def _get(self, arr, i: int, reader=None) -> int:
        return int(arr[i]) if reader is None else reader.get(arr, i)

    def _locate_prefix(self, s: Sequence[int], reader=None):
        """Find (level, lo, hi) of the sibling range for the level ``len(s)``
        after descending the prefix ``s``. Returns None if prefix absent.
        Costs O(len(s) * log) — the binary searches of Prop. 7."""
        lo, hi = 0, len(self.val[0])
        for k, v in enumerate(s):
            arr = self.val[k]
            p = self._bsearch(arr, lo, hi, v, "left", reader)
            if p >= hi or self._get(arr, p, reader) != v:
                return None
            lo = self._get(self.idx[k], p, reader) - self.idx_offset[k]
            hi = self._get(self.idx[k], p + 1, reader) - self.idx_offset[k]
        return lo, hi

    def slice_bounds(self, s: Sequence[int], l: int, h: int, reader=None):
        """Per-level [lo, hi) ranges of the slice R^s_{l->h}; None if empty."""
        k = len(s)
        rng = self._locate_prefix(s, reader)
        if rng is None:
            return None
        lo, hi = rng
        arr = self.val[k]
        a = self._bsearch(arr, lo, hi, l, "left", reader)
        b = self._bsearch(arr, lo, hi, h, "right", reader)
        if a >= b:
            return None
        bounds = [(a, b)]
        for lev in range(k, self.arity - 1):
            lo2 = self._get(self.idx[lev], bounds[-1][0], reader) \
                - self.idx_offset[lev]
            hi2 = self._get(self.idx[lev], bounds[-1][1], reader) \
                - self.idx_offset[lev]
            bounds.append((lo2, hi2))
        return bounds

    def slice_words(self, s: Sequence[int], l: int, h: int, reader=None) -> int:
        """Words of memory the slice would occupy (for probing). O(arity)."""
        bounds = self.slice_bounds(s, l, h, reader)
        if bounds is None:
            return 0
        total = 0
        for i, (a, b) in enumerate(bounds):
            total += b - a                      # values
            if len(s) + i < self.arity - 1:
                total += (b - a) + 1            # idx entries for this level
        return total

    def make_slice(self, s: Sequence[int], l: int, h: int) -> "TrieArraySlice":
        """Materialize the slice (eager provisioning: contiguous copies)."""
        k = len(s)
        bounds = self.slice_bounds(s, l, h)
        sub_arity = self.arity - k
        if bounds is None:
            val = [np.zeros(0, dtype=np.int64) for _ in range(sub_arity)]
            idx = [np.zeros(1, dtype=np.int64) for _ in range(sub_arity - 1)]
            return TrieArraySlice(sub_arity, val, idx, [0] * (sub_arity - 1),
                                  prefix=tuple(s), low=l, high=h, words_loaded=0)
        val, idx, offs = [], [], []
        for i, (a, b) in enumerate(bounds):
            lev = k + i
            val.append(self.val[lev][a:b])       # numpy view == DMA'd copy
            if lev < self.arity - 1:
                idx.append(self.idx[lev][a:b + 1])
                # Raw idx entries point into the *source's raw* coordinate
                # space; subtracting the raw first entry re-bases them onto
                # the copied sub-array regardless of how deeply the source
                # itself was sliced.
                offs.append(int(self.idx[lev][a]))
        words = sum(len(v) for v in val) + sum(len(x) for x in idx)
        return TrieArraySlice(sub_arity, val, idx, offs, prefix=tuple(s),
                              low=l, high=h, words_loaded=int(words))

    # -- probing (paper Prop. 8 / Fig. 3) ------------------------------------

    def probe(self, s: Sequence[int], l: int, budget_words: int, reader=None):
        """Maximal h >= l such that slice R^s_{l->h} fits ``budget_words``.

        Returns (h, words) or (SPILL, single_value_words). O(log |R|) probes,
        each O(arity) via the idx prefix pointers (Prop. 8). With a reader,
        every probed element is charged on the block device.
        """
        k = len(s)
        rng = self._locate_prefix(s, reader)
        if rng is None:
            return np.inf, 0  # nothing to load; slice empty -> h unbounded
        lo, hi = rng
        arr = self.val[k]
        a = self._bsearch(arr, lo, hi, l, "left", reader)
        if a >= hi:
            return np.inf, 0
        first_val = self._get(arr, a, reader)
        w1 = self.slice_words(s, first_val, first_val, reader)
        if w1 > budget_words:
            return SPILL, w1
        # binary search the largest position p in [a, hi) with fitting slice
        lo_p, hi_p = a, hi - 1
        best = a
        while lo_p <= hi_p:
            mid = (lo_p + hi_p) // 2
            w = self.slice_words(s, first_val, self._get(arr, mid, reader),
                                 reader)
            if w <= budget_words:
                best = mid
                lo_p = mid + 1
            else:
                hi_p = mid - 1
        h = self._get(arr, best, reader)
        if best == hi - 1:
            # everything from l on fits: the upper bound is unbounded
            return np.inf, self.slice_words(s, first_val, h)
        return h, self.slice_words(s, first_val, h)


@dataclass
class TrieArraySlice(TrieArray):
    """A provisioned slice; behaves as a TrieArray of reduced arity.

    ``prefix`` records the bound values for the removed leading attributes,
    ``low``/``high`` the range restriction on its (new) first attribute.
    """

    prefix: tuple = ()
    low: int = 0
    high: int = 0
    words_loaded: int = 0


def max_value(ta: TrieArray, level: int = 0) -> int:
    return int(ta.val[level][-1]) if len(ta.val[level]) else 0


def successor(v) -> int:
    """succ(h) in the boxing loop (integer domains)."""
    return int(v) + 1
