"""Adversarial instance G_N of paper Prop. 4 (§3, Fig. 12).

E = {(x, y) | x = 0..N, y = N - B·(x mod T)},  T = M/B + 1.

Second-column values are spaced B words apart so every level-z lookup of
vanilla LFTJ touches a distinct block, and they repeat in groups of T —
one more than fits in the cache — so LRU evicts each block just before its
reuse. Vanilla LFTJ-Δ therefore incurs ≥ 2|E| block I/Os (thrashing);
boxed LFTJ reads the input O(|E|/M) times sequentially instead.
"""

from __future__ import annotations

import numpy as np


def adversarial_graph(n_edges: int, mem_words: int, block_words: int):
    """Return (src, dst) of G_N. Requires N >= M + B (paper)."""
    n = int(n_edges)
    m, b = int(mem_words), int(block_words)
    if n < m + b:
        raise ValueError(f"need N >= M + B (N={n}, M={m}, B={b})")
    t = m // b + 1
    x = np.arange(n + 1, dtype=np.int64)
    y = n - b * (x % t)
    return x, y
