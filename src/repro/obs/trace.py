"""Structured tracing: nestable spans, ring buffer, Perfetto export.

One :class:`Tracer` instance is shared by every layer of a run (engine,
query executor, box scheduler, serving layer, fabric shards). Design
constraints, in order:

1. **Zero cost when off.** Instrumented code holds ``self.tracer``
   (``None`` by default) and guards every emission with one attribute
   check — no wrapper objects, no dummy context managers on the hot
   path. Attaching a tracer must not change execution order, issue
   source reads, or touch any ledger: counts, listings and measured
   ``block_reads`` are byte-identical traced-on vs traced-off (the CI
   trace-smoke gate).
2. **Thread-correct nesting.** The span stack is thread-local (the
   pattern of ``kernels/ledger``): the async box scheduler's workers
   each see their own parent chain, and every event records the emitting
   thread id, so the Chrome/Perfetto timeline renders one lane per
   worker.
3. **Bounded memory.** Events land in a ring buffer (``capacity``
   begin/end/instant records, default 2^16); a long-running server
   keeps the most recent window instead of growing without bound.
   ``dropped`` counts what the ring evicted.

Spans record begin ("B") and end ("E") events with monotonic
microsecond timestamps relative to the tracer's epoch; ``event()``
records an instant ("i"). ``export_chrome(path)`` writes the standard
``trace_event`` JSON (loadable in Perfetto / ``chrome://tracing``);
``snapshot()`` returns the raw event dicts for tests.

**Lanes.** A fabric run merges shard executions into one trace:
``with tracer.lane("shard3"): ...`` assigns every event emitted by the
current thread to a named lane, exported as its own Chrome *process*
row (with a ``process_name`` metadata record), so stragglers and
shipping skew are visible side by side on one timeline.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Tracer", "wrap_stage"]

_DEFAULT_CAPACITY = 1 << 16


class _Span:
    """Reusable span context manager (one allocation per span)."""

    __slots__ = ("_tracer", "_sid")

    def __init__(self, tracer: "Tracer", sid: int):
        self._tracer = tracer
        self._sid = sid

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._end_span(self._sid)
        return False


class _Lane:
    """Thread-local lane context (``with tracer.lane("shard0"):``)."""

    __slots__ = ("_tracer", "_prev")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self._prev = getattr(tracer._tls, "lane", None)
        tracer._tls.lane = name

    def __enter__(self) -> "_Lane":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._tls.lane = self._prev
        return False


class Tracer:
    """Thread-safe span/event recorder with a bounded ring buffer."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self.capacity = max(16, int(capacity))
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)
        self._t0 = time.perf_counter()
        self._len_before = 0       # events ever appended (for `dropped`)

    # -- emission -------------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _stack(self) -> List[int]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _emit(self, rec: dict) -> None:
        with self._lock:
            self._len_before += 1
            self._events.append(rec)

    def span(self, name: str, **attrs) -> _Span:
        """Open a nested span; use as ``with tracer.span("box.fetch"): ...``.

        The begin event is recorded here (monotonic µs, thread id,
        parent span id from this thread's stack); the matching end event
        on exit. ``attrs`` are attached to the begin event's ``args``.
        """
        stack = self._stack()
        sid = next(self._ids)
        rec = {"ph": "B", "name": name, "ts": self._now_us(),
               "tid": threading.get_ident(), "sid": sid,
               "parent": stack[-1] if stack else None,
               "lane": getattr(self._tls, "lane", None)}
        if attrs:
            rec["args"] = attrs
        stack.append(sid)
        self._emit(rec)
        return _Span(self, sid)

    def _end_span(self, sid: int) -> None:
        stack = self._stack()
        # tolerate exception-unwound nesting: pop through to this span
        while stack and stack[-1] != sid:
            stack.pop()
        if stack:
            stack.pop()
        self._emit({"ph": "E", "ts": self._now_us(),
                    "tid": threading.get_ident(), "sid": sid,
                    "lane": getattr(self._tls, "lane", None)})

    def event(self, name: str, **attrs) -> None:
        """Record an instant event (cache hit, kernel launch, ...)."""
        stack = self._stack()
        rec = {"ph": "i", "name": name, "ts": self._now_us(),
               "tid": threading.get_ident(), "sid": None,
               "parent": stack[-1] if stack else None,
               "lane": getattr(self._tls, "lane", None)}
        if attrs:
            rec["args"] = attrs
        self._emit(rec)

    def lane(self, name: str) -> _Lane:
        """Assign this thread's subsequent events to lane ``name`` (a
        Chrome *process* row in the export) until the context exits."""
        return _Lane(self, str(name))

    # -- introspection --------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events evicted by the ring buffer so far."""
        with self._lock:
            return max(0, self._len_before - len(self._events))

    def snapshot(self) -> List[dict]:
        """The buffered events as plain dicts (oldest first)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def span_names(self) -> List[str]:
        """Distinct begin-event span names in buffer order (tests)."""
        seen: Dict[str, None] = {}
        for e in self.snapshot():
            if e["ph"] == "B":
                seen.setdefault(e["name"], None)
        return list(seen)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._len_before = 0

    # -- export ---------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object for the
        buffered events: B/E duration events per span, instant events
        with thread scope, plus ``process_name`` metadata for lanes."""
        events = self.snapshot()
        # map span id -> name so orphaned E events (B evicted by the
        # ring) can be dropped instead of emitting unmatched pairs
        names: Dict[int, str] = {e["sid"]: e["name"] for e in events
                                 if e["ph"] == "B"}
        lanes: Dict[Optional[str], int] = {None: 1}
        out: List[dict] = []
        for e in events:
            lane = e.get("lane")
            pid = lanes.setdefault(lane, len(lanes) + 1)
            if e["ph"] == "B":
                rec = {"ph": "B", "name": e["name"], "cat": "repro",
                       "ts": e["ts"], "pid": pid, "tid": e["tid"]}
                if e.get("args"):
                    rec["args"] = {k: _jsonable(v)
                                   for k, v in e["args"].items()}
            elif e["ph"] == "E":
                if e["sid"] not in names:
                    continue            # begin fell off the ring
                rec = {"ph": "E", "name": names[e["sid"]], "cat": "repro",
                       "ts": e["ts"], "pid": pid, "tid": e["tid"]}
            else:
                rec = {"ph": "i", "name": e["name"], "cat": "repro",
                       "ts": e["ts"], "pid": pid, "tid": e["tid"],
                       "s": "t"}
                if e.get("args"):
                    rec["args"] = {k: _jsonable(v)
                                   for k, v in e["args"].items()}
            out.append(rec)
        meta = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                 "args": {"name": lane if lane is not None else "main"}}
                for lane, pid in lanes.items()]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        """Write the ``trace_event`` JSON to ``path``; returns ``path``
        (load it in Perfetto or ``chrome://tracing``)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def _jsonable(v):
    """Args values must survive json.dump; numpy scalars and the like
    degrade to their repr instead of failing the export."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, (int, float)):
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        return repr(v)


def wrap_stage(tracer: Optional[Tracer], name: str, fn):
    """Wrap a one-argument stage callable in a span — or return it
    untouched when ``tracer`` is None, so the traced-off path is the
    original callable with zero indirection (the box scheduler wraps
    its fetch/build/work stages through this once per run)."""
    if tracer is None:
        return fn

    def traced(x):
        with tracer.span(name):
            return fn(x)
    return traced
