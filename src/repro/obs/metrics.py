"""Cross-layer metrics registry: one namespace over the existing ledgers.

The repo's telemetry grew one ledger per subsystem — ``EngineStats`` /
``QueryStats`` (per-run), ``IOStats`` + ``BlockDevice`` tag partitions
(measured I/O), ``kernels/ledger.KernelLedger`` (device launches),
``serve.cache.TenantStats`` (per-tenant cache), the box-queue telemetry
dict. :class:`MetricsRegistry` does NOT replace their accounting: it
*adopts* them. Each ledger stays the single source of truth for its own
counters; registered adapters snapshot it into one labeled namespace on
``collect()``:

======================  ====================================================
series                  source ledger
======================  ====================================================
``io.*{tag=...}``       ``BlockDevice`` global + per-tag ``IOStats``
``cache.*{tenant=..}``  ``SharedSliceCache`` global + per-tenant ledgers
``kernel.*{op=...}``    ``KernelLedger`` totals folded per attach site
``box.*{lane=...}``     ``run_box_queue`` telemetry via the engines
``serve.*``             per-query latency histograms (p50/p90/p99)
``engine.* / query.*``  ``EngineStats`` / ``QueryStats`` published as gauges
======================  ====================================================

**Exact-sum invariants.** Adapters emit per-partition series *and* the
global, plus an explicit ``_untagged`` / ``_unattributed`` residual
(global minus the partition sum) — so per-tag/per-tenant series sum to
the global ledger exactly, by construction, and the residual being
nonzero is itself a signal (reads issued outside any attribution
window). ``tests/test_obs.py`` property-checks both directions against
the raw ledgers.

Direct instruments (``inc`` / ``set`` / ``observe``) exist for values
with no pre-existing ledger (per-query latency, benchmark gate
numbers). ``to_prom_text()`` renders the Prometheus textfile format;
``snapshot()`` returns plain nested dicts for tests and JSON records.

A process-wide default registry (``set_default_registry``) lets the
benchmark harness collect series from instrumented code it does not
construct; it is ``None`` unless something opts in, so library use pays
one module-global check.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["MetricsRegistry", "default_registry", "set_default_registry"]

_LabelKey = Tuple[Tuple[str, str], ...]

_IO_FIELDS = ("block_reads", "block_writes", "word_reads", "probes",
              "cache_served_words")
_CACHE_FIELDS = ("hits", "misses", "hit_words", "miss_words",
                 "passthrough_words")


def _labels_key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Named counters / gauges / histograms with string labels."""

    def __init__(self):
        self._lock = threading.Lock()
        # name -> labels_key -> value
        self._counters: Dict[str, Dict[_LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[_LabelKey, float]] = {}
        self._hists: Dict[str, Dict[_LabelKey, List[float]]] = {}
        self._adapters: List[Callable[[], None]] = []

    # -- direct instruments ---------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = _labels_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[key] = series.get(key, 0.0) + value

    def set(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges.setdefault(name, {})[_labels_key(labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._hists.setdefault(name, {}).setdefault(
                _labels_key(labels), []).append(float(value))

    # -- reads ----------------------------------------------------------------

    def get(self, name: str, **labels) -> Optional[float]:
        key = _labels_key(labels)
        with self._lock:
            for table in (self._gauges, self._counters):
                if name in table and key in table[name]:
                    return table[name][key]
        return None

    def series(self, name: str) -> Dict[_LabelKey, float]:
        """Every labeled value of one counter/gauge name."""
        with self._lock:
            out: Dict[_LabelKey, float] = {}
            out.update(self._counters.get(name, {}))
            out.update(self._gauges.get(name, {}))
            return out

    def quantile(self, name: str, q: float, **labels) -> Optional[float]:
        """Empirical quantile of one histogram series (q in [0, 1])."""
        with self._lock:
            vals = self._hists.get(name, {}).get(_labels_key(labels))
            if not vals:
                return None
            vals = sorted(vals)
        idx = min(len(vals) - 1, max(0, int(round(q * (len(vals) - 1)))))
        return vals[idx]

    # -- ledger adapters ------------------------------------------------------
    # each adapter re-snapshots its ledger on collect(): the ledger keeps
    # accounting exactly as before, the registry only mirrors it.

    def add_adapter(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._adapters.append(fn)

    def adopt_device(self, device, prefix: str = "io") -> None:
        """Mirror a ``BlockDevice``: global ``io.*`` gauges, per-tag
        ``io.*{tag=...}`` (partitions survive ``close_tag``), and the
        ``tag="_untagged"`` residual, so Σ_tags == global exactly."""

        def _collect(dev=device, pfx=prefix):
            tags = dev.all_tag_stats()
            for f in _IO_FIELDS:
                g = int(getattr(dev.stats, f))
                self.set(f"{pfx}.{f}", g)
                attributed = 0
                for tag, st in tags.items():
                    v = int(getattr(st, f))
                    attributed += v
                    self.set(f"{pfx}.{f}", v, tag=str(tag))
                self.set(f"{pfx}.{f}", g - attributed, tag="_untagged")
        self.add_adapter(_collect)

    def adopt_shared_cache(self, cache, relation: str = "E") -> None:
        """Mirror a ``SharedSliceCache``: global ``cache.*{relation=..}``,
        per-tenant ``cache.*{relation=.., tenant=..}`` (departed tenants
        included — their ledgers are kept), and the ``tenant="_shared"``
        residual, so Σ_tenants == global exactly."""

        def _collect(c=cache, rel=relation):
            tenants = c.all_tenant_stats()
            for f in _CACHE_FIELDS:
                g = int(getattr(c, f))
                self.set(f"cache.{f}", g, relation=rel)
                attributed = 0
                for tenant, st in tenants.items():
                    v = int(getattr(st, f))
                    attributed += v
                    self.set(f"cache.{f}", v, relation=rel,
                             tenant=str(tenant))
                self.set(f"cache.{f}", g - attributed, relation=rel,
                         tenant="_shared")
            self.set("cache.cross_hits", int(c.cross_hits), relation=rel)
        self.add_adapter(_collect)

    def adopt_slice_cache(self, cache, relation: str = "E") -> None:
        """Mirror a single-tenant ``SliceCache`` (no tenant label)."""

        def _collect(c=cache, rel=relation):
            for f in _CACHE_FIELDS:
                self.set(f"cache.{f}", int(getattr(c, f)), relation=rel)
        self.add_adapter(_collect)

    def note_kernel(self, ledger, op: str = "staged") -> None:
        """Fold one detached ``KernelLedger`` into the ``kernel.*{op=..}``
        counters (called once per box by the executors — the ledger
        object itself stays per-box/thread-local)."""
        if not ledger.invocations:
            return
        self.inc("kernel.invocations", ledger.invocations, op=op)
        self.inc("kernel.bytes_in", ledger.bytes_in, op=op)
        self.inc("kernel.bytes_out", ledger.bytes_out, op=op)

    def note_queue(self, tele: dict, lane: str = "all") -> None:
        """Fold one ``run_box_queue`` telemetry dict into ``box.*``."""
        self.inc("box.wait_s", tele.get("wait", 0.0), lane=lane)
        self.inc("box.build_s", tele.get("build", 0.0), lane=lane)
        self.inc("box.compute_s", tele.get("compute", 0.0), lane=lane)
        self.set("box.pool", tele.get("pool", 0), lane=lane)

    def publish_stats(self, stats, prefix: str, **labels) -> None:
        """Publish every numeric field of a stats object (``EngineStats``
        / ``QueryStats`` / ``FabricStats``) as ``<prefix>.<field>``
        gauges — the run-level dataclasses become views over the
        registry instead of a parallel bookkeeping system."""
        for f in getattr(stats, "__dataclass_fields__", {}):
            v = getattr(stats, f)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.set(f"{prefix}.{f}", float(v), **labels)

    def collect(self) -> "MetricsRegistry":
        """Run every ledger adapter (re-snapshotting the live ledgers
        into gauges); returns self for chaining."""
        with self._lock:
            adapters = list(self._adapters)
        for fn in adapters:
            fn()
        return self

    # -- export ---------------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, dict]]:
        """Plain-dict view: ``{"counters": {name: {label_str: v}}, ...}``
        with histograms summarized to count/sum/p50/p90/p99."""
        self.collect()
        with self._lock:
            def flat(table):
                return {name: {_label_str(k): v for k, v in series.items()}
                        for name, series in table.items()}
            hists = {}
            for name, series in self._hists.items():
                hists[name] = {}
                for k, vals in series.items():
                    s = sorted(vals)

                    def pick(q):
                        return s[min(len(s) - 1,
                                     max(0, int(round(q * (len(s) - 1)))))]
                    hists[name][_label_str(k)] = {
                        "count": len(s), "sum": sum(s),
                        "p50": pick(0.50), "p90": pick(0.90),
                        "p99": pick(0.99)}
            return {"counters": flat(self._counters),
                    "gauges": flat(self._gauges),
                    "histograms": hists}

    def to_prom_text(self) -> str:
        """Prometheus textfile exposition of every series (counters and
        gauges verbatim; histograms as _count/_sum plus quantile
        gauges)."""
        snap = self.snapshot()
        lines: List[str] = []
        for kind in ("counters", "gauges"):
            for name in sorted(snap[kind]):
                prom = _prom_name(name)
                lines.append(f"# TYPE {prom} "
                             f"{'counter' if kind == 'counters' else 'gauge'}")
                for label_str, v in sorted(snap[kind][name].items()):
                    lines.append(f"{prom}{label_str} {_prom_num(v)}")
        for name in sorted(snap["histograms"]):
            prom = _prom_name(name)
            lines.append(f"# TYPE {prom} summary")
            for label_str, h in sorted(snap["histograms"][name].items()):
                base = label_str[1:-1] if label_str else ""
                for q in ("p50", "p90", "p99"):
                    qlab = f'quantile="0.{q[1:]}"'
                    lab = f"{{{base},{qlab}}}" if base else f"{{{qlab}}}"
                    lines.append(f"{prom}{lab} {_prom_num(h[q])}")
                lines.append(f"{prom}_count{label_str} {h['count']}")
                lines.append(f"{prom}_sum{label_str} {_prom_num(h['sum'])}")
        return "\n".join(lines) + "\n"


def _label_str(key: _LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_num(v: float) -> str:
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


# -- process-wide default registry (benchmark harness opt-in) ----------------

_default: Optional[MetricsRegistry] = None


def default_registry() -> Optional[MetricsRegistry]:
    return _default


def set_default_registry(reg: Optional[MetricsRegistry]) -> None:
    global _default
    _default = reg
