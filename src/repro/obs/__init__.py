"""Unified observability layer: structured tracing + a metrics registry.

Two small, dependency-free pieces every layer of the system threads
through (PR 10):

* :mod:`repro.obs.trace` — a thread-safe, nestable :class:`Tracer` whose
  ``span()`` context managers record begin/end events (monotonic
  timestamps, thread id, parent span) into a bounded ring buffer, with a
  Chrome/Perfetto ``trace_event`` JSON exporter and a plain-dict
  snapshot for tests. Tracing is noop-by-default: every instrumented
  hot path pays exactly one ``is None`` attribute check when no tracer
  is attached, and instrumentation never reorders or adds source reads,
  so traced-off runs stay byte-identical to pre-instrumentation
  behaviour.
* :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of labeled
  counters/gauges/histograms that *adopts* the existing ledgers
  (``IOStats``/``BlockDevice`` tag partitions, ``KernelLedger``,
  ``SharedSliceCache`` tenants, box-queue telemetry) instead of
  duplicating them: adapters snapshot each ledger into one namespace
  (``io.block_reads{tag=...}``, ``kernel.invocations{op=...}``,
  ``box.compute_s{lane=...}``) with exact-sum invariants — per-tag and
  per-tenant series sum to the globals by construction. Exports
  Prometheus textfile format via ``to_prom_text()``.

Engines (:class:`~repro.core.engine.TriangleEngine`,
:class:`~repro.query.executor.QueryEngine`), the serving layer
(:class:`~repro.serve.server.Server`) and the distributed fabric
(:class:`~repro.parallel.fabric.Fabric`) all take optional ``tracer=``
and ``metrics=`` knobs wiring one tracer/registry through every stage
of a run.
"""

from .trace import Tracer, wrap_stage  # noqa: F401
from .metrics import (MetricsRegistry, default_registry,  # noqa: F401
                      set_default_registry)
