"""gcn-cora [arXiv:1609.02907; paper]: 2-layer GCN, sym-normalized."""

from repro.models.gnn import GNNConfig

from .base import GNN_SHAPES, ArchBundle, register

CONFIG = GNNConfig(
    name="gcn-cora", kind="gcn", n_layers=2, d_hidden=16,
    d_in=1433, d_out=7, aggregator="mean")

SMOKE_CONFIG = GNNConfig(
    name="gcn-cora-smoke", kind="gcn", n_layers=2, d_hidden=8,
    d_in=1433, d_out=7, aggregator="mean")

register(ArchBundle(
    arch_id="gcn-cora", family="gnn", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=GNN_SHAPES,
    notes="norm=sym; d_hidden=16 means full-batch cells are wholly "
          "bandwidth/collective bound — a roofline stress case."))
