"""gin-tu [arXiv:1810.00826; paper]: 5-layer GIN with learnable eps."""

from repro.models.gnn import GNNConfig

from .base import GNN_SHAPES, ArchBundle, register

CONFIG = GNNConfig(
    name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
    d_in=30, d_out=2, aggregator="sum", learn_eps=True)

SMOKE_CONFIG = GNNConfig(
    name="gin-tu-smoke", kind="gin", n_layers=2, d_hidden=16,
    d_in=30, d_out=2, aggregator="sum")

register(ArchBundle(
    arch_id="gin-tu", family="gnn", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=GNN_SHAPES,
    notes="sum aggregator; eps learnable per layer."))
