"""qwen2-7b [arXiv:2407.10671; hf]: dense GQA LM with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_head=128 d_ff=18944 vocab=152064.
"""

from repro.models.transformer import LayerSpec, TransformerConfig

from .base import LM_SHAPES, ArchBundle, register

CONFIG = TransformerConfig(
    name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_head=128, d_ff=18944, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0, pattern=(LayerSpec(),))

SMOKE_CONFIG = TransformerConfig(
    name="qwen2-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, qkv_bias=True, pattern=(LayerSpec(),))

register(ArchBundle(
    arch_id="qwen2-7b", family="lm", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=LM_SHAPES,
    notes="GQA kv=4, QKV bias; full attention (long_500k is decode-only, "
          "see DESIGN.md LM shape notes)."))
