"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; 128 routed experts
top-1 + shared expert, MoE on alternate layers; iRoPE-style attention:
chunked-local (8192) RoPE layers with every 4th layer global NoPE.
The upstream card is marked *unverified*; interleaving choices recorded in
DESIGN.md §Config provenance. [vlm] card: backbone only — the vision
frontend is a stub (input_specs feeds precomputed token embeddings).
"""

from repro.models.transformer import LayerSpec, TransformerConfig

from .base import LM_SHAPES, ArchBundle, register

_LOCAL_MOE = LayerSpec(ffn="moe", use_rope=True, chunk=8192)
_LOCAL_DENSE = LayerSpec(ffn="dense", use_rope=True, chunk=8192)
_GLOBAL_DENSE = LayerSpec(ffn="dense", use_rope=False, chunk=None)  # NoPE

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_head=128, d_ff=8192, vocab=202048,
    rope_theta=500_000.0,
    pattern=(_LOCAL_MOE, _LOCAL_DENSE, _LOCAL_MOE, _GLOBAL_DENSE),
    n_experts=128, top_k=1, n_shared=1, d_ff_moe=8192,
    moe_impl="gathered_sort")

SMOKE_CONFIG = TransformerConfig(
    name="llama4-maverick-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
    pattern=(LayerSpec(ffn="moe", chunk=64),
             LayerSpec(ffn="dense", chunk=64),
             LayerSpec(ffn="moe", chunk=64),
             LayerSpec(ffn="dense", use_rope=False)),
    n_experts=4, top_k=1, n_shared=1, d_ff_moe=32, moe_impl="dense")

register(ArchBundle(
    arch_id="llama4-maverick-400b-a17b", family="lm", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=LM_SHAPES,
    notes="chunked-local attention keeps 3/4 of layers O(S*chunk): the one "
          "assigned LM arch where long prefill is sub-quadratic."))
