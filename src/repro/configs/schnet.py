"""schnet [arXiv:1706.08566; paper]: continuous-filter conv GNN.

n_interactions=3 d_hidden=64 rbf=300 cutoff=10. On shapes without
positions, unit distances are synthesized (DESIGN.md §Arch-applicability).
"""

from repro.models.gnn import GNNConfig

from .base import GNN_SHAPES, ArchBundle, register

CONFIG = GNNConfig(
    name="schnet", kind="schnet", n_layers=3, d_hidden=64,
    d_in=30, d_out=1, n_rbf=300, cutoff=10.0)

SMOKE_CONFIG = GNNConfig(
    name="schnet-smoke", kind="schnet", n_layers=2, d_hidden=16,
    d_in=30, d_out=1, n_rbf=16, cutoff=10.0)

register(ArchBundle(
    arch_id="schnet", family="gnn", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=GNN_SHAPES,
    notes="triplet-gather regime (kernel_taxonomy B.3); the RBF filter "
          "MLP dominates flops on molecule batches."))
