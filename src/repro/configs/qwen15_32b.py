"""qwen1.5-32b [hf:Qwen/Qwen1.5-*; hf]: dense MHA LM with QKV bias.

64L d_model=5120 40H (kv=40: full MHA) d_ff=27392 vocab=152064.
"""

from repro.models.transformer import LayerSpec, TransformerConfig

from .base import LM_SHAPES, ArchBundle, register

CONFIG = TransformerConfig(
    name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_head=128, d_ff=27392, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0, pattern=(LayerSpec(),))

SMOKE_CONFIG = TransformerConfig(
    name="qwen1.5-32b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256, qkv_bias=True, pattern=(LayerSpec(),))

register(ArchBundle(
    arch_id="qwen1.5-32b", family="lm", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=LM_SHAPES,
    notes="full MHA (kv=40): the decode shapes are KV-bandwidth bound — "
          "the arch most exposed to the memory roofline term."))
