"""Config registry: architectures × input shapes (assignment cells).

Each arch file registers an ArchBundle; ``input_specs(arch, shape)`` builds
ShapeDtypeStruct stand-ins for every model input of that cell — weak-type
correct, shardable, zero allocation — consumed by launch/dryrun.py.

Step kinds per shape (assignment):
  LM:   train_4k -> train_step · prefill_32k -> prefill_step ·
        decode_32k / long_500k -> serve_step (1 new token vs KV cache)
  GNN:  all four graph shapes -> train_step (full-batch or sampled block)
  DLRM: train_batch -> train_step · serve_p99/serve_bulk -> serve_step ·
        retrieval_cand -> retrieval_step
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

REGISTRY: Dict[str, "ArchBundle"] = {}

I32 = jnp.int32
F32 = jnp.float32


@dataclass
class ShapeSpec:
    name: str
    step: str                  # train | prefill | decode | serve | retrieval
    dims: Dict[str, int] = field(default_factory=dict)


@dataclass
class ArchBundle:
    arch_id: str
    family: str                # lm | gnn | recsys
    config: Any                # full-size model config
    smoke_config: Any          # reduced config for CPU smoke tests
    shapes: Dict[str, ShapeSpec]
    # family-specific hook: (cfg, spec) -> dict of ShapeDtypeStructs
    notes: str = ""

    def shape_names(self):
        return list(self.shapes)


def register(bundle: ArchBundle) -> ArchBundle:
    REGISTRY[bundle.arch_id] = bundle
    return bundle


def get_arch(arch_id: str) -> ArchBundle:
    if arch_id not in REGISTRY:
        from . import _load_all
        _load_all()
    return REGISTRY[arch_id]


def all_arch_ids():
    from . import _load_all
    _load_all()
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# canonical shape tables (from the assignment)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq=4096, batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq=32768, batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq=32768, batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode",
                           dict(seq=524288, batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                                    n_classes=7)),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
             fanout=(15, 10), d_feat=602, n_classes=41,
             # padded sampled-block sizes (seeds + 1-hop + 2-hop)
             blk_nodes=1024 * (1 + 15 + 150), blk_edges=1024 * (15 + 150))),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              dict(n_nodes=2449029, n_edges=61859140,
                                   d_feat=100, n_classes=47)),
    "molecule": ShapeSpec("molecule", "train",
                          dict(n_nodes=30, n_edges=64, batch=128, d_feat=30,
                               d_target=1)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}


# ---------------------------------------------------------------------------
# input_specs builders
# ---------------------------------------------------------------------------

def lm_input_specs(cfg, spec: ShapeSpec) -> Dict[str, Any]:
    b, s = spec.dims["batch"], spec.dims["seq"]
    if spec.step == "train":
        return {"tokens": jax.ShapeDtypeStruct((b, s), I32),
                "targets": jax.ShapeDtypeStruct((b, s), I32)}
    if spec.step == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), I32)}
    if spec.step == "decode":
        from repro.models.transformer import cache_specs
        return {"cache": cache_specs(cfg, b, s),
                "token": jax.ShapeDtypeStruct((b, 1), I32),
                "pos": jax.ShapeDtypeStruct((), I32)}
    raise ValueError(spec.step)


def _pad_to(n: int, m: int = 512) -> int:
    """Graph sizes are padded to multiples of the full mesh size (512) so
    node/edge arrays shard evenly; masks zero out the padding."""
    return ((n + m - 1) // m) * m


def gnn_input_specs(cfg, spec: ShapeSpec) -> Dict[str, Any]:
    d = spec.dims
    if spec.name == "minibatch_lg":
        n, e = d["blk_nodes"], d["blk_edges"]
    elif spec.name == "molecule":
        n = d["n_nodes"] * d["batch"]
        e = d["n_edges"] * d["batch"]
    else:
        n, e = d["n_nodes"], d["n_edges"]
    n, e = _pad_to(n), _pad_to(e)
    out: Dict[str, Any] = {
        "node_feat": jax.ShapeDtypeStruct((n, d["d_feat"]), F32),
        "edge_src": jax.ShapeDtypeStruct((e,), I32),
        "edge_dst": jax.ShapeDtypeStruct((e,), I32),
        "edge_mask": jax.ShapeDtypeStruct((e,), F32),
        "node_mask": jax.ShapeDtypeStruct((n,), F32),
    }
    if spec.name == "molecule":
        # per-node regression (atomic-energy style); positions for SchNet
        out["pos"] = jax.ShapeDtypeStruct((n, 3), F32)
        out["graph_id"] = jax.ShapeDtypeStruct((n,), I32)
        out["targets"] = jax.ShapeDtypeStruct((n, d["d_target"]), F32)
    else:
        out["labels"] = jax.ShapeDtypeStruct((n,), I32)
        out["label_mask"] = jax.ShapeDtypeStruct((n,), F32)
    return out


def config_for_shape(arch_id: str, shape_name: str, smoke: bool = False):
    """Specialize the arch config to a shape (GNN d_in/d_out track the
    graph's feature/label dims; LM/recsys configs are shape-independent)."""
    import dataclasses
    bundle = get_arch(arch_id)
    cfg = bundle.smoke_config if smoke else bundle.config
    if bundle.family != "gnn":
        return cfg
    spec = bundle.shapes[shape_name]
    d = spec.dims
    d_in = d["d_feat"]
    d_out = d.get("d_target", d.get("n_classes", cfg.d_out))
    return dataclasses.replace(cfg, d_in=d_in, d_out=d_out)


def recsys_input_specs(cfg, spec: ShapeSpec) -> Dict[str, Any]:
    b = spec.dims["batch"]
    out = {"dense": jax.ShapeDtypeStruct((b, cfg.n_dense), F32),
           "sparse": jax.ShapeDtypeStruct((b, cfg.n_sparse, cfg.hot), I32)}
    if spec.step == "train":
        out["labels"] = jax.ShapeDtypeStruct((b,), F32)
    if spec.step == "retrieval":
        out["candidates"] = jax.ShapeDtypeStruct(
            (spec.dims["n_candidates"], cfg.embed_dim), F32)
    return out


def input_specs(arch_id: str, shape_name: str, smoke: bool = False,
                cfg=None):
    """(step_kind, specs) for a cell; smoke=True uses the reduced config.
    ``cfg`` overrides the registry config (probe/transformed cells)."""
    bundle = get_arch(arch_id)
    if cfg is None:
        cfg = bundle.smoke_config if smoke else bundle.config
    spec = bundle.shapes[shape_name]
    if bundle.family == "lm":
        return spec.step, lm_input_specs(cfg, spec)
    if bundle.family == "gnn":
        return spec.step, gnn_input_specs(cfg, spec)
    if bundle.family == "recsys":
        return spec.step, recsys_input_specs(cfg, spec)
    raise ValueError(bundle.family)
