"""yi-6b [arXiv:2403.04652; hf]: llama-arch GQA LM.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.models.transformer import LayerSpec, TransformerConfig

from .base import LM_SHAPES, ArchBundle, register

CONFIG = TransformerConfig(
    name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_head=128, d_ff=11008, vocab=64000, qkv_bias=False,
    rope_theta=5_000_000.0, pattern=(LayerSpec(),))

SMOKE_CONFIG = TransformerConfig(
    name="yi-6b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=256, pattern=(LayerSpec(),))

register(ArchBundle(
    arch_id="yi-6b", family="lm", config=CONFIG, smoke_config=SMOKE_CONFIG,
    shapes=LM_SHAPES,
    notes="llama-style GQA kv=4, no bias."))
