"""Architecture registry (one module per assigned arch + paper workload)."""

from .base import (REGISTRY, ArchBundle, ShapeSpec, all_arch_ids,
                   config_for_shape, get_arch, input_specs)

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (deepseek_v2_236b, dlrm_mlperf, gcn_cora, gin_tu,  # noqa
                   graphcast, llama4_maverick, qwen15_32b, qwen2_7b,
                   schnet, yi_6b)
    _LOADED = True


_load_all()

__all__ = ["REGISTRY", "ArchBundle", "ShapeSpec", "all_arch_ids",
           "config_for_shape", "get_arch", "input_specs"]
