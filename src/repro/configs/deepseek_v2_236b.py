"""deepseek-v2-236b [arXiv:2405.04434; hf]: MLA + fine-grained MoE.

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128), vocab=102400; MoE: 2 shared + 160 routed top-6,
expert d_ff=1536; layer 0 dense (d_ff=12288).
"""

from repro.models.transformer import LayerSpec, TransformerConfig

from .base import LM_SHAPES, ArchBundle, register

CONFIG = TransformerConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, d_head=128, d_ff=12288, vocab=102400,
    rope_theta=10_000.0,
    prefix=(LayerSpec(ffn="dense"),),
    pattern=(LayerSpec(ffn="moe"),),
    n_experts=160, top_k=6, n_shared=2, d_ff_moe=1536,
    moe_impl="gathered_sort",
    mla=True, q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128)

SMOKE_CONFIG = TransformerConfig(
    name="deepseek-v2-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
    prefix=(LayerSpec(ffn="dense"),), pattern=(LayerSpec(ffn="moe"),),
    n_experts=8, top_k=2, n_shared=1, d_ff_moe=32, moe_impl="dense",
    mla=True, q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16)

register(ArchBundle(
    arch_id="deepseek-v2-236b", family="lm", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=LM_SHAPES,
    notes="MLA decode caches the 512-dim latent + 64-dim rope key per "
          "token (vs 128 heads * 256: ~57x KV compression); MoE experts "
          "shard over the model axis."))
