"""dlrm-mlperf [arXiv:1906.00091; paper]: MLPerf DLRM (Criteo 1TB).

n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 interaction=dot.
"""

from repro.models.dlrm import CRITEO_TABLE_SIZES, DLRMConfig

from .base import RECSYS_SHAPES, ArchBundle, register


def _pad512(v: int) -> int:
    """Vocabs padded to multiples of 512 so tables shard over any mesh
    axis combination (§Perf dlrm_train v0: unpadded Criteo sizes are not
    divisible by 16 and silently fell back to full replication — 240 GiB
    of tables+moments per device). Pad rows are never referenced."""
    return ((v + 511) // 512) * 512


CONFIG = DLRMConfig(
    name="dlrm-mlperf", n_dense=13, embed_dim=128,
    table_sizes=tuple(_pad512(v) for v in CRITEO_TABLE_SIZES),
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1), hot=1,
    sparse_optimizer=True, shard_moments_2d=True)

SMOKE_CONFIG = DLRMConfig(
    name="dlrm-smoke", n_dense=13, embed_dim=16,
    table_sizes=(100, 50, 20, 7),
    bot_mlp=(32, 16), top_mlp=(32, 16, 1), hot=3)

register(ArchBundle(
    arch_id="dlrm-mlperf", family="recsys", config=CONFIG,
    smoke_config=SMOKE_CONFIG, shapes=RECSYS_SHAPES,
    notes="~24B embedding params (188M rows x 128); tables vocab-sharded "
          "over the model axis, bag-sum psum-combined (DESIGN.md §5). The "
          "lookup is the join Bags ⋈ Table — probe/provision machinery "
          "reused for budgeted shard prefetch."))
