"""Graph data: generators (RAND/RMAT), CSR utilities, icosahedral mesh.

RAND and RMAT are the paper's synthetic datasets (§6, Fig. 6): RAND picks
endpoints uniformly; RMAT follows Chakrabarti et al. [5] with the standard
(a,b,c,d) = (0.57, 0.19, 0.19, 0.05) parameters. Graphs are simplified
(self/duplicate edges removed) exactly as in the paper.

``icosahedral_mesh`` builds GraphCast's refinement-r multimesh
[arXiv:2212.12794]: recursively subdivided icosahedron with the union of
all refinement levels' edges.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def simplify_edges(src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Remove self loops and duplicate (undirected) edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    a = np.minimum(src, dst)
    b = np.maximum(src, dst)
    e = np.unique(np.stack([a, b], axis=1), axis=0)
    return e[:, 0], e[:, 1]


def random_graph(n_nodes: int, n_edges: int, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """The paper's RAND dataset: uniform endpoints, then simplified."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges, dtype=np.int64)
    dst = rng.integers(0, n_nodes, n_edges, dtype=np.int64)
    return simplify_edges(src, dst)


def rmat_graph(n_nodes: int, n_edges: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> Tuple[np.ndarray, np.ndarray]:
    """R-MAT generator [Chakrabarti et al. 2004], vectorized.

    Each edge picks one quadrant per scale via categorical draws; node ids
    are the accumulated bit paths. Power-law degrees, community structure —
    the paper's hard synthetic case (hub nodes stress boxing)."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(2, n_nodes))))
    p = np.asarray([a, b, c, 1.0 - a - b - c])
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    for bit in range(scale):
        q = rng.choice(4, size=n_edges, p=p)
        src = (src << 1) | (q >> 1)
        dst = (dst << 1) | (q & 1)
    src %= n_nodes
    dst %= n_nodes
    return simplify_edges(src, dst)


def clustered_graph(n_clusters: int, cluster_size: int, seed: int = 0,
                    p_in: float = 0.5) -> Tuple[np.ndarray, np.ndarray]:
    """Triangle-rich planted-partition graph (tests/benchmarks oracle).

    Arboricity scales with cluster density — used for the Thm. 17
    arboricity-scaling benchmark (cliques pack α ≈ cluster_size/2)."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for ci in range(n_clusters):
        base = ci * cluster_size
        m = rng.random((cluster_size, cluster_size)) < p_in
        iu, ju = np.triu_indices(cluster_size, k=1)
        sel = m[iu, ju]
        srcs.append(base + iu[sel])
        dsts.append(base + ju[sel])
    # sparse inter-cluster chain keeps it connected
    chain = np.arange(n_clusters - 1) * cluster_size
    srcs.append(chain)
    dsts.append(chain + cluster_size)
    return simplify_edges(np.concatenate(srcs), np.concatenate(dsts))


def synthetic_features(n_nodes: int, d_feat: int, n_classes: int,
                       seed: int = 0) -> Dict[str, np.ndarray]:
    """Class-conditioned Gaussian features (GNN train smoke/examples)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.standard_normal((n_classes, d_feat)) * 2.0
    feats = centers[labels] + rng.standard_normal((n_nodes, d_feat))
    return {"node_feat": feats.astype(np.float32),
            "labels": labels.astype(np.int32)}


def make_gnn_batch(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   d_feat: int, n_classes: int = 0, d_target: int = 0,
                   pad_to: int = 0, seed: int = 0,
                   pos: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """Fixed-shape padded GNN batch matching configs.base.gnn_input_specs."""
    n, e = n_nodes, len(src)
    n_pad = max(n, pad_to) if pad_to else n
    e_pad = max(e, pad_to) if pad_to else e
    if pad_to:
        n_pad = ((n + pad_to - 1) // pad_to) * pad_to
        e_pad = ((e + pad_to - 1) // pad_to) * pad_to
    rng = np.random.default_rng(seed)
    batch = {
        "node_feat": np.zeros((n_pad, d_feat), np.float32),
        "edge_src": np.zeros((e_pad,), np.int32),
        "edge_dst": np.zeros((e_pad,), np.int32),
        "edge_mask": np.zeros((e_pad,), np.float32),
        "node_mask": np.zeros((n_pad,), np.float32),
    }
    feats = synthetic_features(n, d_feat, max(2, n_classes), seed)
    batch["node_feat"][:n] = feats["node_feat"]
    batch["edge_src"][:e] = src
    batch["edge_dst"][:e] = dst
    batch["edge_mask"][:e] = 1.0
    batch["node_mask"][:n] = 1.0
    if d_target:
        batch["targets"] = np.zeros((n_pad, d_target), np.float32)
        batch["targets"][:n] = rng.standard_normal((n, d_target))
        if pos is None:
            pos = rng.standard_normal((n, 3)).astype(np.float32)
        batch["pos"] = np.zeros((n_pad, 3), np.float32)
        batch["pos"][:n] = pos
        batch["graph_id"] = np.zeros((n_pad,), np.int32)
    else:
        batch["labels"] = np.zeros((n_pad,), np.int32)
        batch["labels"][:n] = feats["labels"] % n_classes
        batch["label_mask"] = batch["node_mask"].copy()
    return batch


# ---------------------------------------------------------------------------
# GraphCast icosahedral multimesh
# ---------------------------------------------------------------------------

def icosahedral_mesh(refinement: int = 2):
    """Vertices + multimesh edges of a recursively refined icosahedron.

    Returns (verts (V,3) float32 unit sphere, src, dst) where the edge set
    is the union over refinement levels 0..r (GraphCast's multimesh).
    refinement=6 gives 40,962 nodes (the arch card's mesh size)."""
    phi = (1 + np.sqrt(5)) / 2
    verts = np.asarray([
        [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
        [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
        [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1]],
        dtype=np.float64)
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.asarray([
        [0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
        [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
        [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
        [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1]])

    all_edges = []

    def face_edges(fs):
        e = np.concatenate([fs[:, [0, 1]], fs[:, [1, 2]], fs[:, [2, 0]]])
        a = np.minimum(e[:, 0], e[:, 1])
        b = np.maximum(e[:, 0], e[:, 1])
        return np.unique(np.stack([a, b], 1), axis=0)

    all_edges.append(face_edges(faces))
    for _ in range(refinement):
        verts_list = [verts]
        midpoint = {}
        nv = len(verts)

        def mid(i, j):
            nonlocal nv
            key = (min(i, j), max(i, j))
            if key not in midpoint:
                m = verts_list[0][i] + verts_list[0][j]
                verts_list.append((m / np.linalg.norm(m))[None])
                midpoint[key] = nv
                nv += 1
            return midpoint[key]

        verts_cat = verts
        new_faces = []
        for (i, j, k) in faces:
            # note: mid() reads verts (pre-refinement coords)
            a = mid(i, j)
            b = mid(j, k)
            c = mid(k, i)
            new_faces += [[i, a, c], [j, b, a], [k, c, b], [a, b, c]]
        verts = np.concatenate(verts_list)
        faces = np.asarray(new_faces)
        all_edges.append(face_edges(faces))

    edges = np.unique(np.concatenate(all_edges), axis=0)
    return verts.astype(np.float32), edges[:, 0].astype(np.int64), \
        edges[:, 1].astype(np.int64)
