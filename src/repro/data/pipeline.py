"""Host-side input pipeline: prefetching and edge batching.

Two consumers share this module:

* the **streaming executor** (``core.executor``) wraps its per-box slice
  materialization in a ``Prefetcher`` so host DMA overlaps device compute;
* the **ingest path** (``TriangleEngine.ingest`` ->
  ``data.edgestore.EdgeStoreWriter``) wraps the edge-batch producer in a
  depth-1 ``Prefetcher`` so reading/generating the next batch overlaps the
  writer's sort-and-spill work, and uses ``edge_batches`` to slice big
  in-memory arrays into bounded batches.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import numpy as np


def edge_batches(src, dst, batch_edges: int = 1 << 20) -> Iterator:
    """Yield ``(src, dst)`` batches of at most ``batch_edges`` edges.

    Convenience for feeding already-materialized arrays to the streaming
    ingest path; each yielded pair is a view, so the generator itself
    allocates nothing.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    if len(src) != len(dst):
        raise ValueError("src and dst differ in length")
    batch_edges = max(1, int(batch_edges))
    for i in range(0, len(src), batch_edges):
        yield src[i:i + batch_edges], dst[i:i + batch_edges]


class Prefetcher:
    """Runs ``producer()`` on a background thread, ``depth`` batches ahead.

    Iteration order is preserved; exceptions propagate to the consumer.
    """

    _SENTINEL = object()

    def __init__(self, producer: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: Optional[BaseException] = None
        self._stop = False
        self._closed = False

        def run():
            try:
                for item in producer:
                    while not self._stop:
                        try:
                            self.q.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
                    if self._stop:
                        break
            except BaseException as e:  # noqa: BLE001
                self.err = e
            finally:
                while True:
                    try:
                        self.q.put(self._SENTINEL, timeout=0.1)
                        break
                    except queue.Full:
                        if self._stop:
                            break

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._SENTINEL:
            if self.err is not None:
                raise self.err
            raise StopIteration
        return item

    def close(self, timeout: float = 5.0) -> None:
        """Stop the producer early (consumer abandons the stream).

        The background thread stops at its next queue hand-off; already
        queued items are discarded and the thread is joined, so a closed
        prefetcher never leaks its producer. Idempotent: double-close (or
        close after exhaustion) is a cheap no-op."""
        self._stop = True
        if self._closed:
            return
        # drain until the producer exits: it may be blocked mid-put, so one
        # drain pass is not enough to guarantee progress
        deadline = time.monotonic() + timeout
        while True:
            try:
                while True:
                    self.q.get_nowait()
            except queue.Empty:
                pass
            self.thread.join(timeout=0.05)
            if not self.thread.is_alive() or time.monotonic() > deadline:
                break
        self._closed = True
