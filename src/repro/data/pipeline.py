"""Double-buffered host prefetcher: overlaps host batch prep with device
compute (the standard input-pipeline pattern on TPU hosts)."""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class Prefetcher:
    """Runs ``producer()`` on a background thread, ``depth`` batches ahead.

    Iteration order is preserved; exceptions propagate to the consumer.
    """

    _SENTINEL = object()

    def __init__(self, producer: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: Optional[BaseException] = None
        self._stop = False

        def run():
            try:
                for item in producer:
                    while not self._stop:
                        try:
                            self.q.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
                    if self._stop:
                        break
            except BaseException as e:  # noqa: BLE001
                self.err = e
            finally:
                while True:
                    try:
                        self.q.put(self._SENTINEL, timeout=0.1)
                        break
                    except queue.Full:
                        if self._stop:
                            break

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._SENTINEL:
            if self.err is not None:
                raise self.err
            raise StopIteration
        return item

    def close(self) -> None:
        """Stop the producer early (consumer abandons the stream).

        The background thread stops at its next queue hand-off; already
        queued items are discarded."""
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
