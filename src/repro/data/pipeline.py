"""Double-buffered host prefetcher: overlaps host batch prep with device
compute (the standard input-pipeline pattern on TPU hosts)."""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class Prefetcher:
    """Runs ``producer()`` on a background thread, ``depth`` batches ahead.

    Iteration order is preserved; exceptions propagate to the consumer.
    """

    _SENTINEL = object()

    def __init__(self, producer: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: Optional[BaseException] = None

        def run():
            try:
                for item in producer:
                    self.q.put(item)
            except BaseException as e:  # noqa: BLE001
                self.err = e
            finally:
                self.q.put(self._SENTINEL)

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._SENTINEL:
            if self.err is not None:
                raise self.err
            raise StopIteration
        return item
