"""Spillable edge store: memory-mapped chunked-CSR on disk (writer + reader).

The paper's out-of-core setting (§1 "Model & Assumptions") keeps the edge
relation on secondary storage and charges block I/Os for every word pulled
into the memory budget M. This module is that storage layer for the
streaming triangle engine:

  * ``write_edge_store`` lays the oriented CSR graph out as *chunked CSR*:
    the ``indices`` stream is split into fixed row-count chunks, each
    aligned to a block boundary, with a chunk directory mapping chunk id to
    its word offset. A reader can therefore fetch any vertex row range by
    touching only the chunks that overlap it — the paper's contiguous slice
    provisioning (Def. 6) as literal file reads.
  * ``EdgeStore`` memory-maps the file and serves ``read_rows`` range reads.
    Every read is charged to a ``core.iomodel.BlockDevice`` when one is
    attached, so ``EngineStats`` reports *measured* block I/Os that
    benchmarks compare against the Thm. 10 prediction.
  * ``InMemoryEdgeSource`` wraps host (indptr, indices) arrays behind the
    same interface, so the streaming executor is agnostic to where the
    graph lives.

Two writers produce the same file, byte for byte:

  * ``write_edge_store`` — in-memory: orient + sort the whole edge list in
    RAM, then lay it out. Simple, but peak memory is O(|E|).
  * ``EdgeStoreWriter`` / ``write_edge_store_streaming`` — bounded-memory
    ingest: edges are appended in batches, spilled to sorted run files
    whenever the in-RAM buffer reaches the word budget (pass 1), then
    k-way-merged directly into the chunked-CSR layout (pass 2). Peak
    ingest allocations scale with the budget — ~2x the budget bytes plus
    the O(V) resident degree index and fixed per-batch/merge floors (which
    dominate only at toy budgets; see tests/test_ingest.py for the
    enforced envelope) — so graphs larger than RAM are ingestable, not
    just queryable, out of core.

Only the (V+1)-word ``indptr`` prefix array is kept resident (the paper's
planner likewise assumes the index structure of E is probe-able); the
neighbor stream itself is paged in per box.

File layout (little-endian; the full spec with field offsets lives in
``docs/EDGESTORE_FORMAT.md``)::

    [0:64)       header: magic 'RPRCSR01', version, orientation flag,
                 n_nodes, n_edges, chunk_rows, n_chunks, align_words, k_max
    [64:...)     indptr   int64[n_nodes + 1]
    [...]        chunk directory int64[n_chunks + 1]  (word offsets)
    [...]        indices  int32, per chunk, padded to align_words
"""

from __future__ import annotations

import os
import tempfile
from typing import Iterable, Optional, Tuple

import numpy as np

MAGIC = b"RPRCSR01"
VERSION = 1

_ORIENT_FLAGS = {"minmax": 0, "degree": 1, "raw": 2}
_FLAG_ORIENTS = {v: k for k, v in _ORIENT_FLAGS.items()}

_HEADER = np.dtype([
    ("magic", "S8"), ("version", "<i4"), ("orient", "<i4"),
    ("n_nodes", "<i8"), ("n_edges", "<i8"), ("chunk_rows", "<i8"),
    ("n_chunks", "<i8"), ("align_words", "<i8"), ("k_max", "<i8"),
])
assert _HEADER.itemsize == 64


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def write_edge_store_csr(path, indptr: np.ndarray, indices: np.ndarray, *,
                         orientation: str = "raw", chunk_rows: int = 4096,
                         align_words: int = 1024) -> str:
    """Write a (sorted-row) CSR graph as a chunked-CSR edge store file."""
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int32)
    n_nodes = len(indptr) - 1
    n_edges = len(indices)
    chunk_rows = max(1, int(chunk_rows))
    align_words = max(1, int(align_words))
    n_chunks = max(1, -(-n_nodes // chunk_rows))
    deg = np.diff(indptr)

    offsets = np.zeros(n_chunks + 1, dtype=np.int64)
    chunks = []
    off = 0
    for c in range(n_chunks):
        r0, r1 = c * chunk_rows, min(n_nodes, (c + 1) * chunk_rows)
        data = indices[indptr[r0]:indptr[r1]]
        pad = (-len(data)) % align_words
        if pad:
            data = np.concatenate([data, np.zeros(pad, np.int32)])
        offsets[c] = off
        off += len(data)
        chunks.append(data)
    offsets[n_chunks] = off

    hdr = np.zeros((), dtype=_HEADER)
    hdr["magic"] = MAGIC
    hdr["version"] = VERSION
    hdr["orient"] = _ORIENT_FLAGS.get(orientation, _ORIENT_FLAGS["raw"])
    hdr["n_nodes"] = n_nodes
    hdr["n_edges"] = n_edges
    hdr["chunk_rows"] = chunk_rows
    hdr["n_chunks"] = n_chunks
    hdr["align_words"] = align_words
    hdr["k_max"] = int(deg.max(initial=0))

    path = os.fspath(path)
    with open(path, "wb") as f:
        f.write(hdr.tobytes())
        f.write(indptr.tobytes())
        f.write(offsets.tobytes())
        for data in chunks:
            f.write(data.tobytes())
    return path


def write_edge_store(path, src: np.ndarray, dst: np.ndarray, *,
                     orientation: str = "minmax", chunk_rows: int = 4096,
                     align_words: int = 1024) -> str:
    """Orient an undirected edge list and write it as an edge store.

    The stored graph is the oriented DAG G* (paper §2.3), which is what the
    triangle engine consumes; ``orientation`` is recorded in the header so
    the engine can recover sound pruning rules when it opens the file.
    """
    from repro.core.lftj_jax import csr_from_edges, orient_edges

    a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
    nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
    if nv:
        indptr, indices = csr_from_edges(a, b, n_nodes=nv)
    else:
        indptr, indices = np.zeros(1, np.int64), np.zeros(0, np.int32)
    return write_edge_store_csr(path, indptr, indices,
                                orientation=orientation,
                                chunk_rows=chunk_rows,
                                align_words=align_words)


# ---------------------------------------------------------------------------
# streaming writer (bounded-memory two-pass external-sort ingest)
# ---------------------------------------------------------------------------

class _RunReader:
    """Buffered sequential reader over one sorted spill-run file."""

    def __init__(self, path: str, buf_edges: int):
        self.path = path
        # unbuffered: with k runs open at once, per-file Python I/O buffers
        # (~4-8 KiB each) would dwarf the merge's own word budget
        self.f = open(path, "rb", buffering=0)
        self.buf_edges = max(64, int(buf_edges))
        self.buf = np.zeros(0, np.int64)
        self.eof = False

    def fill(self) -> None:
        if self.eof or len(self.buf) >= self.buf_edges:
            return
        want = self.buf_edges - len(self.buf)
        new = np.fromfile(self.f, dtype=np.int64, count=want)
        if len(new) < want:
            self.eof = True
        self.buf = np.concatenate([self.buf, new]) if len(self.buf) else new

    def close(self) -> None:
        self.f.close()


class EdgeStoreWriter:
    """Bounded-memory streaming edge-store builder (two-pass external sort).

    The in-memory ``write_edge_store`` materializes the whole oriented edge
    list — which makes "graphs larger than RAM" hold only *after* ingest.
    This writer keeps peak ingest allocations at ~2x ``budget_words``
    (4-byte words, the store's unit) plus the O(V) degree index and small
    fixed floors (minimum buffer/batch sizes — relevant only when the
    budget itself is tiny):

    * **pass 1 (spill runs)** — ``add_edges`` batches are self-loop-filtered,
      canonicalized to (min, max) 64-bit keys and appended to a fixed-size
      buffer. A full buffer is sorted in place, deduplicated, and spilled as
      one sorted run file.
    * **pass 2 (merge)** — ``finalize`` k-way-merges the runs (deduplicating
      across runs) straight into the chunked-CSR layout: the merge yields
      edges in CSR order, so chunks stream to their final file offsets and
      only the header / indptr / chunk directory are back-patched at the end.

    The output is byte-identical to ``write_edge_store`` for the same edge
    multiset. For ``orientation='degree'`` the orientation key needs global
    degree counts, which are only known after pass 1 — runs are then
    re-oriented and re-sorted block-wise (an extra pass over the spill
    files) before the merge; ``'minmax'`` orients on the fly.

    Not thread-safe; one writer per output file. Use as a context manager
    to clean up spill runs on error.
    """

    def __init__(self, path, *, orientation: str = "minmax",
                 chunk_rows: int = 4096, align_words: int = 1024,
                 budget_words: int = 1 << 22, tmpdir: Optional[str] = None):
        if orientation not in ("minmax", "degree"):
            raise ValueError(f"orientation {orientation!r} not in "
                             "('minmax', 'degree')")
        self.path = os.fspath(path)
        self.orientation = orientation
        self.chunk_rows = max(1, int(chunk_rows))
        self.align_words = max(1, int(align_words))
        self.budget_words = max(1024, int(budget_words))
        # buffer of int64 keys: flush peak is ~17 bytes/buffered edge
        # (8 buffer + 1 dedup mask + 8 unique copy), so cap = budget/3
        # edges keeps the pass-1 peak near 1.4x the byte budget
        self._cap = max(1024, self.budget_words // 3)
        self._buf = np.empty(self._cap, dtype=np.int64)
        self._fill = 0
        self._runs: list = []
        self._max_id = -1
        self._n_raw = 0
        self.n_spill_runs = 0        # total pass-1 runs (telemetry)
        self._deg = np.zeros(0, dtype=np.int64)   # degree orientation only
        self._tmpdir = tmpdir
        self._own_tmpdir: Optional[str] = None
        self._finalized = False

    # -- pass 1: batch append + spill ----------------------------------------

    def add_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Append one batch of undirected edges (duplicates/self-loops ok)."""
        if self._finalized:
            raise RuntimeError("writer already finalized")
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        if len(src) != len(dst):
            raise ValueError("src and dst batches differ in length")
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if len(src) == 0:
            return
        lo = int(min(src.min(), dst.min()))
        hi = int(max(src.max(), dst.max()))
        if lo < 0 or hi >= 1 << 31:
            raise ValueError("vertex ids must be in [0, 2**31)")
        self._max_id = max(self._max_id, hi)
        self._n_raw += len(src)
        if self.orientation == "degree":
            # the orientation key uses *raw* (pre-dedup) degree counts,
            # exactly as orient_edges does
            if hi >= len(self._deg):
                grown = np.zeros(max(hi + 1, 2 * len(self._deg)), np.int64)
                grown[:len(self._deg)] = self._deg
                self._deg = grown
            self._deg[:hi + 1] += np.bincount(src, minlength=hi + 1)
            self._deg[:hi + 1] += np.bincount(dst, minlength=hi + 1)
        keys = (np.minimum(src, dst) << 32) | np.maximum(src, dst)
        pos = 0
        while pos < len(keys):
            take = min(len(keys) - pos, self._cap - self._fill)
            self._buf[self._fill:self._fill + take] = keys[pos:pos + take]
            self._fill += take
            pos += take
            if self._fill == self._cap:
                self._spill()

    def _spill(self) -> None:
        if self._fill == 0:
            return
        view = self._buf[:self._fill]
        view.sort()        # in-place introsort: no O(run) temp (radix
        #                    'stable' would allocate a working buffer)
        mask = np.empty(self._fill, dtype=bool)
        mask[0] = True
        np.not_equal(view[1:], view[:-1], out=mask[1:])
        uniq = view[mask]
        if self._own_tmpdir is None and self._tmpdir is None:
            self._own_tmpdir = tempfile.mkdtemp(
                prefix=".ingest-", dir=os.path.dirname(self.path) or ".")
        rundir = self._tmpdir or self._own_tmpdir
        rp = os.path.join(rundir, f"run{len(self._runs):05d}.i64")
        uniq.tofile(rp)
        self._runs.append(rp)
        self.n_spill_runs += 1
        self._fill = 0

    # -- degree orientation: re-key runs once global degrees are known -------

    def _reorient_runs_by_degree(self) -> None:
        n = self._max_id + 1
        deg = self._deg[:n]
        out_runs = []
        block = max(256, self._cap // 3)
        for rp in self._runs:
            with open(rp, "rb") as f:
                part = 0
                while True:
                    keys = np.fromfile(f, dtype=np.int64, count=block)
                    if len(keys) == 0:
                        break
                    a = keys >> 32
                    b = keys & 0xFFFFFFFF
                    swap = deg[a] * (n + 1) + a > deg[b] * (n + 1) + b
                    keys = np.where(swap, (b << 32) | a, (a << 32) | b)
                    keys.sort()
                    op = rp + f".o{part}"
                    keys.tofile(op)
                    out_runs.append(op)
                    part += 1
            os.unlink(rp)
        self._runs = out_runs

    # -- pass 2: k-way merge -> chunked-CSR stream ---------------------------

    def finalize(self) -> str:
        """Merge the spill runs into the final store file; returns the path."""
        if self._finalized:
            return self.path
        self._spill()
        self._buf = np.empty(0, dtype=np.int64)   # pass 1 done: free it
        if self.orientation == "degree" and self._runs:
            self._reorient_runs_by_degree()
        n_nodes = self._max_id + 1
        n_chunks = max(1, -(-n_nodes // self.chunk_rows))
        self._outdeg = np.zeros(n_nodes, dtype=np.int64)
        self._offsets = np.zeros(n_chunks + 1, dtype=np.int64)
        self._n_chunks = n_chunks
        self._cur_chunk = 0
        self._cur_chunk_words = 0
        self._total_words = 0
        self._n_edges = 0
        idx_off = _HEADER.itemsize + 8 * (n_nodes + 1) + 8 * (n_chunks + 1)
        # write to a sibling temp file and rename on success: a mid-merge
        # failure (disk full, ...) must never leave a half-written store at
        # the destination path masquerading as a valid file
        tmp_path = self.path + ".ingest-tmp"
        with open(tmp_path, "wb") as f:
            f.seek(idx_off)
            self._merge(f)
            self._close_chunks_upto(f, n_chunks)
            hdr = np.zeros((), dtype=_HEADER)
            hdr["magic"] = MAGIC
            hdr["version"] = VERSION
            hdr["orient"] = _ORIENT_FLAGS[self.orientation]
            hdr["n_nodes"] = n_nodes
            hdr["n_edges"] = self._n_edges
            hdr["chunk_rows"] = self.chunk_rows
            hdr["n_chunks"] = n_chunks
            hdr["align_words"] = self.align_words
            hdr["k_max"] = int(self._outdeg.max(initial=0))
            f.seek(0)
            f.write(hdr.tobytes())
            indptr = np.concatenate(
                [np.zeros(1, np.int64),
                 np.cumsum(self._outdeg, dtype=np.int64)])
            f.write(indptr.tobytes())
            f.write(self._offsets.tobytes())
        os.replace(tmp_path, self.path)
        self._cleanup()
        self._finalized = True
        return self.path

    def _merge(self, f) -> None:
        if not self._runs:
            return
        per = max(64, (self._cap // 2) // len(self._runs))
        readers = [_RunReader(rp, per) for rp in self._runs]
        last_key = -1
        try:
            while readers:
                for r in readers:
                    r.fill()
                readers = [r for r in readers
                           if len(r.buf) or not r.eof]
                live = [r for r in readers if len(r.buf)]
                if not live:
                    if not readers:
                        break
                    continue
                pending = [r for r in live if not r.eof]
                frontier = min(int(r.buf[-1]) for r in pending) if pending \
                    else max(int(r.buf[-1]) for r in live)
                parts = []
                for r in live:
                    cnt = int(np.searchsorted(r.buf, frontier, side="right"))
                    if cnt:
                        parts.append(r.buf[:cnt])
                        r.buf = r.buf[cnt:]
                if len(parts) == 1:
                    block = parts[0]         # one run: already sorted
                else:
                    block = np.concatenate(parts)
                    block.sort()             # in place on the concat copy
                mask = np.empty(len(block), dtype=bool)
                mask[0] = int(block[0]) != last_key
                np.not_equal(block[1:], block[:-1], out=mask[1:])
                block = block[mask]
                if len(block):
                    self._emit_sorted(block, f)
                    last_key = int(block[-1])
        finally:
            for r in readers:
                r.close()

    def _emit_sorted(self, keys: np.ndarray, f) -> None:
        """Write one globally-sorted, deduplicated block of oriented edges."""
        a = keys >> 32
        b = (keys & 0xFFFFFFFF).astype(np.int32)
        self._n_edges += len(keys)
        self._outdeg += np.bincount(a, minlength=len(self._outdeg))
        cids = a // self.chunk_rows
        uc, starts = np.unique(cids, return_index=True)
        ends = np.append(starts[1:], len(cids))
        for cid, s, e in zip(uc, starts, ends):
            if cid != self._cur_chunk:
                self._close_chunks_upto(f, int(cid))
            f.write(b[s:e].tobytes())
            self._cur_chunk_words += int(e - s)
            self._total_words += int(e - s)

    def _close_chunks_upto(self, f, upto: int) -> None:
        """Pad the open chunk to ``align_words`` and record the chunk
        directory start offsets for every chunk in (cur, upto]."""
        pad = (-self._cur_chunk_words) % self.align_words
        if pad:
            f.write(np.zeros(pad, np.int32).tobytes())
            self._total_words += pad
        self._offsets[self._cur_chunk + 1:upto + 1] = self._total_words
        self._cur_chunk = upto
        self._cur_chunk_words = 0

    # -- cleanup -------------------------------------------------------------

    def _cleanup(self) -> None:
        try:
            os.unlink(self.path + ".ingest-tmp")
        except OSError:
            pass
        for rp in self._runs:
            try:
                os.unlink(rp)
            except OSError:
                pass
        self._runs = []
        if self._own_tmpdir is not None:
            try:
                os.rmdir(self._own_tmpdir)
            except OSError:
                pass
            self._own_tmpdir = None

    def __enter__(self) -> "EdgeStoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            try:
                self.finalize()
            except BaseException:
                self._cleanup()      # a failed merge must not leave the
                raise                # temp store or spill runs behind
        else:
            self._cleanup()


def write_edge_store_streaming(path, batches: Iterable, *,
                               orientation: str = "minmax",
                               chunk_rows: int = 4096,
                               align_words: int = 1024,
                               budget_words: int = 1 << 22) -> str:
    """Build an edge store from an iterable of (src, dst) batches with
    bounded memory; byte-identical to ``write_edge_store`` on the same
    edges. See ``EdgeStoreWriter`` for the budget semantics."""
    w = EdgeStoreWriter(path, orientation=orientation, chunk_rows=chunk_rows,
                        align_words=align_words, budget_words=budget_words)
    with w:
        for src, dst in batches:
            w.add_edges(src, dst)
    return w.path


# ---------------------------------------------------------------------------
# readers (EdgeSource implementations)
# ---------------------------------------------------------------------------

class EdgeStore:
    """Memory-mapped chunked-CSR reader, charging reads to a BlockDevice.

    ``read_rows(lo, hi)`` returns ``(indptr_local, values)`` for vertex rows
    ``lo..hi`` inclusive, where ``indptr_local`` is 0-based over the
    returned ``values`` — the provisioning DMA of a contiguous x- or
    y-slice. Chunk padding never reaches the caller.

    Safe for concurrent ``read_rows`` calls from multiple threads (the
    async box scheduler's slice builders): the reader holds no mutable
    per-read state — ``indptr``, the chunk directory and the read-only
    mmap are only ever read, the returned arrays are fresh copies that
    never alias another call's result, and device charging serializes on
    the ``BlockDevice``'s internal lock.
    """

    def __init__(self, path, device=None):
        self.path = os.fspath(path)
        raw = np.fromfile(self.path, dtype=_HEADER, count=1)
        if len(raw) == 0:
            raise ValueError(
                f"{self.path}: truncated header "
                f"(< {_HEADER.itemsize} bytes) — not an edge store")
        hdr = raw[0]
        # fail loudly on format mismatch: misreading a wrong-version file
        # would silently corrupt every downstream triangle count
        if bytes(hdr["magic"]) != MAGIC:
            raise ValueError(f"{self.path}: not an edge store "
                             f"(bad magic {bytes(hdr['magic'])!r}, "
                             f"expected {MAGIC!r})")
        if int(hdr["version"]) != VERSION:
            raise ValueError(
                f"{self.path}: unsupported edge store format version "
                f"{int(hdr['version'])} (this reader supports {VERSION}); "
                "refusing to misread — rewrite the store with this "
                "library's writer")
        self.n_nodes = int(hdr["n_nodes"])
        self.n_edges = int(hdr["n_edges"])
        self.chunk_rows = int(hdr["chunk_rows"])
        self.n_chunks = int(hdr["n_chunks"])
        self.align_words = int(hdr["align_words"])
        self.k_max = int(hdr["k_max"])
        self.orientation = _FLAG_ORIENTS.get(int(hdr["orient"]), "raw")
        if (self.n_nodes < 0 or self.n_edges < 0 or self.chunk_rows < 1
                or self.align_words < 1
                or self.n_chunks != max(1, -(-self.n_nodes
                                             // self.chunk_rows))):
            raise ValueError(f"{self.path}: corrupt header "
                             f"(n_nodes={self.n_nodes}, "
                             f"n_edges={self.n_edges}, "
                             f"chunk_rows={self.chunk_rows}, "
                             f"n_chunks={self.n_chunks})")

        off = _HEADER.itemsize
        # indptr is the resident index structure: V+1 words, read once
        self.indptr = np.fromfile(self.path, dtype=np.int64,
                                  count=self.n_nodes + 1, offset=off)
        off += 8 * (self.n_nodes + 1)
        self._chunk_off = np.fromfile(self.path, dtype=np.int64,
                                      count=self.n_chunks + 1, offset=off)
        if (len(self.indptr) != self.n_nodes + 1
                or len(self._chunk_off) != self.n_chunks + 1):
            raise ValueError(f"{self.path}: truncated index region")
        off += 8 * (self.n_chunks + 1)
        total_words = int(self._chunk_off[-1])
        if os.path.getsize(self.path) < off + 4 * total_words:
            raise ValueError(
                f"{self.path}: truncated indices region (directory claims "
                f"{total_words} words past byte {off})")
        # an edgeless graph has no indices region at all — mmap of length
        # max(1, 0) would point past EOF and raise
        self._idx = np.memmap(self.path, dtype=np.int32, mode="r",
                              offset=off, shape=(total_words,)) \
            if total_words else np.zeros(0, np.int32)
        self.device = None
        # optional obs.trace.Tracer: every read_rows emits an io.read_rows
        # instant event (rows + words) when attached; None = no overhead
        self.tracer = None
        if device is not None:
            self.attach_device(device)

    # -- device accounting ---------------------------------------------------

    def attach_device(self, device) -> None:
        """Register the on-disk indices region with a virtual block device."""
        self.device = device
        if device is not None and len(self._idx):
            device.register(self._idx)

    # -- EdgeSource interface ------------------------------------------------

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def words(self) -> int:
        """Storage words of the neighbor stream (the paper's |R| unit)."""
        return self.n_edges

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        """Neighbor data of vertex rows ``lo..hi`` inclusive (one DMA)."""
        lo = max(0, int(lo))
        hi = min(self.n_nodes - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        parts = []
        c0, c1 = lo // self.chunk_rows, hi // self.chunk_rows
        for c in range(c0, c1 + 1):
            r0 = max(lo, c * self.chunk_rows)
            r1 = min(hi, (c + 1) * self.chunk_rows - 1)
            base = int(self._chunk_off[c]) \
                - int(self.indptr[c * self.chunk_rows])
            s = base + int(self.indptr[r0])
            e = base + int(self.indptr[r1 + 1])
            if e > s:
                if self.device is not None:
                    self.device.read_range(self._idx, s, e)
                parts.append(np.asarray(self._idx[s:e]))
        # concatenate copies out of the mmap even for a single part, so the
        # caller's slice never aliases the file mapping (concurrent readers
        # each get private buffers)
        vals = np.concatenate(parts) if parts \
            else np.zeros(0, np.int32)
        indptr_local = self.indptr[lo:hi + 2] - self.indptr[lo]
        tr = self.tracer
        if tr is not None:
            tr.event("io.read_rows", lo=lo, hi=hi, words=len(vals))
        return indptr_local, vals


class InMemoryEdgeSource:
    """Host (indptr, indices) arrays behind the EdgeSource interface.

    With a ``device`` attached the same block-I/O accounting applies as for
    the on-disk store (useful for modeling runs); without one, reads are
    free — pure in-memory execution.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 device=None, orientation: str = "minmax",
                 tracer=None):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)
        self.n_nodes = len(self.indptr) - 1
        self.n_edges = len(self.indices)
        self.orientation = orientation
        self.device = device
        self.tracer = tracer
        if device is not None and self.n_edges:
            device.register(self.indices)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def words(self) -> int:
        return self.n_edges

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        lo = max(0, int(lo))
        hi = min(self.n_nodes - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        s, e = int(self.indptr[lo]), int(self.indptr[hi + 1])
        if self.device is not None and e > s:
            self.device.read_range(self.indices, s, e)
        tr = self.tracer
        if tr is not None:
            tr.event("io.read_rows", lo=lo, hi=hi, words=e - s)
        return self.indptr[lo:hi + 2] - self.indptr[lo], self.indices[s:e]
