"""Spillable edge store: memory-mapped chunked-CSR on disk (writer + reader).

The paper's out-of-core setting (§1 "Model & Assumptions") keeps the edge
relation on secondary storage and charges block I/Os for every word pulled
into the memory budget M. This module is that storage layer for the
streaming triangle engine:

  * ``write_edge_store`` lays the oriented CSR graph out as *chunked CSR*:
    the ``indices`` stream is split into fixed row-count chunks, each
    aligned to a block boundary, with a chunk directory mapping chunk id to
    its word offset. A reader can therefore fetch any vertex row range by
    touching only the chunks that overlap it — the paper's contiguous slice
    provisioning (Def. 6) as literal file reads.
  * ``EdgeStore`` memory-maps the file and serves ``read_rows`` range reads.
    Every read is charged to a ``core.iomodel.BlockDevice`` when one is
    attached, so ``EngineStats`` reports *measured* block I/Os that
    benchmarks compare against the Thm. 10 prediction.
  * ``InMemoryEdgeSource`` wraps host (indptr, indices) arrays behind the
    same interface, so the streaming executor is agnostic to where the
    graph lives.

Only the (V+1)-word ``indptr`` prefix array is kept resident (the paper's
planner likewise assumes the index structure of E is probe-able); the
neighbor stream itself is paged in per box.

File layout (little-endian)::

    [0:64)       header: magic 'RPRCSR01', version, orientation flag,
                 n_nodes, n_edges, chunk_rows, n_chunks, align_words, k_max
    [64:...)     indptr   int64[n_nodes + 1]
    [...]        chunk directory int64[n_chunks + 1]  (word offsets)
    [...]        indices  int32, per chunk, padded to align_words
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

MAGIC = b"RPRCSR01"
VERSION = 1

_ORIENT_FLAGS = {"minmax": 0, "degree": 1, "raw": 2}
_FLAG_ORIENTS = {v: k for k, v in _ORIENT_FLAGS.items()}

_HEADER = np.dtype([
    ("magic", "S8"), ("version", "<i4"), ("orient", "<i4"),
    ("n_nodes", "<i8"), ("n_edges", "<i8"), ("chunk_rows", "<i8"),
    ("n_chunks", "<i8"), ("align_words", "<i8"), ("k_max", "<i8"),
])
assert _HEADER.itemsize == 64


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def write_edge_store_csr(path, indptr: np.ndarray, indices: np.ndarray, *,
                         orientation: str = "raw", chunk_rows: int = 4096,
                         align_words: int = 1024) -> str:
    """Write a (sorted-row) CSR graph as a chunked-CSR edge store file."""
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int32)
    n_nodes = len(indptr) - 1
    n_edges = len(indices)
    chunk_rows = max(1, int(chunk_rows))
    align_words = max(1, int(align_words))
    n_chunks = max(1, -(-n_nodes // chunk_rows))
    deg = np.diff(indptr)

    offsets = np.zeros(n_chunks + 1, dtype=np.int64)
    chunks = []
    off = 0
    for c in range(n_chunks):
        r0, r1 = c * chunk_rows, min(n_nodes, (c + 1) * chunk_rows)
        data = indices[indptr[r0]:indptr[r1]]
        pad = (-len(data)) % align_words
        if pad:
            data = np.concatenate([data, np.zeros(pad, np.int32)])
        offsets[c] = off
        off += len(data)
        chunks.append(data)
    offsets[n_chunks] = off

    hdr = np.zeros((), dtype=_HEADER)
    hdr["magic"] = MAGIC
    hdr["version"] = VERSION
    hdr["orient"] = _ORIENT_FLAGS.get(orientation, _ORIENT_FLAGS["raw"])
    hdr["n_nodes"] = n_nodes
    hdr["n_edges"] = n_edges
    hdr["chunk_rows"] = chunk_rows
    hdr["n_chunks"] = n_chunks
    hdr["align_words"] = align_words
    hdr["k_max"] = int(deg.max(initial=0))

    path = os.fspath(path)
    with open(path, "wb") as f:
        f.write(hdr.tobytes())
        f.write(indptr.tobytes())
        f.write(offsets.tobytes())
        for data in chunks:
            f.write(data.tobytes())
    return path


def write_edge_store(path, src: np.ndarray, dst: np.ndarray, *,
                     orientation: str = "minmax", chunk_rows: int = 4096,
                     align_words: int = 1024) -> str:
    """Orient an undirected edge list and write it as an edge store.

    The stored graph is the oriented DAG G* (paper §2.3), which is what the
    triangle engine consumes; ``orientation`` is recorded in the header so
    the engine can recover sound pruning rules when it opens the file.
    """
    from repro.core.lftj_jax import csr_from_edges, orient_edges

    a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
    nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
    if nv:
        indptr, indices = csr_from_edges(a, b, n_nodes=nv)
    else:
        indptr, indices = np.zeros(1, np.int64), np.zeros(0, np.int32)
    return write_edge_store_csr(path, indptr, indices,
                                orientation=orientation,
                                chunk_rows=chunk_rows,
                                align_words=align_words)


# ---------------------------------------------------------------------------
# readers (EdgeSource implementations)
# ---------------------------------------------------------------------------

class EdgeStore:
    """Memory-mapped chunked-CSR reader, charging reads to a BlockDevice.

    ``read_rows(lo, hi)`` returns ``(indptr_local, values)`` for vertex rows
    ``lo..hi`` inclusive, where ``indptr_local`` is 0-based over the
    returned ``values`` — the provisioning DMA of a contiguous x- or
    y-slice. Chunk padding never reaches the caller.
    """

    def __init__(self, path, device=None):
        self.path = os.fspath(path)
        hdr = np.fromfile(self.path, dtype=_HEADER, count=1)[0]
        if bytes(hdr["magic"]) != MAGIC:
            raise ValueError(f"{self.path}: not an edge store (bad magic)")
        if int(hdr["version"]) != VERSION:
            raise ValueError(f"{self.path}: unsupported version {hdr['version']}")
        self.n_nodes = int(hdr["n_nodes"])
        self.n_edges = int(hdr["n_edges"])
        self.chunk_rows = int(hdr["chunk_rows"])
        self.n_chunks = int(hdr["n_chunks"])
        self.align_words = int(hdr["align_words"])
        self.k_max = int(hdr["k_max"])
        self.orientation = _FLAG_ORIENTS.get(int(hdr["orient"]), "raw")

        off = _HEADER.itemsize
        # indptr is the resident index structure: V+1 words, read once
        self.indptr = np.fromfile(self.path, dtype=np.int64,
                                  count=self.n_nodes + 1, offset=off)
        off += 8 * (self.n_nodes + 1)
        self._chunk_off = np.fromfile(self.path, dtype=np.int64,
                                      count=self.n_chunks + 1, offset=off)
        off += 8 * (self.n_chunks + 1)
        total_words = int(self._chunk_off[-1])
        # an edgeless graph has no indices region at all — mmap of length
        # max(1, 0) would point past EOF and raise
        self._idx = np.memmap(self.path, dtype=np.int32, mode="r",
                              offset=off, shape=(total_words,)) \
            if total_words else np.zeros(0, np.int32)
        self.device = None
        if device is not None:
            self.attach_device(device)

    # -- device accounting ---------------------------------------------------

    def attach_device(self, device) -> None:
        """Register the on-disk indices region with a virtual block device."""
        self.device = device
        if device is not None and len(self._idx):
            device.register(self._idx)

    # -- EdgeSource interface ------------------------------------------------

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def words(self) -> int:
        """Storage words of the neighbor stream (the paper's |R| unit)."""
        return self.n_edges

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        """Neighbor data of vertex rows ``lo..hi`` inclusive (one DMA)."""
        lo = max(0, int(lo))
        hi = min(self.n_nodes - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        parts = []
        c0, c1 = lo // self.chunk_rows, hi // self.chunk_rows
        for c in range(c0, c1 + 1):
            r0 = max(lo, c * self.chunk_rows)
            r1 = min(hi, (c + 1) * self.chunk_rows - 1)
            base = int(self._chunk_off[c]) \
                - int(self.indptr[c * self.chunk_rows])
            s = base + int(self.indptr[r0])
            e = base + int(self.indptr[r1 + 1])
            if e > s:
                if self.device is not None:
                    self.device.read_range(self._idx, s, e)
                parts.append(np.asarray(self._idx[s:e]))
        vals = np.concatenate(parts) if parts \
            else np.zeros(0, np.int32)
        indptr_local = self.indptr[lo:hi + 2] - self.indptr[lo]
        return indptr_local, vals


class InMemoryEdgeSource:
    """Host (indptr, indices) arrays behind the EdgeSource interface.

    With a ``device`` attached the same block-I/O accounting applies as for
    the on-disk store (useful for modeling runs); without one, reads are
    free — pure in-memory execution.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 device=None, orientation: str = "minmax"):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)
        self.n_nodes = len(self.indptr) - 1
        self.n_edges = len(self.indices)
        self.orientation = orientation
        self.device = device
        if device is not None and self.n_edges:
            device.register(self.indices)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def words(self) -> int:
        return self.n_edges

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        lo = max(0, int(lo))
        hi = min(self.n_nodes - 1, int(hi))
        if hi < lo:
            return np.zeros(1, np.int64), np.zeros(0, np.int32)
        s, e = int(self.indptr[lo]), int(self.indptr[hi + 1])
        if self.device is not None and e > s:
            self.device.read_range(self.indices, s, e)
        return self.indptr[lo:hi + 2] - self.indptr[lo], self.indices[s:e]
