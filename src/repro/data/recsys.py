"""Criteo-like synthetic generator for DLRM (train + serve batches)."""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class CriteoLikeGenerator:
    """Power-law categorical draws + dense log-normal features with a
    planted linear CTR signal (training examples show decreasing BCE)."""

    def __init__(self, table_sizes: Sequence[int], n_dense: int = 13,
                 hot: int = 1, seed: int = 0):
        self.table_sizes = tuple(table_sizes)
        self.n_dense = n_dense
        self.hot = hot
        self.rng = np.random.default_rng(seed)
        self.w_dense = self.rng.standard_normal(n_dense) * 0.4
        self.hot_bias = [self.rng.standard_normal(min(1000, v)) * 0.3
                         for v in self.table_sizes]

    def _zipf_draw(self, v: int, size) -> np.ndarray:
        u = self.rng.random(size)
        # truncated zipf via inverse-CDF approximation
        x = np.floor((v ** u - 1)).astype(np.int64)
        return np.clip(x, 0, v - 1)

    def batch(self, batch_size: int, with_labels: bool = True
              ) -> Dict[str, np.ndarray]:
        dense = self.rng.lognormal(0.0, 1.0,
                                   (batch_size, self.n_dense)).astype(np.float32)
        dense = np.log1p(dense)
        sparse = np.stack(
            [self._zipf_draw(v, (batch_size, self.hot))
             for v in self.table_sizes], axis=1).astype(np.int32)
        out = {"dense": dense, "sparse": sparse}
        if with_labels:
            logit = dense @ self.w_dense
            for t, bias in enumerate(self.hot_bias):
                logit += bias[np.minimum(sparse[:, t, 0], len(bias) - 1)]
            p = 1.0 / (1.0 + np.exp(-(logit - logit.mean())))
            out["labels"] = (self.rng.random(batch_size) < p).astype(np.float32)
        return out
