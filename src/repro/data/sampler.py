"""Fanout neighbor sampler for minibatch GNN training (minibatch_lg shape).

GraphSAGE-style layered sampling over CSR: per seed, sample up to
fanout[0] 1-hop neighbors, then fanout[1] per 1-hop node, etc. Output is
the padded fixed-shape block that configs.base.gnn_input_specs describes —
static shapes for jit, masks for validity.

The CSR here is the same TrieArray val/idx layout the triangle engine uses
(DESIGN.md: shared substrate).
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 fanout: Sequence[int] = (15, 10), seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.fanout = tuple(fanout)
        self.rng = np.random.default_rng(seed)
        self.n_nodes = len(indptr) - 1

    def sample_block(self, seeds: np.ndarray) -> Tuple[np.ndarray, ...]:
        """Returns (nodes, src, dst): local subgraph with original node ids;
        edges point sampled-neighbor -> parent (message direction)."""
        frontier = np.asarray(seeds, dtype=np.int64)
        nodes = [frontier]
        srcs, dsts = [], []
        for f in self.fanout:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # vectorized per-node sampling: draw f slots, mask short rows
            draw = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                     size=(len(frontier), f))
            valid = draw < deg[:, None]
            flat_parent = np.repeat(frontier, f)[valid.ravel()]
            offs = (self.indptr[frontier][:, None] + draw)[valid]
            nbrs = self.indices[offs]
            srcs.append(nbrs)
            dsts.append(flat_parent)
            frontier = np.unique(nbrs)
            nodes.append(frontier)
        all_nodes = np.unique(np.concatenate(nodes))
        return all_nodes, np.concatenate(srcs), np.concatenate(dsts)

    def padded_batch(self, seeds: np.ndarray, feats: np.ndarray,
                     labels: np.ndarray, blk_nodes: int, blk_edges: int
                     ) -> Dict[str, np.ndarray]:
        nodes, src, dst = self.sample_block(seeds)
        nodes = nodes[:blk_nodes]
        remap = -np.ones(self.n_nodes, dtype=np.int64)
        remap[nodes] = np.arange(len(nodes))
        ls, ld = remap[src], remap[dst]
        ok = (ls >= 0) & (ld >= 0)
        ls, ld = ls[ok][:blk_edges], ld[ok][:blk_edges]
        d_feat = feats.shape[1]
        batch = {
            "node_feat": np.zeros((blk_nodes, d_feat), np.float32),
            "edge_src": np.zeros((blk_edges,), np.int32),
            "edge_dst": np.zeros((blk_edges,), np.int32),
            "edge_mask": np.zeros((blk_edges,), np.float32),
            "node_mask": np.zeros((blk_nodes,), np.float32),
            "labels": np.zeros((blk_nodes,), np.int32),
            "label_mask": np.zeros((blk_nodes,), np.float32),
        }
        batch["node_feat"][:len(nodes)] = feats[nodes]
        batch["node_mask"][:len(nodes)] = 1.0
        batch["edge_src"][:len(ls)] = ls
        batch["edge_dst"][:len(ld)] = ld
        batch["edge_mask"][:len(ls)] = 1.0
        batch["labels"][:len(nodes)] = labels[nodes]
        # supervise seeds only (standard sampled-training semantics)
        seed_local = remap[np.asarray(seeds)]
        seed_local = seed_local[seed_local >= 0]
        batch["label_mask"][seed_local] = 1.0
        return batch
