"""Synthetic LM token pipeline: deterministic, shardable, packed sequences.

A Zipfian unigram stream with injected bigram structure — enough signal
that the end-to-end training example shows a falling loss, while remaining
fully reproducible offline (no datasets ship with the container).
"""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class TokenStream:
    """Deterministic pseudo-corpus: Zipf unigrams + Markov bigram signal."""

    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        # sparse deterministic bigram table: each token prefers a successor
        self.succ = (np.arange(vocab) * 31 + 17) % vocab

    def batch(self, batch_size: int, seq_len: int) -> Dict[str, np.ndarray]:
        draws = self.rng.choice(self.vocab, size=(batch_size, seq_len + 1),
                                p=self.p)
        # 50% of positions follow the bigram successor of the previous token
        follow = self.rng.random((batch_size, seq_len)) < 0.5
        toks = draws.copy()
        for t in range(1, seq_len + 1):
            toks[:, t] = np.where(follow[:, t - 1],
                                  self.succ[toks[:, t - 1]], draws[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def batches(self, batch_size: int, seq_len: int, n: int
                ) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(n):
            yield self.batch(batch_size, seq_len)
