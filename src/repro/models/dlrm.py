"""DLRM (MLPerf config): sparse embedding tables + dot interaction + MLPs.

The embedding lookup is the hot path; JAX has no EmbeddingBag, so lookups
are `jnp.take` + segment-sum (kernels/embedding_bag provides the Pallas
version). Tables are vocab-sharded over the `model` mesh axis: a bag-sum
over a row-sharded table is a *local masked bag-sum followed by a psum* —
the sum over bag slots commutes with the shard sum, so no all-to-all of
rows is needed (DESIGN.md §5; the a2a variant is a §Perf alternative).

The paper connection (DESIGN.md §4): probing/provisioning drives the
budgeted prefetch of table shards in the out-of-core serving path; the
lookup itself is the join  Bags(b, slot, id) ⋈ Table(id, vec).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from repro.parallel.sharding import constrain as _constrain
from .layers import abstractify, materialize

FDTYPE = jnp.float32

# Criteo-1TB per-field vocabulary sizes (MLPerf DLRM benchmark config).
CRITEO_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36)


@dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    embed_dim: int = 128
    table_sizes: Tuple[int, ...] = CRITEO_TABLE_SIZES
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    hot: int = 1                      # multi-hot size per field
    sparse_optimizer: bool = False    # row-sparse table updates (§Perf)
    shard_moments_2d: bool = False    # ZeRO-style (model, dp) moment shard

    @property
    def n_sparse(self) -> int:
        return len(self.table_sizes)

    def params_count(self) -> int:
        n = sum(self.table_sizes) * self.embed_dim
        dims = [self.n_dense] + list(self.bot_mlp)
        n += sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        n_int = self.n_sparse + 1
        d_top = self.embed_dim + n_int * (n_int - 1) // 2
        dims = [d_top] + list(self.top_mlp)
        n += sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        return n


def param_shapes(cfg: DLRMConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    for t, v in enumerate(cfg.table_sizes):
        s[f"table{t}"] = ((v, cfg.embed_dim), L.PDTYPE)
    dims = [cfg.n_dense] + list(cfg.bot_mlp)
    for i in range(len(dims) - 1):
        s[f"bot_w{i}"] = ((dims[i], dims[i + 1]), FDTYPE)
        s[f"bot_b{i}"] = ((dims[i + 1],), FDTYPE)
    n_int = cfg.n_sparse + 1
    d_top = cfg.embed_dim + n_int * (n_int - 1) // 2
    dims = [d_top] + list(cfg.top_mlp)
    for i in range(len(dims) - 1):
        s[f"top_w{i}"] = ((dims[i], dims[i + 1]), FDTYPE)
        s[f"top_b{i}"] = ((dims[i + 1],), FDTYPE)
    return s


def init_params(cfg: DLRMConfig, key):
    return materialize(param_shapes(cfg), key)


def param_specs(cfg: DLRMConfig):
    return abstractify(param_shapes(cfg))


def _mlp(params, x, prefix, n, sigmoid_last=False):
    for i in range(n):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
        elif sigmoid_last:
            pass  # logits returned raw; BCE applies sigmoid
    return x


def forward(cfg: DLRMConfig, params, batch: Dict[str, jnp.ndarray]):
    """batch: dense (B, 13) f32, sparse (B, 26, hot) int32 -> logits (B,)."""
    dense = batch["dense"].astype(FDTYPE)
    sparse = batch["sparse"]
    b = dense.shape[0]
    x_dense = _mlp(params, dense, "bot", len(cfg.bot_mlp))       # (B, D)

    embs = []
    for t in range(cfg.n_sparse):
        tab = params[f"table{t}"]
        idx = sparse[:, t, :]                                    # (B, hot)
        vec = jnp.take(tab, jnp.minimum(idx, tab.shape[0] - 1), axis=0)
        vec = jnp.sum(vec.astype(FDTYPE), axis=1)                # bag sum
        embs.append(vec)
    z = jnp.stack([x_dense] + embs, axis=1)                      # (B, 27, D)

    # dot interaction: lower-triangular pairwise dots
    zz = jnp.einsum("bnd,bmd->bnm", z, z,
                    preferred_element_type=jnp.float32)          # (B, 27, 27)
    n_int = cfg.n_sparse + 1
    iu, ju = np.tril_indices(n_int, k=-1)
    pairs = zz[:, iu, ju]                                        # (B, 351)
    top_in = jnp.concatenate([x_dense, pairs], axis=-1)
    logits = _mlp(params, top_in, "top", len(cfg.top_mlp))[:, 0]
    return logits


def loss_fn(cfg: DLRMConfig, params, batch):
    logits = forward(cfg, params, batch)
    y = batch["labels"].astype(FDTYPE)
    # numerically-stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"bce": loss}


def serve_step(cfg: DLRMConfig, params, batch):
    """Online/offline scoring: forward only, sigmoid CTR."""
    return jax.nn.sigmoid(forward(cfg, params, batch))


def retrieval_score(cfg: DLRMConfig, params, batch):
    """retrieval_cand shape: one query against n_candidates item vectors.

    query: dense (1, 13) + sparse (1, 26, hot) -> user vector via the bottom
    tower; candidates (C, D) scored by batched dot (no loop), top-k returned.
    """
    dense = batch["dense"].astype(FDTYPE)
    x_user = _mlp(params, dense, "bot", len(cfg.bot_mlp))        # (1, D)
    sparse = batch["sparse"]
    for t in range(cfg.n_sparse):
        tab = params[f"table{t}"]
        idx = sparse[:, t, :]
        x_user = x_user + jnp.sum(
            jnp.take(tab, jnp.minimum(idx, tab.shape[0] - 1), axis=0)
            .astype(FDTYPE), axis=1)
    cand = batch["candidates"].astype(FDTYPE)                    # (C, D)
    scores = jnp.einsum("qd,cd->qc", x_user, cand,
                        preferred_element_type=jnp.float32)      # (1, C)
    k = min(100, cand.shape[0])
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i


# ---------------------------------------------------------------------------
# §Perf hillclimb: row-sparse embedding training
# ---------------------------------------------------------------------------

def make_sparse_train_step(cfg: DLRMConfig, opt_cfg):
    """Train step whose table updates touch only the rows in the batch.

    The dense AdamW step reads+writes every row of every table plus both
    f32 moments (~1.5 TB of HBM traffic per step for the 24B-param MLPerf
    tables) even though a 65k batch references at most B·hot rows/table.
    This step:

      1. gathers the unique rows per table (jnp.unique, static size B·hot)
         — the paper's *slice provisioning* applied to optimizer state:
         only the referenced slice moves through fast memory;
      2. differentiates w.r.t. the gathered rows (the tables themselves
         never enter the autodiff graph);
      3. applies AdamW row-wise and scatters params/moments back with
         .at[].add deltas (duplicate-pad-safe).

    Lazy-Adam semantics: untouched rows' moments do not decay that step
    (the standard embedding-optimizer trade; recorded in EXPERIMENTS.md).
    """
    from repro.optim import adamw

    tables = [f"table{t}" for t in range(cfg.n_sparse)]

    def step(params, opt_state, batch):
        sparse = batch["sparse"]                      # (B, T, hot)
        b = sparse.shape[0]
        cap_u = b * cfg.hot

        dense_params = {k: v for k, v in params.items() if k not in tables}
        uniqs, invs, rows0 = {}, {}, {}
        for t, name in enumerate(tables):
            vsz = cfg.table_sizes[t]
            idx = sparse[:, t, :].reshape(-1)
            uniq = jnp.unique(idx, size=cap_u, fill_value=vsz)
            inv = jnp.searchsorted(uniq, idx)
            safe = jnp.minimum(uniq, vsz - 1)
            uniqs[name], invs[name] = uniq, inv
            rows0[name] = jnp.take(params[name], safe, axis=0)

        def loss_from(dp, rows):
            dense = batch["dense"].astype(FDTYPE)
            x_dense = _mlp(dp, dense, "bot", len(cfg.bot_mlp))
            embs = []
            for t, name in enumerate(tables):
                vec = rows[name][invs[name]].reshape(b, cfg.hot, cfg.embed_dim)
                embs.append(jnp.sum(vec.astype(FDTYPE), axis=1))
            z = jnp.stack([x_dense] + embs, axis=1)
            zz = jnp.einsum("bnd,bmd->bnm", z, z,
                            preferred_element_type=jnp.float32)
            n_int = cfg.n_sparse + 1
            iu, ju = np.tril_indices(n_int, k=-1)
            top_in = jnp.concatenate([x_dense, zz[:, iu, ju]], axis=-1)
            logits = _mlp(dp, top_in, "top", len(cfg.top_mlp))[:, 0]
            y = batch["labels"].astype(FDTYPE)
            return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                            jnp.log1p(jnp.exp(-jnp.abs(logits))))

        loss, (g_dense, g_rows) = jax.value_and_grad(
            loss_from, argnums=(0, 1))(dense_params, rows0)

        # dense side: plain AdamW over the small MLP subtree
        sub_m = {k: opt_state.m[k] for k in dense_params}
        sub_v = {k: opt_state.v[k] for k in dense_params}
        sub_state = adamw.OptState(opt_state.step, sub_m, sub_v)
        new_dense, sub_state2, om = adamw.apply(opt_cfg, dense_params,
                                                g_dense, sub_state)
        step_c = sub_state2.step
        new_params = dict(params)
        new_params.update(new_dense)
        new_m = dict(opt_state.m)
        new_m.update(sub_state2.m)
        new_v = dict(opt_state.v)
        new_v.update(sub_state2.v)

        # table side: row-wise lazy AdamW (delta scatters; pads add 0)
        b1, b2, eps = opt_cfg.beta1, opt_cfg.beta2, opt_cfg.eps
        lr = adamw.schedule(opt_cfg, step_c)
        bc1 = 1 - b1 ** step_c.astype(jnp.float32)
        bc2 = 1 - b2 ** step_c.astype(jnp.float32)
        for t, name in enumerate(tables):
            vsz = cfg.table_sizes[t]
            uniq = uniqs[name]
            safe = _constrain(jnp.minimum(uniq, vsz - 1), "dlrm_rows")
            live = (uniq < vsz).astype(jnp.float32)[:, None]
            g = _constrain(g_rows[name].astype(jnp.float32) * live,
                           "dlrm_rows")
            m_rows = jnp.take(opt_state.m[name], safe, axis=0)
            v_rows = jnp.take(opt_state.v[name], safe, axis=0)
            m2 = b1 * m_rows + (1 - b1) * g
            v2 = b2 * v_rows + (1 - b2) * g * g
            delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            new_params[name] = params[name].at[safe].add(
                (-lr * delta * live).astype(params[name].dtype))
            new_m[name] = opt_state.m[name].at[safe].add((m2 - m_rows) * live)
            new_v[name] = opt_state.v[name].at[safe].add((v2 - v_rows) * live)

        new_state = adamw.OptState(step_c, new_m, new_v)
        return new_params, new_state, {"loss": loss, **om}

    return step
