"""Mixture-of-Experts FFN (top-k routing, shared experts, EP-ready).

Dispatch is the dense/einsum ("capacity-free") formulation: per-token expert
weights form a (tokens, E) matrix contracted against expert-stacked weights.
This is deterministic, drop-free, and shards cleanly with experts on the
``model`` mesh axis (the contraction over E becomes a local slice + psum —
XLA inserts the reduce-scatter/all-gather pair). The all-to-all token-
shuffle variant is a §Perf hillclimb alternative discussed in EXPERIMENTS.md.

Routing: softmax-then-topk with renormalization (DeepSeek-V2 style); an
auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from repro.parallel.sharding import constrain as _constrain
from .layers import swiglu


def moe_shapes(d_model: int, d_ff: int, n_experts: int,
               n_shared: int) -> Dict[str, Any]:
    s = {
        "router": ((d_model, n_experts), L.NDTYPE),
        "wi": ((n_experts, d_model, 2 * d_ff), L.PDTYPE),
        "wo": ((n_experts, d_ff, d_model), L.PDTYPE),
    }
    if n_shared:
        s["shared_wi"] = ((d_model, 2 * d_ff * n_shared), L.PDTYPE)
        s["shared_wo"] = ((d_ff * n_shared, d_model), L.PDTYPE)
    return s


def moe_ffn(p: Dict[str, jnp.ndarray], x: jnp.ndarray, top_k: int
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_w, top_i = jax.lax.top_k(probs, top_k)                  # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    n_e = probs.shape[-1]
    # dense combine weights: (T, E), zero outside the top-k
    combine = jnp.zeros_like(probs)
    combine = jax.vmap(lambda c, i, w: c.at[i].add(w))(combine, top_i, top_w)

    # einsum dispatch: every expert sees every token, weighted; contraction
    # over E shards with experts on the model axis.
    h = jnp.einsum("td,edf->tef", xt, p["wi"],
                   preferred_element_type=jnp.float32)          # (T, E, 2F)
    gate, up = jnp.split(h, 2, axis=-1)
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    eo = jnp.einsum("tef,efd->ted", act, p["wo"],
                    preferred_element_type=jnp.float32)         # (T, E, D)
    out = jnp.einsum("ted,te->td", eo, combine).astype(x.dtype)

    if "shared_wi" in p:
        out = out + swiglu(xt, p["shared_wi"], p["shared_wo"])

    # Switch-style load-balance aux: E * Σ_e f_e · P_e
    f = jnp.mean(combine > 0, axis=0)          # fraction routed per expert
    pbar = jnp.mean(probs, axis=0)
    aux = n_e * jnp.sum(f * pbar)
    return out.reshape(b, s, d), aux.astype(jnp.float32)


def moe_ffn_gathered(p: Dict[str, jnp.ndarray], x: jnp.ndarray, top_k: int,
                     capacity_factor: float = 1.25
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped capacity dispatch (the production variant).

    Tokens are routed *within each batch row* (group): capacity
    C = cf·S·k/E per row, positions via a per-row cumsum — embarrassingly
    parallel over the (data-sharded) batch axis, with experts on the model
    axis. Expert flops are O(B·S·k·cf·D·F) — top-k-scaled, never O(T·E·F)
    like the dense einsum form. Overflow tokens are dropped (standard
    capacity semantics; cf controls the drop rate)."""
    b, s, d = x.shape
    n_e = p["router"].shape[-1]
    cap = max(1, int(capacity_factor * s * top_k / n_e))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, S, E)
    top_w, top_i = jax.lax.top_k(probs, top_k)                  # (B, S, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    flat_e = top_i.reshape(b, s * top_k)                        # (B, S·k)
    flat_w = top_w.reshape(b, s * top_k)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(s), top_k)[None], (b, 1))
    onehot = jax.nn.one_hot(flat_e, n_e, dtype=jnp.int32)       # (B, S·k, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, n_e * cap)       # (B, S·k)

    def dispatch_row(xr, slot_r, tok_r):
        g = jnp.zeros((n_e * cap + 1, d), xr.dtype)
        return g.at[slot_r].set(xr[tok_r])[:-1]

    ge = jax.vmap(dispatch_row)(x, slot, flat_t)                # (B, E·cap, D)
    ge = ge.reshape(b, n_e, cap, d)
    ge = _constrain(ge, "moe_ge")                               # EP over model
    h = jnp.einsum("becd,edf->becf", ge, p["wi"],
                   preferred_element_type=jnp.float32)
    gate, up = jnp.split(h, 2, axis=-1)
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    eo = jnp.einsum("becf,efd->becd", act, p["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    flat_out = eo.reshape(b, n_e * cap, d)

    def combine_row(fo, slot_r, tok_r, w_r, keep_r):
        contrib = jnp.where(keep_r, w_r, 0.0)[:, None].astype(fo.dtype) * \
            fo[jnp.minimum(slot_r, n_e * cap - 1)]
        return jnp.zeros((s, d), fo.dtype).at[tok_r].add(contrib)

    out = jax.vmap(combine_row)(flat_out, slot, flat_t, flat_w, keep)

    if "shared_wi" in p:
        out = out + swiglu(x.reshape(b * s, d), p["shared_wi"],
                           p["shared_wo"]).reshape(b, s, d)
    f = jnp.mean(jax.nn.one_hot(top_i, n_e), axis=(0, 1, 2))
    aux = n_e * jnp.sum(f * jnp.mean(probs, axis=(0, 1)))
    return out, aux.astype(jnp.float32)


def moe_ffn_sorted(p: Dict[str, jnp.ndarray], x: jnp.ndarray, top_k: int,
                   capacity_factor: float = 1.25
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based grouped dispatch (§Perf hillclimb: deepseek train_4k).

    ``moe_ffn_gathered`` ranks tokens within their expert bucket via a
    cumsum over a (B, S·k, E) one-hot — an O(T·E) int32 buffer that
    dominates peak memory at E=160 (4 TB global for the train_4k cell).
    Sorting (B, S·k) expert keys instead gives ranks in O(T log T) compute
    and O(T) memory: rank = index_in_sorted − first_index_of_expert
    (searchsorted on the sorted keys). Same capacity semantics, same
    output (dispatch order within an expert differs, sums are identical).
    """
    b, s, d = x.shape
    n_e = p["router"].shape[-1]
    cap = max(1, int(capacity_factor * s * top_k / n_e))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    t = s * top_k
    flat_e = top_i.reshape(b, t)
    flat_w = top_w.reshape(b, t)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(s), top_k)[None], (b, 1))

    # localize the scatter/gather: with the residual stream sequence-
    # sharded (SP), dispatching across model shards makes SPMD materialize
    # u32 index freight for every (row, feature) pair; un-sharding S for
    # the dispatch keeps scatters device-local, and the single re-shard to
    # expert-parallel layout happens on the contiguous ge tensor instead
    x = _constrain(x, "moe_x_local")

    order = jnp.argsort(flat_e, axis=1, stable=True)            # (B, T)
    sorted_e = jnp.take_along_axis(flat_e, order, 1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    pos = jnp.arange(t)[None, :] - first                        # rank in expert
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, n_e * cap)
    tok_s = jnp.take_along_axis(flat_t, order, 1)
    w_s = jnp.take_along_axis(flat_w, order, 1)

    def dispatch_row(xr, slot_r, tok_r):
        g = jnp.zeros((n_e * cap + 1, d), xr.dtype)
        return g.at[slot_r].set(xr[tok_r])[:-1]

    ge = jax.vmap(dispatch_row)(x, slot, tok_s).reshape(b, n_e, cap, d)
    ge = _constrain(ge, "moe_ge")
    h = jnp.einsum("becd,edf->becf", ge, p["wi"],
                   preferred_element_type=jnp.float32)
    gate, up = jnp.split(h, 2, axis=-1)
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    eo = jnp.einsum("becf,efd->becd", act, p["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    flat_out = eo.reshape(b, n_e * cap, d)

    def combine_row(fo, slot_r, tok_r, w_r, keep_r):
        contrib = jnp.where(keep_r, w_r, 0.0)[:, None].astype(fo.dtype) * \
            fo[jnp.minimum(slot_r, n_e * cap - 1)]
        return jnp.zeros((s, d), fo.dtype).at[tok_r].add(contrib)

    out = jax.vmap(combine_row)(flat_out, slot, tok_s, w_s, keep)

    if "shared_wi" in p:
        out = out + swiglu(x.reshape(b * s, d), p["shared_wi"],
                           p["shared_wo"]).reshape(b, s, d)
    f = jnp.mean(jax.nn.one_hot(top_i, n_e), axis=(0, 1, 2))
    aux = n_e * jnp.sum(f * jnp.mean(probs, axis=(0, 1)))
    return out, aux.astype(jnp.float32)
