"""Shared model layers (pure JAX, functional; params are nested dicts).

Conventions:
  * params are bf16 (norm scales f32); matmuls accumulate in f32 via
    preferred_element_type; losses/softmaxes in f32.
  * every layer has ``<name>_shapes(cfg) -> {name: (shape, dtype)}`` used
    both by real init (smoke tests) and by the dry-run's ShapeDtypeStruct
    path (no allocation for the full-size configs).
  * attention supports GQA (+ optional QKV bias, Qwen-style), optional
    chunked-local masking (Llama-4 iRoPE style) and NoPE layers, and MLA
    (DeepSeek-V2 latent KV compression) as a separate function.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain as _constrain

PDTYPE = jnp.bfloat16   # parameter dtype
NDTYPE = jnp.float32    # norm-scale dtype
ADTYPE = jnp.bfloat16   # activation dtype


def set_dtypes(params=jnp.bfloat16, acts=jnp.bfloat16) -> None:
    """Switch global param/activation dtypes.

    Full-size configs stay bf16 (dry-run only *compiles*); CPU smoke tests
    call ``set_dtypes(jnp.float32, jnp.float32)`` because the CPU backend
    cannot *execute* some bf16xbf16->f32 dot shapes. Modules must reference
    ``layers.PDTYPE`` (module attribute), not import it by value."""
    global PDTYPE, ADTYPE
    PDTYPE = params
    ADTYPE = acts


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def materialize(shapes: Dict[str, Any], key: jax.Array) -> Dict[str, Any]:
    """Turn a {name: (shape, dtype)} tree into initialized arrays.

    Name-aware: keys containing 'norm' get ones (RMS/LN scales); bias-like
    keys (b*, *_b<i>, eps) get zeros; everything else fan-in-scaled normal."""
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], tuple))
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes, is_leaf=is_leaf)
    keys = jax.random.split(key, max(1, len(flat)))
    out = []
    for (path, (shape, dtype)), k in zip(flat, keys):
        name = str(path[-1].key) if path else ""
        if "norm" in name:
            out.append(jnp.ones(shape, dtype))
        elif name == "eps" or name.startswith("b") and len(shape) == 1 \
                or "_b" in name:
            out.append(jnp.zeros(shape, dtype))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(max(1, fan_in))
            out.append((jax.random.normal(k, shape, jnp.float32) * std)
                       .astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstractify(shapes: Dict[str, Any]):
    """Same tree as ShapeDtypeStructs (dry-run: zero allocation)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x[0], x[1]),
        shapes, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jnp.ndarray, wi: jnp.ndarray, wo: jnp.ndarray) -> jnp.ndarray:
    """Fused gate+up projection: wi (d, 2*f), wo (f, d)."""
    h = jnp.einsum("...d,df->...f", x, wi,
                   preferred_element_type=jnp.float32)
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate) * up
    return jnp.einsum("...f,fd->...d", act.astype(x.dtype), wo,
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta))              # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_shapes(d_model: int, n_heads: int, n_kv: int, d_head: int,
                     qkv_bias: bool) -> Dict[str, Any]:
    s = {
        "wq": ((d_model, n_heads * d_head), PDTYPE),
        "wk": ((d_model, n_kv * d_head), PDTYPE),
        "wv": ((d_model, n_kv * d_head), PDTYPE),
        "wo": ((n_heads * d_head, d_model), PDTYPE),
    }
    if qkv_bias:
        s["bq"] = ((n_heads * d_head,), NDTYPE)
        s["bk"] = ((n_kv * d_head,), NDTYPE)
        s["bv"] = ((n_kv * d_head,), NDTYPE)
    return s


def _causal_mask(sq: int, skv: int, q_off, chunk: Optional[int]) -> jnp.ndarray:
    qpos = q_off + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if chunk is not None:
        m = m & (kpos // chunk == qpos // chunk)  # Llama-4 chunked locality
    return m


def gqa_attention(p: Dict[str, jnp.ndarray], x: jnp.ndarray,
                  positions: jnp.ndarray, n_heads: int, n_kv: int,
                  d_head: int, *, theta: float = 10000.0,
                  use_rope: bool = True, chunk: Optional[int] = None,
                  kv_cache: Optional[Tuple] = None,
                  cache_len: Optional[jnp.ndarray] = None,
                  q_chunk: Optional[int] = None,
                  unroll_chunks: bool = False):
    """x: (B, S, D). With kv_cache=(k,v) of (B, Skv, n_kv, Dh): decode mode —
    returns (out, (k', v')); else self-attention over x (causal)."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, n_heads, d_head)
    k = k.reshape(b, s, n_kv, d_head)
    v = v.reshape(b, s, n_kv, d_head)
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        skv = ck.shape[1]
        # write the new K/V at cache_len (decode: s == 1)
        idx = (cache_len if cache_len is not None else skv - 1)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, idx, axis=1) \
            if s == 1 else ck
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, idx, axis=1) \
            if s == 1 else cv
        k_all, v_all = ck, cv
        kpos = jnp.arange(skv)[None, :]
        mask = kpos <= (idx if cache_len is not None else skv - 1)
        if chunk is not None:
            qc = (idx) // chunk
            mask = mask & (kpos // chunk == qc)
        out = _sdpa(q, k_all, v_all, n_heads, n_kv, mask[:, None, :])
        y = out.reshape(b, s, n_heads * d_head)
        y = jnp.einsum("bsh,hd->bsd", y, p["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        return y, (ck, cv)

    mask = _causal_mask(s, s, 0, chunk)
    out = _sdpa(q, k, v, n_heads, n_kv, mask, q_chunk=q_chunk,
                unroll_chunks=unroll_chunks)
    y = out.reshape(b, s, n_heads * d_head)
    y = jnp.einsum("bsh,hd->bsd", y, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, None


def _sdpa(q, k, v, n_heads, n_kv, mask, q_chunk: Optional[int] = None,
          unroll_chunks: bool = False):
    """Grouped scaled dot-product attention; f32 logits/softmax.

    Score tensors are sequence-sharded over the model axis (query dim for
    prefill/train, KV dim for decode) — head counts need not divide the TP
    size (GQA kv=4 vs model=16), and the O(S²) buffer is the peak-memory
    driver at 32k (EXPERIMENTS.md §Perf).

    q_chunk: blockwise (Rabe-Staats / flash-style) query chunking — the
    score buffer shrinks from O(Sq·Skv) to O(q_chunk·Skv). This is the
    paper's *boxing* applied to attention: partition the (q, kv) search
    space so the working set fits fast memory (§Perf hillclimb #1).
    unroll_chunks: unroll the chunk scan (set by the dry-run cost probes —
    XLA counts while bodies once)."""
    b, sq, _, dh = q.shape
    skv = k.shape[1]
    g = n_heads // n_kv
    q = q.reshape(b, sq, n_kv, g, dh)

    if q_chunk is not None and sq > q_chunk and sq % q_chunk == 0:
        n_chunks = sq // q_chunk
        qs = q.reshape(b, n_chunks, q_chunk, n_kv, g, dh)
        qs = jnp.moveaxis(qs, 1, 0)                       # (C, B, qc, kv, g, d)
        if mask.ndim != 2:
            raise ValueError("q_chunk expects a (Sq, Skv) mask")
        ms = mask.reshape(n_chunks, q_chunk, skv)

        def chunk(carry, inp):
            qc, mc = inp
            oc = _sdpa_core(qc, k, v, g, dh, mc[None, None, None])
            return carry, oc

        _, outs = jax.lax.scan(chunk, 0, (qs, ms),
                               unroll=n_chunks if unroll_chunks else 1)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, n_kv, g, dh)
        return out.reshape(b, sq, n_heads, dh)

    m = mask[None, None, None, :, :] if mask.ndim == 2 else \
        (mask[:, None, None, :, :] if mask.ndim == 3 else mask)
    out = _sdpa_core(q, k, v, g, dh, m, constrain=True)
    return out.reshape(b, sq, n_heads, dh)


def _sdpa_core(q, k, v, g, dh, m, constrain: bool = False):
    """One (q-block × full-KV) attention tile: (B, qc, kv, g, d) x
    (B, S, kv, d) -> (B, qc, kv, g, d)."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    if constrain:
        logits = _constrain(logits, "attn_q" if q.shape[1] > 1 else "attn_s")
    logits = logits / math.sqrt(dh)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(m, logits, neg)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def mla_shapes(d_model: int, n_heads: int, q_lora: int, kv_lora: int,
               qk_nope: int, qk_rope: int, v_head: int) -> Dict[str, Any]:
    return {
        "wq_a": ((d_model, q_lora), PDTYPE),
        "q_a_norm": ((q_lora,), NDTYPE),
        "wq_b": ((q_lora, n_heads * (qk_nope + qk_rope)), PDTYPE),
        "wkv_a": ((d_model, kv_lora + qk_rope), PDTYPE),
        "kv_a_norm": ((kv_lora,), NDTYPE),
        "wkv_b": ((kv_lora, n_heads * (qk_nope + v_head)), PDTYPE),
        "wo": ((n_heads * v_head, d_model), PDTYPE),
    }


def mla_attention(p, x, positions, n_heads, q_lora, kv_lora, qk_nope,
                  qk_rope, v_head, *, theta: float = 10000.0,
                  kv_cache=None, cache_len=None,
                  q_chunk: Optional[int] = None,
                  unroll_chunks: bool = False):
    """DeepSeek-V2 MLA. Decode cache stores the *compressed* latent
    (B, S, kv_lora + qk_rope) — the memory win that defines MLA."""
    b, s, d = x.shape
    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"],
                             preferred_element_type=jnp.float32).astype(x.dtype),
                  p["q_a_norm"])
    q = jnp.einsum("bsr,rh->bsh", qa, p["wq_b"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(b, s, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    latent, k_rope_in = kv_a[..., :kv_lora], kv_a[..., kv_lora:]
    latent = rms_norm(latent, p["kv_a_norm"])
    k_rope = apply_rope(k_rope_in[..., None, :], positions, theta)  # (B,S,1,r)

    if kv_cache is not None:
        c_lat, c_kr = kv_cache
        skv = c_lat.shape[1]
        idx = cache_len if cache_len is not None else skv - 1
        if s == 1:
            c_lat = jax.lax.dynamic_update_slice_in_dim(c_lat, latent, idx, 1)
            c_kr = jax.lax.dynamic_update_slice_in_dim(
                c_kr, k_rope[..., 0, :], idx, 1)
        latent_all, k_rope_all = c_lat, c_kr
        kpos = jnp.arange(skv)[None, :]
        mask = (kpos <= idx)[:, None, :]
    else:
        latent_all, k_rope_all = latent, k_rope[..., 0, :]
        mask = _causal_mask(s, s, 0, None)
        c_lat = c_kr = None

    kv = jnp.einsum("bsr,rh->bsh", latent_all, p["wkv_b"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    kv = kv.reshape(b, latent_all.shape[1], n_heads, qk_nope + v_head)
    k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]

    scale = 1.0 / math.sqrt(qk_nope + qk_rope)

    def _mla_tile(qn, qr, m):
        lg = (jnp.einsum("bqhd,bshd->bhqs", qn, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", qr, k_rope_all,
                           preferred_element_type=jnp.float32)) * scale
        lg = jnp.where(m, lg, jnp.finfo(jnp.float32).min)
        ww = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
        return ww, lg

    if q_chunk is not None and s > q_chunk and s % q_chunk == 0 \
            and kv_cache is None and mask.ndim == 2:
        # blockwise query chunking (boxing applied to attention): the
        # (B, H, S, S) score buffer becomes (B, H, qc, S) per step.
        n_chunks = s // q_chunk
        qn_c = jnp.moveaxis(q_nope.reshape(b, n_chunks, q_chunk,
                                           n_heads, qk_nope), 1, 0)
        qr_c = jnp.moveaxis(q_rope.reshape(b, n_chunks, q_chunk,
                                           n_heads, qk_rope), 1, 0)
        m_c = mask.reshape(n_chunks, q_chunk, latent_all.shape[1])

        def chunk(carry, inp):
            qn1, qr1, m1 = inp
            w1, _ = _mla_tile(qn1, qr1, m1[None, None])
            o1 = jnp.einsum("bhqs,bshd->bqhd", w1, v,
                            preferred_element_type=jnp.float32).astype(x.dtype)
            return carry, o1

        _, outs = jax.lax.scan(chunk, 0, (qn_c, qr_c, m_c),
                               unroll=n_chunks if unroll_chunks else 1)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_heads, v_head)
    else:
        m = mask[None, None, :, :] if mask.ndim == 2 else mask[:, None, :, :]
        w, _ = _mla_tile(q_nope, q_rope, m)
        w = _constrain(w, "mla_scores")
        out = jnp.einsum("bhqs,bshd->bqhd", w, v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, n_heads * v_head), p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if kv_cache is not None:
        return y, (c_lat, c_kr)
    return y, None
