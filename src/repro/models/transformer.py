"""LM transformer family: dense GQA, MLA, and MoE variants (pure JAX).

Structure is pattern-based so heterogeneous stacks (DeepSeek's dense first
layer, Llama-4's interleaved MoE / chunked-local layers) still compile as a
single `lax.scan` over stacked layer params — essential for compile time at
60 layers on a 512-device mesh (HLO is O(pattern), not O(n_layers)).

API (all functional):
  param_shapes(cfg) / init_params(cfg, key) / param_specs(cfg)
  forward(cfg, params, tokens)                  -> logits
  loss_fn(cfg, params, batch)                   -> (loss, metrics)
  prefill(cfg, params, tokens)                  -> (cache, last_logits)
  decode_step(cfg, params, cache, token, pos)   -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from repro.parallel.sharding import constrain as _constrain
from .moe import moe_ffn, moe_ffn_gathered, moe_shapes


@dataclass(frozen=True)
class LayerSpec:
    ffn: str = "dense"                  # "dense" | "moe"
    use_rope: bool = True               # False => NoPE (Llama-4 global layers)
    chunk: Optional[int] = None         # chunked-local attention window


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    prefix: Tuple[LayerSpec, ...] = ()
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_moe: int = 0
    moe_impl: str = "gathered"          # gathered | gathered_sort | dense
    # MLA (DeepSeek-V2)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    tie_embeddings: bool = False
    remat: str = "layer"                # "none" | "layer" | "dots"
    attn_q_chunk: Optional[int] = None  # blockwise attention query chunk
    scan_unroll: bool = False           # True: unroll the layer scan (the
                                        # dry-run cost probes need unrolled
                                        # bodies: XLA cost analysis counts
                                        # `while` bodies once per program)

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.pattern) == 0, (self.n_layers, self.pattern)
        return body // len(self.pattern)

    def params_count(self) -> int:
        """Total parameters (for 6ND model-flops accounting)."""
        import math as _math
        tree = param_shapes(self)
        return sum(_math.prod(s[0])
                   for s in jax.tree_util.tree_leaves(
                       tree, is_leaf=_is_shape_leaf))

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        total = self.params_count()
        if self.n_experts == 0:
            return total
        # subtract inactive expert fraction
        n_moe_layers = sum(1 for s in self.pattern if s.ffn == "moe") \
            * self.n_repeats + sum(1 for s in self.prefix if s.ffn == "moe")
        per_expert = self.d_model * 2 * self.d_ff_moe + self.d_ff_moe * self.d_model
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


def _is_shape_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


# ---------------------------------------------------------------------------
# parameter shapes
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: TransformerConfig, spec: LayerSpec) -> Dict[str, Any]:
    if cfg.mla:
        attn = L.mla_shapes(cfg.d_model, cfg.n_heads, cfg.q_lora, cfg.kv_lora,
                            cfg.qk_nope, cfg.qk_rope, cfg.v_head)
    else:
        attn = L.attention_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.d_head, cfg.qkv_bias)
    if spec.ffn == "moe":
        ffn = moe_shapes(cfg.d_model, cfg.d_ff_moe, cfg.n_experts, cfg.n_shared)
    else:
        ffn = {"wi": ((cfg.d_model, 2 * cfg.d_ff), L.PDTYPE),
               "wo": ((cfg.d_ff, cfg.d_model), L.PDTYPE)}
    return {"attn": attn, "ffn": ffn,
            "norm1": ((cfg.d_model,), L.NDTYPE),
            "norm2": ((cfg.d_model,), L.NDTYPE)}


def _stack_shapes(tree: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda x: ((n,) + x[0], x[1]), tree, is_leaf=_is_shape_leaf)


def param_shapes(cfg: TransformerConfig) -> Dict[str, Any]:
    shapes: Dict[str, Any] = {
        "embed": ((cfg.vocab, cfg.d_model), L.PDTYPE),
        "final_norm": ((cfg.d_model,), L.NDTYPE),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = ((cfg.d_model, cfg.vocab), L.PDTYPE)
    for i, spec in enumerate(cfg.prefix):
        shapes[f"prefix{i}"] = _layer_shapes(cfg, spec)
    for i, spec in enumerate(cfg.pattern):
        shapes[f"block{i}"] = _stack_shapes(_layer_shapes(cfg, spec),
                                            cfg.n_repeats)
    return shapes


def init_params(cfg: TransformerConfig, key: jax.Array):
    return L.materialize(param_shapes(cfg), key)


def param_specs(cfg: TransformerConfig):
    return L.abstractify(param_shapes(cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(cfg: TransformerConfig, spec: LayerSpec, p, x, positions,
                 kv_cache=None, cache_len=None):
    h = L.rms_norm(x, p["norm1"])
    if cfg.mla:
        attn_out, new_cache = L.mla_attention(
            p["attn"], h, positions, cfg.n_heads, cfg.q_lora, cfg.kv_lora,
            cfg.qk_nope, cfg.qk_rope, cfg.v_head, theta=cfg.rope_theta,
            kv_cache=kv_cache, cache_len=cache_len,
            q_chunk=cfg.attn_q_chunk, unroll_chunks=cfg.scan_unroll)
    else:
        attn_out, new_cache = L.gqa_attention(
            p["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            theta=cfg.rope_theta, use_rope=spec.use_rope, chunk=spec.chunk,
            kv_cache=kv_cache, cache_len=cache_len,
            q_chunk=cfg.attn_q_chunk, unroll_chunks=cfg.scan_unroll)
    x = x + attn_out
    h = L.rms_norm(x, p["norm2"])
    aux = jnp.float32(0)
    if spec.ffn == "moe":
        if cfg.moe_impl == "dense":
            ffn_out, aux = moe_ffn(p["ffn"], h, cfg.top_k)
        elif cfg.moe_impl == "gathered_sort":
            from .moe import moe_ffn_sorted
            ffn_out, aux = moe_ffn_sorted(p["ffn"], h, cfg.top_k)
        else:
            ffn_out, aux = moe_ffn_gathered(p["ffn"], h, cfg.top_k)
    else:
        b, s, d = h.shape
        ffn_out = L.swiglu(h.reshape(b * s, d), p["ffn"]["wi"],
                           p["ffn"]["wo"]).reshape(b, s, d)
    return x + ffn_out, aux, new_cache


def forward(cfg: TransformerConfig, params, tokens: jnp.ndarray,
            last_only: bool = False):
    """tokens (B, S) -> logits (B, S, V) [or (B, V) when last_only]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.ADTYPE)
    x = _constrain(x, "lm_act")
    positions = jnp.tile(jnp.arange(s)[None, :], (b, 1))
    aux_total = jnp.float32(0)

    for i, spec in enumerate(cfg.prefix):
        x, aux, _ = _apply_layer(cfg, spec, params[f"prefix{i}"], x, positions)
        aux_total += aux

    def body(carry, xs):
        xc, aux_acc = carry
        for i, spec in enumerate(cfg.pattern):
            fn = partial(_apply_layer, cfg, spec)
            if cfg.remat == "layer":
                fn = jax.checkpoint(fn)
            elif cfg.remat == "dots":
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.checkpoint_dots)
            xc, aux, _ = fn(xs[f"block{i}"], xc, positions)
            xc = _constrain(xc, "lm_act")
            aux_acc += aux
        return (xc, aux_acc), None

    xs = {f"block{i}": params[f"block{i}"] for i in range(len(cfg.pattern))}
    (x, aux_total), _ = jax.lax.scan(
        body, (x, aux_total), xs,
        unroll=cfg.n_repeats if cfg.scan_unroll else 1)

    x = L.rms_norm(x, params["final_norm"])
    if last_only:
        x = x[:, -1, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, head,
                        preferred_element_type=jnp.float32)
    logits = _constrain(logits, "lm_logits" if logits.ndim == 3 else "lm_logits2")
    return logits, aux_total


def loss_fn(cfg: TransformerConfig, params, batch: Dict[str, jnp.ndarray]):
    """batch: tokens (B, S), targets (B, S). Returns (loss, metrics)."""
    logits, aux = forward(cfg, params, batch["tokens"])
    tgt = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int):
    """Per-layer KV cache shapes (stacked for the scanned blocks)."""
    if cfg.mla:
        per = {"latent": ((batch, max_len, cfg.kv_lora), L.ADTYPE),
               "rope": ((batch, max_len, cfg.qk_rope), L.ADTYPE)}
    else:
        per = {"k": ((batch, max_len, cfg.n_kv_heads, cfg.d_head), L.ADTYPE),
               "v": ((batch, max_len, cfg.n_kv_heads, cfg.d_head), L.ADTYPE)}
    shapes = {}
    for i in range(len(cfg.prefix)):
        shapes[f"prefix{i}"] = per
    for i in range(len(cfg.pattern)):
        shapes[f"block{i}"] = _stack_shapes(per, cfg.n_repeats)
    return shapes


def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x[0], x[1]), cache_shapes(cfg, batch, max_len),
        is_leaf=_is_shape_leaf)


def cache_specs(cfg: TransformerConfig, batch: int, max_len: int):
    return L.abstractify(cache_shapes(cfg, batch, max_len))


def _cache_tuple(cfg, c):
    return (c["latent"], c["rope"]) if cfg.mla else (c["k"], c["v"])


def _cache_dict(cfg, t):
    return {"latent": t[0], "rope": t[1]} if cfg.mla else {"k": t[0], "v": t[1]}


def decode_step(cfg: TransformerConfig, params, cache, token: jnp.ndarray,
                pos: jnp.ndarray):
    """token (B, 1) int32, pos scalar int32 -> (logits (B, V), cache')."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(L.ADTYPE)
    positions = jnp.full((b, 1), pos, jnp.int32)
    new_cache = {}
    for i, spec in enumerate(cfg.prefix):
        x, _, nc = _apply_layer(cfg, spec, params[f"prefix{i}"], x, positions,
                                kv_cache=_cache_tuple(cfg, cache[f"prefix{i}"]),
                                cache_len=pos)
        new_cache[f"prefix{i}"] = _cache_dict(cfg, nc)

    def body(xc, xs):
        for i, spec in enumerate(cfg.pattern):
            xc, _, nc = _apply_layer(
                cfg, spec, xs[f"p{i}"], xc, positions,
                kv_cache=_cache_tuple(cfg, xs[f"c{i}"]), cache_len=pos)
            xs[f"c{i}"] = _cache_dict(cfg, nc)
        return xc, {k: v for k, v in xs.items() if k.startswith("c")}

    xs = {}
    for i in range(len(cfg.pattern)):
        xs[f"p{i}"] = params[f"block{i}"]
        xs[f"c{i}"] = cache[f"block{i}"]
    x, new_blocks = jax.lax.scan(
        body, x, xs, unroll=cfg.n_repeats if cfg.scan_unroll else 1)
    for i in range(len(cfg.pattern)):
        new_cache[f"block{i}"] = new_blocks[f"c{i}"]

    x = L.rms_norm(x[:, -1, :], params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x, head,
                        preferred_element_type=jnp.float32)
    logits = _constrain(logits, "lm_logits2")
    return logits, new_cache


def prefill(cfg: TransformerConfig, params, tokens: jnp.ndarray,
            max_len: Optional[int] = None):
    """Full-sequence prefill; returns (cache, last-token logits).

    The cache is populated by recomputing K/V per layer (projection-only
    pass reusing forward activations would save flops; recorded as a §Perf
    candidate). Chunked (Sarathi-style) prefill is used by serve.py for
    long sequences."""
    b, s = tokens.shape
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.ADTYPE)
    positions = jnp.tile(jnp.arange(s)[None, :], (b, 1))
    cache = init_cache(cfg, b, max_len)

    def project_kv(cfg, spec, p, h):
        if cfg.mla:
            kv_a = jnp.einsum("bsd,dr->bsr", L.rms_norm(h, p["norm1"]),
                              p["attn"]["wkv_a"],
                              preferred_element_type=jnp.float32).astype(h.dtype)
            lat = L.rms_norm(kv_a[..., :cfg.kv_lora], p["attn"]["kv_a_norm"])
            kr = L.apply_rope(kv_a[..., None, cfg.kv_lora:], positions,
                              cfg.rope_theta)[..., 0, :]
            return {"latent": lat, "rope": kr}
        hn = L.rms_norm(h, p["norm1"])
        k = jnp.einsum("bsd,dh->bsh", hn, p["attn"]["wk"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        v = jnp.einsum("bsd,dh->bsh", hn, p["attn"]["wv"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        if "bk" in p["attn"]:
            k = k + p["attn"]["bk"].astype(h.dtype)
            v = v + p["attn"]["bv"].astype(h.dtype)
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        if spec.use_rope:
            k = L.apply_rope(k, positions, cfg.rope_theta)
        return {"k": k, "v": v}

    def pad_c(c):
        return jax.tree_util.tree_map(
            lambda a: jnp.pad(a, [(0, 0), (0, max_len - s)] +
                              [(0, 0)] * (a.ndim - 2)), c)

    for i, spec in enumerate(cfg.prefix):
        cache[f"prefix{i}"] = pad_c(project_kv(cfg, spec,
                                               params[f"prefix{i}"], x))
        x, _, _ = _apply_layer(cfg, spec, params[f"prefix{i}"], x, positions)

    def body(xc, xs):
        cs = {}
        for i, spec in enumerate(cfg.pattern):
            cs[f"c{i}"] = pad_c(project_kv(cfg, spec, xs[f"p{i}"], xc))
            xc, _, _ = _apply_layer(cfg, spec, xs[f"p{i}"], xc, positions)
        return xc, cs

    xs = {f"p{i}": params[f"block{i}"] for i in range(len(cfg.pattern))}
    x, blocks = jax.lax.scan(body, x, xs,
                             unroll=cfg.n_repeats if cfg.scan_unroll else 1)
    for i in range(len(cfg.pattern)):
        cache[f"block{i}"] = blocks[f"c{i}"]

    x = L.rms_norm(x[:, -1, :], params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x, head,
                        preferred_element_type=jnp.float32)
    logits = _constrain(logits, "lm_logits2")
    return cache, logits
