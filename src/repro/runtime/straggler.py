"""Straggler detection + work-stealing for idempotent work items.

Two mechanisms (DESIGN.md §5):

* ``StepTimeWatchdog`` — records per-step wall times; flags a straggling
  step when it exceeds ``k`` × a robust (median-based) baseline. On a real
  fleet the flag triggers hot-spare swap / checkpoint-restart; here the
  policy object is what we test.

* ``BoxScheduler`` — the paper's boxes are overlap-free, idempotent work
  items (§3.3), which makes straggler mitigation trivial and *exact*:
  unfinished boxes are re-queued and duplicated results are deduplicated
  by box id. This is the triangle engine's distribution layer; the same
  scheduler drives multi-process CPU runs and the 512-chip plan.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set


class StepTimeWatchdog:
    def __init__(self, window: int = 32, threshold: float = 2.5,
                 min_samples: int = 8):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        self.min_samples = min_samples
        self.flagged: List[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self._step += 1
        if len(self.times) >= self.min_samples:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.threshold * med:
                self.flagged.append(self._step)
                self.times.append(seconds)
                return True
        self.times.append(seconds)
        return False


@dataclass
class BoxTask:
    box_id: int
    payload: object = None
    assigned_to: Optional[int] = None
    t_assigned: float = 0.0
    done: bool = False
    result: object = None


class BoxScheduler:
    """Work-stealing scheduler over idempotent boxes.

    Thread-safe: the serving layer (``repro.serve``) drives one scheduler
    per query from several box-pool worker threads — completion dedup and
    re-queuing serialize on an internal lock, so a box that a retry round
    and a straggler duplicate both finish is counted exactly once
    (``complete`` returns whether this completion was the effective one,
    ``duplicates``/``requeues`` tally the rest)."""

    def __init__(self, boxes: Sequence, n_workers: int,
                 steal_after_s: float = 60.0):
        self.tasks = {i: BoxTask(i, b) for i, b in enumerate(boxes)}
        self.queue = deque(self.tasks)
        self.n_workers = n_workers
        self.steal_after_s = steal_after_s
        self.inflight: Dict[int, Set[int]] = {w: set() for w in range(n_workers)}
        self.duplicates = 0
        self.requeues = 0
        self._lock = threading.RLock()

    def next_for(self, worker: int, now: Optional[float] = None) -> Optional[BoxTask]:
        now = time.monotonic() if now is None else now
        with self._lock:
            while self.queue:
                tid = self.queue.popleft()
                t = self.tasks[tid]
                if t.done:
                    continue
                t.assigned_to = worker
                t.t_assigned = now
                self.inflight[worker].add(tid)
                return t
            # steal the longest-outstanding task from another worker
            victim = None
            for w, tids in self.inflight.items():
                if w == worker:
                    continue
                for tid in tids:
                    t = self.tasks[tid]
                    if t.done or now - t.t_assigned < self.steal_after_s:
                        continue
                    if victim is None or t.t_assigned < victim.t_assigned:
                        victim = t
            if victim is not None:
                self.duplicates += 1
                self.inflight[worker].add(victim.box_id)
                return victim
            return None

    def complete(self, worker: int, box_id: int, result) -> bool:
        """Idempotent completion: the first result wins; returns whether
        this completion was the effective one."""
        with self._lock:
            t = self.tasks[box_id]
            self.inflight[worker].discard(box_id)
            if t.done:
                return False
            t.done = True
            t.result = result
            return True

    def requeue(self, box_ids: Sequence[int]) -> int:
        """Re-queue not-yet-done boxes (a failed/cancelled attempt handing
        its work back — boxes are idempotent, so re-running is exact).
        Returns how many were actually re-queued; already-done boxes are
        skipped, which is the dedup-by-box-id contract."""
        n = 0
        with self._lock:
            for tid in box_ids:
                t = self.tasks[tid]
                if t.done:
                    continue
                t.assigned_to = None
                self.queue.append(tid)
                self.requeues += 1
                n += 1
        return n

    def pending(self) -> List[int]:
        """Box ids not yet effectively completed, in box order."""
        with self._lock:
            return [i for i in sorted(self.tasks) if not self.tasks[i].done]

    def all_done(self) -> bool:
        with self._lock:
            return all(t.done for t in self.tasks.values())

    def results(self):
        with self._lock:
            return [self.tasks[i].result for i in sorted(self.tasks)]


def fail_worker(sched: BoxScheduler, worker: int) -> int:
    """Simulated worker death: re-queue its in-flight boxes. Returns count."""
    with sched._lock:
        tids = list(sched.inflight[worker])
        for tid in tids:
            sched.inflight[worker].discard(tid)
            if not sched.tasks[tid].done:
                sched.queue.append(tid)
        return len(tids)
