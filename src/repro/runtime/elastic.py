"""Elastic scaling + failure recovery (simulated control plane).

On a real cluster the coordinator detects missing heartbeats; here the
same state machine runs against a simulated device pool so the recovery
logic (the part that is *our* code, not the infra's) is exercised by tests:

  1. failure detected -> drop the failed hosts' devices,
  2. choose the largest feasible mesh from the survivors (power-of-two
     slices along the data axis; the model axis is preserved because TP
     shards are interdependent),
  3. rebuild shardings for the new mesh,
  4. restore params from the last checkpoint into the new sharding,
  5. rescale grad-accumulation so the *global* batch is invariant
     (elastic semantics: same optimization trajectory, longer steps).

Boxes (the paper's triangle engine) recover even more cheaply: boxes are
idempotent work items, so unfinished boxes are simply re-queued
(runtime.straggler handles reassignment).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class DevicePool:
    """Simulated fleet: device ids grouped by host."""

    n_hosts: int
    devices_per_host: int = 4
    failed_hosts: set = field(default_factory=set)

    def alive_devices(self) -> List[int]:
        out = []
        for h in range(self.n_hosts):
            if h in self.failed_hosts:
                continue
            out.extend(range(h * self.devices_per_host,
                             (h + 1) * self.devices_per_host))
        return out

    def fail(self, host: int) -> None:
        self.failed_hosts.add(host)

    def recover(self, host: int) -> None:
        self.failed_hosts.discard(host)


@dataclass
class MeshPlan:
    data: int
    model: int
    pod: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pod

    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    def shape(self) -> Tuple[int, ...]:
        return ((self.pod, self.data, self.model) if self.pod > 1
                else (self.data, self.model))


def plan_mesh(n_alive: int, model_parallel: int, prefer_pods: int = 1
              ) -> Optional[MeshPlan]:
    """Largest power-of-two data axis that fits the surviving devices while
    preserving the model axis (TP shards can't shrink without resharding
    params — that path goes through checkpoint restore anyway, step 4)."""
    if n_alive < model_parallel:
        return None
    budget = n_alive // model_parallel
    data = 1 << int(math.floor(math.log2(budget)))
    pod = prefer_pods
    while pod > 1 and data // pod < 1:
        pod //= 2
    data //= pod
    return MeshPlan(data=data, model=model_parallel, pod=pod)


@dataclass
class ElasticState:
    pool: DevicePool
    model_parallel: int
    global_batch: int
    plan: Optional[MeshPlan] = None
    generation: int = 0

    def __post_init__(self):
        self.plan = plan_mesh(len(self.pool.alive_devices()),
                              self.model_parallel)

    def grad_accum_steps(self, per_device_batch: int = 1) -> int:
        """Micro-steps to keep the global batch invariant (step 5)."""
        return accum_steps_for(self.global_batch, self.plan, per_device_batch)

    def on_failure(self, host: int) -> MeshPlan:
        """Steps 1-2: drop host, re-plan. Caller rebuilds shardings (3),
        restores from checkpoint (4) and queries accum rescale (5)."""
        self.pool.fail(host)
        new_plan = plan_mesh(len(self.pool.alive_devices()),
                             self.model_parallel)
        if new_plan is None:
            raise RuntimeError("insufficient devices for model parallelism")
        self.plan = new_plan
        self.generation += 1
        return new_plan

    def on_recovery(self, host: int) -> MeshPlan:
        self.pool.recover(host)
        self.plan = plan_mesh(len(self.pool.alive_devices()),
                              self.model_parallel)
        self.generation += 1
        return self.plan


def accum_steps_for(global_batch: int, plan: MeshPlan,
                    per_device_batch: int) -> int:
    """Micro-batches per optimizer step so DP-size changes never change the
    effective global batch: ceil(global / (dp_size * per_device))."""
    dp = plan.data * plan.pod
    return max(1, -(-global_batch // (dp * per_device_batch)))
