"""AdamW + schedules + global-norm clipping (functional, pytree-native).

Moments are f32 regardless of param dtype (bf16 params update through f32
math — no separate master copy; the f32 moments pair carries the precision;
this halves optimizer HBM vs master-copy designs and is recorded in
EXPERIMENTS.md §Perf). Moment trees shard exactly like their parameters.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    t = (s - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
