"""Gradient compression with error feedback (DCN-crossing reductions).

At 2 pods the gradient all-reduce crosses the data-center network once per
step; compressing the DCN leg is the classic distributed-optimization
trick. Two codecs:

  * bf16: cast (2x); error-free enough in practice, no state.
  * int8: per-tensor symmetric quantization with error-feedback residuals
    [1-bit Adam / EF-SGD lineage]: the quantization error is added back
    into the next step's gradient, preserving convergence.

Both are pure pytree transforms usable inside jit; train.py applies them
between grad computation and the optimizer.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)


def init_error_feedback(grads_template):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def compress_int8_ef(grads, residuals):
    """Returns ((q, scales), new_residuals). q is int8, scale per tensor."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return (q, scale), new_r

    flat, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    qs, rs = [], []
    for g, r in zip(flat, flat_r):
        (q, s), nr = one(g, r)
        qs.append((q, s))
        rs.append(nr)
    return (jax.tree_util.tree_unflatten(tdef, qs),
            jax.tree_util.tree_unflatten(tdef, rs))


def decompress_int8(packed):
    def one(p):
        q, s = p
        return q.astype(jnp.float32) * s

    return jax.tree_util.tree_map(one, packed,
                                  is_leaf=lambda x: isinstance(x, tuple)
                                  and len(x) == 2)
