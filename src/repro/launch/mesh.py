"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A function, not a module-level constant: importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) 'data' x 'model' single pod (256 chips), or
    (2, 16, 16) 'pod' x 'data' x 'model' for 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline analysis.
HW = dict(
    peak_bf16_flops=197e12,      # per chip
    hbm_bandwidth=819e9,         # bytes/s per chip
    ici_bandwidth=50e9,          # bytes/s per link
    hbm_bytes=16 * 2**30,        # capacity per chip
    chips_single_pod=256,
    chips_multi_pod=512,
)
