"""Production mesh construction (assignment MULTI-POD DRY-RUN §1) plus the
distributed box-fabric mesh helpers (``repro.parallel.fabric``).

Every constructor is a function, not a module-level constant: importing
this module never touches jax device state (``resolve_fabric_shards`` and
``fabric_mesh`` only enumerate devices when called without an explicit
device list).
"""

from __future__ import annotations

import os
import re
from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) 'data' x 'model' single pod (256 chips), or
    (2, 16, 16) 'pod' x 'data' x 'model' for 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# distributed box fabric (repro.parallel.fabric)
# ---------------------------------------------------------------------------

FABRIC_AXIS = "shards"
FABRIC_SHARDS_ENV = "REPRO_FABRIC_SHARDS"

_FORCED_DEVICES_RE = re.compile(
    r"--xla_force_host_platform_device_count=(\d+)")


def host_device_count_from_flags(flags: Optional[str] = None
                                 ) -> Optional[int]:
    """The forced host-platform device count requested by an ``XLA_FLAGS``
    string (``None`` = read the environment), or ``None`` when the flag is
    absent. When the flag repeats, the last occurrence wins — XLA's own
    parsing rule, so what this returns is what ``jax.devices()`` will
    materialize on the cpu platform."""
    if flags is None:
        flags = os.environ.get("XLA_FLAGS", "")
    hits = _FORCED_DEVICES_RE.findall(flags or "")
    return int(hits[-1]) if hits else None


def resolve_fabric_shards(requested: Optional[int] = None,
                          devices: Optional[Sequence] = None) -> int:
    """Number of fabric shards for this process: an explicit request wins,
    then the ``REPRO_FABRIC_SHARDS`` env override, then one shard per
    local device. Always >= 1. More shards than devices is legal — the
    fabric executes shards as host partitions and only needs devices for
    the optional mesh (``psum``) reduction."""
    if requested is not None:
        return max(1, int(requested))
    env = os.environ.get(FABRIC_SHARDS_ENV, "").strip()
    if env:
        return max(1, int(env))
    if devices is None:
        devices = jax.devices()
    return max(1, len(devices))


def fabric_mesh(n_shards: Optional[int] = None,
                devices: Optional[Sequence] = None):
    """1-D device mesh with the single axis ``"shards"`` over the first
    ``n_shards`` devices — the fabric's reduction mesh (one device per
    shard partial). Raises ``ValueError`` when the host exposes fewer
    devices than shards; under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` the cpu
    platform materializes N of them, which is how the CI fabric job runs
    48-way meshes on a 2-core box."""
    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    n = resolve_fabric_shards(n_shards, devices)
    if n > len(devices):
        raise ValueError(
            f"fabric_mesh: {n} shards but only {len(devices)} device(s); "
            f"force more with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (cpu platform)")
    return Mesh(np.asarray(devices[:n]), (FABRIC_AXIS,))


def maybe_init_distributed(coordinator: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None) -> bool:
    """Gated ``jax.distributed.initialize`` for multi-process fabrics.

    Configuration comes from the arguments or the environment
    (``REPRO_FABRIC_COORDINATOR``, ``REPRO_FABRIC_NUM_PROCESSES``,
    ``REPRO_FABRIC_PROCESS_ID``). Returns True when the distributed
    runtime is (or already was) initialized, False when unconfigured or
    unsupported on this platform — the fabric worker CLI then falls back
    to file-based partial merging (``fabric.merge_partials``), which needs
    no cross-process runtime at all."""
    coordinator = coordinator or os.environ.get("REPRO_FABRIC_COORDINATOR")
    if num_processes is None:
        env = os.environ.get("REPRO_FABRIC_NUM_PROCESSES", "").strip()
        num_processes = int(env) if env else None
    if process_id is None:
        env = os.environ.get("REPRO_FABRIC_PROCESS_ID", "").strip()
        process_id = int(env) if env else None
    if not coordinator or num_processes is None or process_id is None:
        return False
    try:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
        return True
    except RuntimeError:
        # already initialized: idempotent success
        return True
    except Exception:
        return False


# TPU v5e-class hardware constants used by the roofline analysis.
HW = dict(
    peak_bf16_flops=197e12,      # per chip
    hbm_bandwidth=819e9,         # bytes/s per chip
    ici_bandwidth=50e9,          # bytes/s per link
    hbm_bytes=16 * 2**30,        # capacity per chip
    chips_single_pod=256,
    chips_multi_pod=512,
)
