"""Serving driver: batched prefill + decode with a KV cache.

Chunked (Sarathi-style) prefill for long prompts, continuous batched
decode, greedy sampling. CPU smoke configs execute end-to-end; full
configs run via the same code path on TPU.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def generate(cfg, params, prompts: np.ndarray, n_gen: int,
             greedy: bool = True, seed: int = 0):
    """prompts (B, S) -> generated tokens (B, n_gen)."""
    from repro.models import transformer as M
    b, s = prompts.shape
    max_len = s + n_gen
    cache, logits = M.prefill(cfg, params, jnp.asarray(prompts),
                              max_len=max_len)
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    key = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for i in range(n_gen):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits)[:, None].astype(jnp.int32)
    return np.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    from repro.models import layers as L

    if args.smoke or jax.default_backend() == "cpu":
        L.set_dtypes(jnp.float32, jnp.float32)
    bundle = get_arch(args.arch)
    assert bundle.family == "lm", "serve.py drives LM archs"
    cfg = bundle.smoke_config if args.smoke else bundle.config

    from repro.models import transformer as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s) "
          f"sample row: {toks[0][:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
