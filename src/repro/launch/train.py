"""Training driver: --arch/--shape selectable, checkpoint/restart, elastic
hooks, straggler watchdog, optional gradient compression.

On this CPU container it runs the *smoke* configs end-to-end (real data,
real optimizer, real checkpoints); on a TPU fleet the same driver runs the
full configs — the only difference is ``--smoke`` and the mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --smoke --steps 30
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", choices=["none", "bf16", "int8"],
                    default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    from repro.models import layers as L
    from repro.optim import adamw
    from repro.optim import compression as C
    from repro.runtime.straggler import StepTimeWatchdog

    if args.smoke or jax.default_backend() == "cpu":
        L.set_dtypes(jnp.float32, jnp.float32)

    bundle = get_arch(args.arch)
    cfg = bundle.smoke_config if args.smoke else bundle.config
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10),
                                warmup_steps=max(2, args.steps // 10))
    rng = jax.random.PRNGKey(0)

    if bundle.family == "lm":
        from repro.data.tokens import TokenStream
        from repro.models import transformer as M
        params = M.init_params(cfg, rng)
        stream = TokenStream(cfg.vocab, seed=1)
        batches = (stream.batch(args.batch, args.seq)
                   for _ in range(10**9))
        loss_fn = partial(M.loss_fn, cfg)
    elif bundle.family == "gnn":
        from repro.data.graphs import make_gnn_batch, random_graph
        from repro.models import gnn as M
        import dataclasses
        cfg = dataclasses.replace(cfg, d_in=32, d_out=5)
        params = M.init_params(cfg, rng)
        src, dst = random_graph(512, 2048, seed=1)
        fixed = make_gnn_batch(src, dst, 512, 32, n_classes=5, seed=1)
        batches = (fixed for _ in range(10**9))
        loss_fn = partial(M.loss_fn, cfg)
    else:
        from repro.data.recsys import CriteoLikeGenerator
        from repro.models import dlrm as M
        params = M.init_params(cfg, rng)
        gen = CriteoLikeGenerator(cfg.table_sizes, cfg.n_dense, cfg.hot, seed=1)
        batches = (gen.batch(args.batch) for _ in range(10**9))
        loss_fn = partial(M.loss_fn, cfg)

    opt_state = adamw.init(params)
    ef = None
    if args.compress == "int8":
        ef = C.init_error_feedback(params)

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and mgr.latest_step() is not None:
            (params, opt_state), start_step = mgr.restore((params, opt_state))
            print(f"resumed from step {start_step}")

    @jax.jit
    def step_plain(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        params, opt_state, om = adamw.apply(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    @jax.jit
    def step_int8(params, opt_state, ef, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        packed, ef = C.compress_int8_ef(grads, ef)
        grads = C.decompress_int8(packed)   # stands in for the DCN hop
        params, opt_state, om = adamw.apply(opt_cfg, params, grads, opt_state)
        return params, opt_state, ef, {"loss": loss, **om}

    watchdog = StepTimeWatchdog()
    losses = []
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        t0 = time.time()
        if args.compress == "int8":
            params, opt_state, ef, m = step_int8(params, opt_state, ef, batch)
        else:
            params, opt_state, m = step_plain(params, opt_state, batch)
        loss = float(m["loss"])
        straggle = watchdog.record(time.time() - t0)
        losses.append(loss)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {loss:.4f} lr {float(m['lr']):.2e} "
                  f"gnorm {float(m['grad_norm']):.3f}"
                  f"{' [straggler]' if straggle else ''}", flush=True)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, (params, opt_state))
    if mgr:
        mgr.save(args.steps, (params, opt_state))
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
