"""Step builders: one jit-able function per (arch × shape × mesh) cell.

``build_cell`` returns everything launch/dryrun.py and launch/train.py
need: the python callable, its abstract argument specs, and matching
in/out shardings — so a cell is lowered with

    jax.jit(fn, in_shardings=..., out_shardings=..., donate_argnums=...)
       .lower(*arg_specs).compile()
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import config_for_shape, get_arch, input_specs
from repro.models import dlrm as DLRM
from repro.models import gnn as GNN
from repro.models import transformer as TF
from repro.optim import adamw
from repro.parallel import sharding as SH


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    step_kind: str
    fn: Callable
    arg_specs: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    cfg: Any
    meta: Dict[str, Any]

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.arg_specs)


def _rep(mesh):
    return NamedSharding(mesh, P())


def _batched(mesh, dim0: int, ndim: int, tail_axis=None, tail_dim=None):
    """P(dp, ..., tail_axis at tail_dim) with divisibility fallbacks."""
    dp = SH.dp_axes(mesh)
    spec = [None] * ndim
    if dim0 % int(jnp.prod(jnp.asarray([mesh.shape[a] for a in dp]))) == 0:
        spec[0] = dp
    if tail_axis is not None and tail_dim is not None:
        spec[tail_dim] = tail_axis
    return NamedSharding(mesh, P(*spec))


OPT_CFG = adamw.AdamWConfig()


def _with_rules(fn, mesh, family):
    """Activate the family's activation-sharding rules at trace time."""
    def wrapped(*args):
        SH.set_rules(mesh, family)
        try:
            return fn(*args)
        finally:
            SH.set_rules(None, None)
    return wrapped


def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               smoke: bool = False, cfg_transform: Optional[Callable] = None
               ) -> Cell:
    bundle = get_arch(arch_id)
    cfg = config_for_shape(arch_id, shape_name, smoke=smoke)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    step_kind, in_specs = input_specs(arch_id, shape_name, smoke=smoke,
                                      cfg=cfg)
    fam = bundle.family

    if fam == "lm":
        cell = _build_lm(arch_id, shape_name, step_kind, cfg, in_specs, mesh)
    elif fam == "gnn":
        cell = _build_gnn(arch_id, shape_name, step_kind, cfg, in_specs, mesh)
    elif fam == "recsys":
        cell = _build_dlrm(arch_id, shape_name, step_kind, cfg, in_specs, mesh)
    else:
        raise ValueError(fam)
    cell.fn = _with_rules(cell.fn, mesh, fam)
    return cell


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _build_lm(arch_id, shape_name, step_kind, cfg, in_specs, mesh) -> Cell:
    shapes_tree = TF.param_shapes(cfg)
    p_specs = TF.param_specs(cfg)
    p_shard = SH.lm_param_sharding(mesh, shapes_tree)

    if step_kind == "train":
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: TF.loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt_state, om = adamw.apply(OPT_CFG, params, grads,
                                                opt_state)
            return params, opt_state, {"loss": loss, **metrics, **om}

        o_specs = jax.eval_shape(adamw.init, p_specs)
        o_shard = SH.opt_state_sharding(p_shard, o_specs)
        b_shard = SH.lm_batch_sharding(mesh, in_specs)
        metrics_shard = {k: _rep(mesh) for k in
                         ("loss", "nll", "aux", "grad_norm", "lr")}
        return Cell(arch_id, shape_name, step_kind, train_step,
                    (p_specs, o_specs, in_specs),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, metrics_shard),
                    donate_argnums=(0, 1), cfg=cfg,
                    meta=dict(tokens=int(jnp.prod(jnp.asarray(
                        in_specs["tokens"].shape)))))

    if step_kind == "prefill":
        b, s = in_specs["tokens"].shape
        if s >= 8192 and getattr(cfg, "attn_q_chunk", None) is None:
            # boxed attention by default for long prefill (§Perf qwen2 v1:
            # peak 3808 -> 60 GiB/dev, collective term 104 -> 27 s)
            import dataclasses
            cfg = dataclasses.replace(cfg, attn_q_chunk=1024)

        def prefill_step(params, tokens):
            return TF.prefill(cfg, params, tokens)

        cache_specs = TF.cache_specs(cfg, b, s)
        c_shard = SH.lm_cache_sharding(mesh, cache_specs)
        tok_shard = _batched(mesh, b, 2)
        logits_shard = _batched(mesh, b, 2, "model", 1)
        return Cell(arch_id, shape_name, step_kind, prefill_step,
                    (p_specs, in_specs["tokens"]),
                    (p_shard, tok_shard),
                    (c_shard, logits_shard),
                    donate_argnums=(), cfg=cfg,
                    meta=dict(tokens=b * s))

    if step_kind == "decode":
        b, _ = in_specs["token"].shape
        # cache max_len: read from the cache specs (k: (L,B,S,kv,dh))
        leaf = jax.tree_util.tree_leaves(in_specs["cache"])[0]
        max_len = leaf.shape[2] if leaf.ndim >= 4 else leaf.shape[1]

        def serve_step(params, cache, token, pos):
            return TF.decode_step(cfg, params, cache, token, pos)

        c_shard = SH.lm_cache_sharding(mesh, in_specs["cache"])
        tok_shard = _batched(mesh, b, 2)
        pos_shard = _rep(mesh)
        logits_shard = _batched(mesh, b, 2, "model", 1)
        return Cell(arch_id, shape_name, step_kind, serve_step,
                    (p_specs, in_specs["cache"], in_specs["token"],
                     in_specs["pos"]),
                    (p_shard, c_shard, tok_shard, pos_shard),
                    (logits_shard, c_shard),
                    donate_argnums=(1,), cfg=cfg,
                    meta=dict(tokens=b, kv_len=max_len))

    raise ValueError(step_kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _build_gnn(arch_id, shape_name, step_kind, cfg, in_specs, mesh) -> Cell:
    shapes_tree = GNN.param_shapes(cfg)
    p_specs = GNN.param_specs(cfg)
    p_shard = SH.gnn_param_sharding(mesh, shapes_tree)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: GNN.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, om = adamw.apply(OPT_CFG, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    o_specs = jax.eval_shape(adamw.init, p_specs)
    o_shard = SH.opt_state_sharding(p_shard, o_specs)
    b_shard = SH.gnn_batch_sharding(mesh, in_specs)
    metrics_shard = {k: _rep(mesh) for k in ("loss", "grad_norm", "lr")}
    n_edges = in_specs["edge_src"].shape[0]
    return Cell(arch_id, shape_name, step_kind, train_step,
                (p_specs, o_specs, in_specs),
                (p_shard, o_shard, b_shard),
                (p_shard, o_shard, metrics_shard),
                donate_argnums=(0, 1), cfg=cfg,
                meta=dict(n_edges=n_edges,
                          n_nodes=in_specs["node_feat"].shape[0]))


# ---------------------------------------------------------------------------
# DLRM cells
# ---------------------------------------------------------------------------

def _build_dlrm(arch_id, shape_name, step_kind, cfg, in_specs, mesh) -> Cell:
    shapes_tree = DLRM.param_shapes(cfg)
    p_specs = DLRM.param_specs(cfg)
    p_shard = SH.dlrm_param_sharding(mesh, shapes_tree)
    b_shard = SH.dlrm_batch_sharding(mesh, in_specs)
    dp = SH.dp_axes(mesh)

    if step_kind == "train":
        if getattr(cfg, "sparse_optimizer", False):
            train_step = DLRM.make_sparse_train_step(cfg, OPT_CFG)
        else:
            def train_step(params, opt_state, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: DLRM.loss_fn(cfg, p, batch), has_aux=True)(params)
                params, opt_state, om = adamw.apply(OPT_CFG, params, grads,
                                                    opt_state)
                return params, opt_state, {"loss": loss, **om}

        o_specs = jax.eval_shape(adamw.init, p_specs)
        o_shard = SH.opt_state_sharding(p_shard, o_specs)
        if getattr(cfg, "shard_moments_2d", False):
            # ZeRO-for-embeddings: moments (V, D) shard (model, dp) — the
            # optimizer state of the 24B tables divides by the full mesh
            dp = SH.dp_axes(mesh)
            def _m2(path_shard):
                flat, tdef = jax.tree_util.tree_flatten_with_path(path_shard)
                out = []
                for path, ns in flat:
                    name = str(path[-1].key) if path else ""
                    if name.startswith("table"):
                        ns = NamedSharding(mesh, P("model", dp))
                    out.append(ns)
                return jax.tree_util.tree_unflatten(tdef, out)
            o_shard = adamw.OptState(o_shard.step, _m2(o_shard.m),
                                     _m2(o_shard.v))
        metrics_shard = {k: _rep(mesh) for k in ("loss", "grad_norm", "lr")}
        return Cell(arch_id, shape_name, step_kind, train_step,
                    (p_specs, o_specs, in_specs),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, metrics_shard),
                    donate_argnums=(0, 1), cfg=cfg,
                    meta=dict(batch=in_specs["dense"].shape[0]))

    if step_kind == "serve":
        def serve_step(params, batch):
            return DLRM.serve_step(cfg, params, batch)

        out_shard = NamedSharding(mesh, P(dp))
        return Cell(arch_id, shape_name, step_kind, serve_step,
                    (p_specs, in_specs), (p_shard, b_shard), out_shard,
                    donate_argnums=(), cfg=cfg,
                    meta=dict(batch=in_specs["dense"].shape[0]))

    if step_kind == "retrieval":
        def retrieval_step(params, batch):
            return DLRM.retrieval_score(cfg, params, batch)

        out_shard = (_rep(mesh), _rep(mesh))
        return Cell(arch_id, shape_name, step_kind, retrieval_step,
                    (p_specs, in_specs), (p_shard, b_shard), out_shard,
                    donate_argnums=(), cfg=cfg,
                    meta=dict(candidates=in_specs["candidates"].shape[0]))

    raise ValueError(step_kind)
