import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: measure named variants of the three chosen
cells and append the hypothesis → change → before/after log.

  PYTHONPATH=src python -m repro.launch.perf [--cell qwen2_prefill] [--force]

Variants are (name, cfg_transform) pairs; every measurement goes through
the same dry-run pipeline (compile + memory/cost/collective analysis +
scan-probe extrapolation) into results/perf/.
"""

import argparse
import dataclasses
import json
from pathlib import Path

OUT = Path("results/perf")


def qwen2_prefill_variants():
    return [
        ("v1_qchunk1024", lambda c: dataclasses.replace(c, attn_q_chunk=1024)),
        ("v2_qchunk2048", lambda c: dataclasses.replace(c, attn_q_chunk=2048)),
        ("v3_qchunk512", lambda c: dataclasses.replace(c, attn_q_chunk=512)),
    ]


def deepseek_train_variants():
    return [
        ("v1_sortdispatch",
         lambda c: dataclasses.replace(c, moe_impl="gathered_sort")),
        ("v2_sort_qchunk",
         lambda c: dataclasses.replace(c, moe_impl="gathered_sort",
                                       attn_q_chunk=1024)),
        # v3 = v1 + device-local dispatch scatters (moe_x_local rule;
        # code change in moe_ffn_sorted)
        ("v3_sort_localdisp",
         lambda c: dataclasses.replace(c, moe_impl="gathered_sort")),
    ]


def dlrm_train_variants():
    return [
        ("v1_sparse_opt",
         lambda c: dataclasses.replace(c, sparse_optimizer=True)),
        # v2 = v1 + replicated row-update constraint (code change in
        # dlrm.make_sparse_train_step guarded by the dlrm_rows rule)
        ("v2_sparse_opt_repl",
         lambda c: dataclasses.replace(c, sparse_optimizer=True)),
        ("v3_sparse_zero_moments",
         lambda c: dataclasses.replace(c, sparse_optimizer=True,
                                       shard_moments_2d=True)),
    ]


CELLS = {
    "qwen2_prefill": ("qwen2-7b", "prefill_32k", qwen2_prefill_variants),
    "deepseek_train": ("deepseek-v2-236b", "train_4k", deepseek_train_variants),
    "dlrm_train": ("dlrm-mlperf", "train_batch", dlrm_train_variants),
}


def summarize(rec):
    if not rec.get("ok"):
        return f"FAIL {rec.get('error', '')[:120]}"
    gb = (rec.get("temp_size_in_bytes", 0) +
          rec.get("argument_size_in_bytes", 0)) / 2**30
    return (f"comp={rec['t_compute_s']:.3f}s mem={rec['t_memory_s']:.3f}s "
            f"coll={rec['t_collective_s']:.3f}s [{rec['bottleneck']}] "
            f"useful={rec.get('useful_flops_ratio', 0):.3f} "
            f"peak={gb:.1f}GiB/dev")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    OUT.mkdir(parents=True, exist_ok=True)
    for key in ([args.cell] if args.cell else list(CELLS)):
        arch, shape, variants = CELLS[key]
        base = run_cell(arch, shape, "single", OUT, force=args.force,
                        probes=True, variant="baseline")
        print(f"{key}/baseline: {summarize(base)}", flush=True)
        for vname, tf in variants():
            rec = run_cell(arch, shape, "single", OUT, force=args.force,
                           probes=True, cfg_transform=tf, variant=vname)
            print(f"{key}/{vname}: {summarize(rec)}", flush=True)


if __name__ == "__main__":
    main()
