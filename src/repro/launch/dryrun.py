import os
# Only force the fake-device count when the caller has not already pinned
# one (the fabric CI job runs with 48; tests reload this module under
# their own XLA_FLAGS). XLA reads the flag at first backend init, so the
# guard must run at import time, before any jax device call.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (and stores under results/dryrun/):
  * compile success (the deliverable gate),
  * memory_analysis()  — per-device argument/output/temp/peak bytes,
  * cost_analysis()    — HLO flops & bytes (per partitioned device program),
  * collective bytes   — parsed from the compiled HLO: Σ operand bytes of
    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute (async -start forms counted once),
  * the three roofline terms vs TPU v5e constants (launch.mesh.HW).

Usage:
  python -m repro.launch.dryrun --all                 # 40 cells × 2 meshes
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all --mesh single --force
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
          "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
          "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9_,\[\]{} ]*\)?)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Per-opcode result-shape bytes of every collective in the compiled
    (per-device SPMD) HLO. Result bytes ≈ bytes moved per device for
    all-gather/all-to-all/collective-permute, and ≈ half the ring traffic
    for all-reduce; reduce-scatter results under-count by the group size —
    the accounting convention is recorded in EXPERIMENTS.md §Roofline.
    Async ``-start`` forms print a (operand, result) tuple: the largest
    shape is taken; ``-done`` lines carry no opcode match and are skipped.
    Scan bodies appear once; launch.dryrun extrapolates by trip count."""
    out = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1 + 1)
        shapes = _SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        total = max(_shape_bytes(d, s) for d, s in shapes)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += total
    return out


def model_flops_for(cell) -> float:
    """MODEL_FLOPS: 6·N·D for LM (N = active params), analytic for others."""
    cfg = cell.cfg
    if cell.step_kind in ("train",) and hasattr(cfg, "active_params_count"):
        n = cfg.active_params_count()
        toks = cell.meta.get("tokens", 0)
        return 6.0 * n * toks
    if cell.step_kind == "prefill" and hasattr(cfg, "active_params_count"):
        return 2.0 * cfg.active_params_count() * cell.meta.get("tokens", 0)
    if cell.step_kind == "decode" and hasattr(cfg, "active_params_count"):
        return 2.0 * cfg.active_params_count() * cell.meta.get("tokens", 0)
    if hasattr(cfg, "kind"):  # GNN: ~6 · E · d_hidden² per MP layer (train)
        e = cell.meta.get("n_edges", 0)
        nn = cell.meta.get("n_nodes", 0)
        mults = {"gcn": 1, "gin": 2, "schnet": 4, "graphcast": 6}
        per = mults.get(cfg.kind, 2) * cfg.d_hidden * cfg.d_hidden
        fwd = (e + nn) * per * cfg.n_layers * 2
        return 3.0 * fwd  # fwd + bwd ~ 3x
    if hasattr(cfg, "table_sizes"):  # DLRM: MLP flops dominate
        b = cell.meta.get("batch", cell.meta.get("candidates", 0))
        dims = [cfg.n_dense] + list(cfg.bot_mlp)
        f = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        n_int = cfg.n_sparse + 1
        d_top = cfg.embed_dim + n_int * (n_int - 1) // 2
        dims = [d_top] + list(cfg.top_mlp)
        f += sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        f += n_int * n_int * cfg.embed_dim  # interaction
        mult = 6.0 if cell.step_kind == "train" else 2.0
        return mult * b * f
    return 0.0


def _scan_repeats(cfg) -> int:
    """Trip count of the layer scan (1 => no extrapolation needed)."""
    if hasattr(cfg, "n_repeats"):
        return int(cfg.n_repeats)
    if getattr(cfg, "kind", None) == "graphcast":
        return int(cfg.n_layers)
    return 1


def _repeats_transform(cfg, k: int):
    """Probe config: k scan repeats, scan fully unrolled so XLA cost
    analysis sees every layer (while bodies are otherwise counted once —
    the k=1/k=2 delta of *unrolled* probes is the exact per-layer cost)."""
    import dataclasses
    if hasattr(cfg, "n_repeats"):
        return dataclasses.replace(
            cfg, n_layers=len(cfg.prefix) + len(cfg.pattern) * k,
            scan_unroll=True)
    if getattr(cfg, "kind", None) == "graphcast":
        return dataclasses.replace(cfg, n_layers=k, scan_unroll=True)
    return cfg


def _measure(cell) -> tuple:
    """(flops, bytes, collectives-dict) of a compiled cell, per device."""
    lowered = cell.lower()
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: Path, smoke: bool = False, force: bool = False,
             probes: bool = True, cfg_transform=None, variant: str = "") -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import HW, make_production_mesh
    from repro.launch.steps import build_cell

    tag = f"{arch_id}__{shape_name}__{mesh_kind}" + ("__smoke" if smoke else "")
    if variant:
        tag += f"__{variant}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "ok": False}
    if variant:
        rec["variant"] = variant
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_chips = mesh.devices.size
        cell = build_cell(arch_id, shape_name, mesh, smoke=smoke,
                          cfg_transform=cfg_transform)
        with mesh:
            lowered = cell.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)

        rec.update(ok=True, lower_s=round(t1 - t0, 2),
                   compile_s=round(t2 - t1, 2), n_chips=int(n_chips))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        rec["peak_bytes_per_device"] = int(
            getattr(mem, "temp_size_in_bytes", 0) or 0) + int(
            getattr(mem, "argument_size_in_bytes", 0) or 0)
        flops_dev = float(cost.get("flops", 0.0)) if cost else 0.0
        bytes_dev = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
        rec["hlo_flops_per_device_raw"] = flops_dev
        rec["hlo_bytes_per_device_raw"] = bytes_dev
        rec["collectives"] = coll

        # XLA cost analysis counts `while` (scan) bodies ONCE regardless of
        # trip count. Two probe compiles at n_repeats = 1 and 2 give the
        # per-layer deltas; linear extrapolation recovers the full program
        # (exact for homogeneous scanned layers; see EXPERIMENTS.md).
        r = _scan_repeats(cell.cfg)
        rec["scan_repeats"] = r
        if probes and r > 2:
            # probes at k=2 and k=3 (k=1 lets XLA squeeze the layer axis
            # and change partitioning decisions): body = m3 - m2,
            # total(R) = m2 + (R-2)·body — exact for homogeneous layers.
            def _probe_tf(k):
                def tf(c):
                    if cfg_transform is not None:
                        c = cfg_transform(c)
                    return _repeats_transform(c, k)
                return tf

            cell2 = build_cell(arch_id, shape_name, mesh, smoke=smoke,
                               cfg_transform=_probe_tf(2))
            cell3 = build_cell(arch_id, shape_name, mesh, smoke=smoke,
                               cfg_transform=_probe_tf(3))
            with mesh:
                f2, b2, c2 = _measure(cell2)
                f3, b3, c3 = _measure(cell3)
            flops_dev = max(f2 + (r - 2) * (f3 - f2), flops_dev)
            bytes_dev = max(b2 + (r - 2) * (b3 - b2), bytes_dev)
            coll_x = {}
            ops = set(c2) | set(c3) | set(coll)
            for op in ops:
                v2 = c2.get(op, {"count": 0, "bytes": 0})
                v3 = c3.get(op, {"count": 0, "bytes": 0})
                coll_x[op] = {
                    "count": max(0, v2["count"] + (r - 2) * (v3["count"] - v2["count"])),
                    "bytes": max(0, v2["bytes"] + (r - 2) * (v3["bytes"] - v2["bytes"])),
                }
            coll = coll_x
            rec["probe_flops"] = [f2, f3]
            rec["collectives_extrapolated"] = coll
        rec["hlo_flops_per_device"] = flops_dev
        rec["hlo_bytes_per_device"] = bytes_dev
        coll_bytes = sum(v["bytes"] for v in coll.values())
        rec["collective_bytes_per_device"] = coll_bytes
        rec["model_flops_global"] = model_flops_for(cell)

        # roofline terms (seconds): per-device work vs per-chip peaks —
        # chips factor already absorbed because the partitioned HLO is the
        # per-device program (EXPERIMENTS.md §Roofline, 'accounting').
        rec["t_compute_s"] = flops_dev / HW["peak_bf16_flops"]
        rec["t_memory_s"] = bytes_dev / HW["hbm_bandwidth"]
        rec["t_collective_s"] = coll_bytes / HW["ici_bandwidth"]
        terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
                 "collective": rec["t_collective_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        total_hlo_flops = flops_dev * n_chips
        rec["useful_flops_ratio"] = (rec["model_flops_global"] /
                                     total_hlo_flops) if total_hlo_flops else 0.0
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    status = "OK" if rec["ok"] else "FAIL"
    print(f"[{status}] {tag} wall={rec['wall_s']}s "
          f"{'err=' + rec.get('error', '') if not rec['ok'] else ''}",
          flush=True)
    return rec


def fabric_dryrun(out_dir: Path, *, n_shards: int = 4,
                  pattern: str = "triangle", nv: int = 96, ne: int = 400,
                  mem_words: int = 1 << 12, seed: int = 7) -> dict:
    """Smoke the distributed box fabric's planning path without touching
    any device: plan the query, schedule boxes over ``n_shards`` host
    partitions, and record the shipped byte-range layout per shard. No
    shard is executed and no mesh is built, so this runs on a bare host
    with zero accelerators — the dry-run analogue of the compile-only
    gate above."""
    from repro.data.graphs import random_graph
    from repro.parallel.fabric import Fabric
    from repro.query.patterns import PATTERNS

    t0 = time.time()
    src, dst = random_graph(nv, ne, seed=seed)
    fab = Fabric.from_graph(PATTERNS[pattern](), src, dst,
                            n_shards=n_shards, mem_words=mem_words)
    rec = fab.describe()
    rec.update(ok=True, pattern=pattern, nv=int(nv), ne=int(ne),
               wall_s=round(time.time() - t0, 2))
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"fabric__{pattern}__s{n_shards}"
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    print(f"[OK] {tag} boxes={rec['n_boxes']} shards={rec['n_shards']} "
          f"wall={rec['wall_s']}s", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--fabric", action="store_true",
                    help="smoke the box-fabric planning path (no devices)")
    ap.add_argument("--fabric-shards", type=int, default=4)
    args = ap.parse_args()

    if args.fabric:
        rec = fabric_dryrun(Path(args.out), n_shards=args.fabric_shards)
        return 0 if rec["ok"] else 1

    from repro.configs import all_arch_ids, get_arch

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for aid in all_arch_ids():
            for shp in get_arch(aid).shape_names():
                cells.append((aid, shp))
    else:
        aid = args.arch
        shapes = [args.shape] if args.shape else get_arch(aid).shape_names()
        cells = [(aid, s) for s in shapes]

    n_ok = n_fail = 0
    for aid, shp in cells:
        for mk in meshes:
            # roofline probes (2 extra compiles) only for the single-pod
            # mesh — §Roofline is single-pod; multi-pod proves sharding.
            rec = run_cell(aid, shp, mk, out_dir, smoke=args.smoke,
                           force=args.force, probes=(mk == "single"))
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
