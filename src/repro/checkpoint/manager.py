"""Checkpointing: sharded, atomic, keep-k, async — restart-safe.

Design (1000-node posture, DESIGN.md §5):
  * params/opt-state pytrees are flattened to name->array; each host saves
    its addressable shards (here: the full array on the single-host sim);
  * writes go to ``step_<n>.tmp/`` then os.replace() to ``step_<n>/`` —
    a crashed save can never be mistaken for a complete one;
  * ``manifest.json`` records step, tree structure and array metadata and
    is written last, so restore never sees a partial checkpoint;
  * async mode hands the (host-copied) arrays to a writer thread — the
    train loop continues; ``wait()`` joins before the next save;
  * keep-k garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        flat = _flatten(tree)     # host copies happen here, synchronously
        treedef = jax.tree_util.tree_structure(tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, str(treedef), extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, str(treedef), extra)

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               treedef: str, extra: Optional[dict]) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)    # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (values replaced)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        data = np.load(path / "arrays.npz")
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = "/".join(_path_str(x) for x in p)
            arr = data[key]
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        return tree, step
