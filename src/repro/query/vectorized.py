"""Vectorized (batched) leapfrog primitives for the generic QueryEngine.

The scalar reference (``core.leapfrog.LeapfrogTriejoin``) walks the binding
trie one value at a time. This module replaces that inner loop with a
*frontier* formulation: all partial bindings at one depth are held as
columns of a matrix, and one variable is expanded for the whole frontier at
once with numpy ``searchsorted`` kernels — the same lifted-key idiom as the
triangle executor's GIL-releasing host lane (``StreamingExecutor._count_host``),
so worker threads of the shared box scheduler scale on CPU hosts.

Per depth ``d`` of the variable order:

* atoms whose *second* variable is ``d`` expand the frontier (candidates =
  the adjacency row of the bound first endpoint) and then prune it (every
  further such atom is a batched membership probe into its lifted CSR);
* atoms whose *first* variable is ``d`` contribute their key set (vertices
  with a non-empty in-range row) as a sorted-membership filter — the level
  the scalar LFTJ intersects lazily, applied eagerly here;
* at the innermost depth a count-only query never materializes bindings:
  one incident atom degenerates to a degree sum, two lower onto a pairwise
  sorted-intersection — the host lane's lifted ``searchsorted``, or the
  ``kernels/intersect`` Pallas op on TPU (``intersect_count_rows``) — and
  three or more materialize the pairwise intersection once and filter.

Frontiers are split recursively when the projected expansion exceeds
``chunk_entries``, so peak host memory is bounded by the chunk, not the
result size; splits preserve binding order, keeping counts, listings and
their order deterministic for any split points.

Every slice here is *box-local* (built by the executor from EdgeSource
reads already restricted to the box), so values never need re-clipping:
an atom's candidate values were filtered to its second variable's box
range at slice-build time.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lftj_jax import SENTINEL


@dataclass
class AtomSlice:
    """One atom's box-restricted relation in compact CSR form.

    ``keys`` are the sorted global vertex ids of the atom's first variable
    having at least one in-range value; ``off``/``vals`` the concatenated
    sorted in-range adjacency. ``stride`` lifts (row, value) pairs into
    disjoint int64 key ranges for the one-probe membership tests; it must
    clear the whole id domain (membership queries carry values from OTHER
    atoms' expansions, not just this slice's own), so it is the 2**31
    vertex-id ceiling the edge store enforces — row_pos · stride + value
    stays well inside int64 for any slice.
    """

    keys: np.ndarray                     # int64, sorted
    off: np.ndarray                      # int64, len(keys) + 1
    vals: np.ndarray                     # int32
    stride: int = 1 << 31
    _lifted: Optional[np.ndarray] = None

    @property
    def n_keys(self) -> int:
        return len(self.keys)

    @property
    def words(self) -> int:
        return len(self.vals) + len(self.off)

    @property
    def deg(self) -> np.ndarray:
        return np.diff(self.off)

    @property
    def lifted(self) -> np.ndarray:
        """Row-position-lifted sorted value keys (built once per box)."""
        if self._lifted is None:
            rid = np.repeat(np.arange(self.n_keys, dtype=np.int64),
                            self.deg)
            self._lifted = rid * self.stride + self.vals
        return self._lifted


def build_atom_slice(ip_local: np.ndarray, vals: np.ndarray, row_lo: int,
                     val_lo: Optional[int] = None,
                     val_hi: Optional[int] = None) -> AtomSlice:
    """AtomSlice for rows ``row_lo..row_lo+len(ip_local)-2`` with values
    optionally restricted to ``[val_lo, val_hi]`` (the second variable's
    box range)."""
    ip_local = np.asarray(ip_local, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.int32)
    n_rows = len(ip_local) - 1
    deg = np.diff(ip_local)
    if val_lo is not None or val_hi is not None:
        lo = -1 if val_lo is None else int(val_lo)
        hi = np.iinfo(np.int64).max if val_hi is None else int(val_hi)
        rid = np.repeat(np.arange(n_rows), deg)
        mask = (vals >= lo) & (vals <= hi)
        deg = np.bincount(rid[mask], minlength=n_rows).astype(np.int64)
        vals = vals[mask]
    keep = deg > 0
    keys = (row_lo + np.flatnonzero(keep)).astype(np.int64)
    off = np.concatenate([np.zeros(1, np.int64),
                          np.cumsum(deg[keep], dtype=np.int64)])
    return AtomSlice(keys=keys, off=off, vals=vals)


# ---------------------------------------------------------------------------
# batched probes (host lane)
# ---------------------------------------------------------------------------

def in_sorted(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Boolean membership of each query in the sorted unique ``keys``."""
    if len(keys) == 0 or len(queries) == 0:
        return np.zeros(len(queries), dtype=bool)
    pos = np.searchsorted(keys, queries)
    np.minimum(pos, len(keys) - 1, out=pos)
    return keys[pos] == queries


def row_lookup(slc: AtomSlice, u: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(positions, present) of vertex ids ``u`` in ``slc.keys``."""
    if slc.n_keys == 0 or len(u) == 0:
        return np.zeros(len(u), dtype=np.int64), np.zeros(len(u), dtype=bool)
    pos = np.searchsorted(slc.keys, u)
    np.minimum(pos, slc.n_keys - 1, out=pos)
    return pos, slc.keys[pos] == u


def gather(slc: AtomSlice, pos: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray]:
    """(deg, concatenated values, source index per value) for key
    positions ``pos`` (each must be a valid key position)."""
    deg = slc.deg[pos]
    total = int(deg.sum())
    if total == 0:
        return deg, np.zeros(0, np.int32), np.zeros(0, np.int64)
    starts = slc.off[pos]
    idx = np.repeat(starts, deg) + np.arange(total, dtype=np.int64) \
        - np.repeat(np.cumsum(deg) - deg, deg)
    rep = np.repeat(np.arange(len(pos), dtype=np.int64), deg)
    return deg, slc.vals[idx], rep


def member_rows(slc: AtomSlice, pos: np.ndarray,
                values: np.ndarray) -> np.ndarray:
    """Per (row-position, value) pair: value ∈ row? One lifted probe."""
    if len(pos) == 0:
        return np.zeros(0, dtype=bool)
    lifted = slc.lifted
    if len(lifted) == 0:
        return np.zeros(len(pos), dtype=bool)
    q = pos.astype(np.int64) * slc.stride + values.astype(np.int64)
    p = np.searchsorted(lifted, q)
    np.minimum(p, len(lifted) - 1, out=p)
    return lifted[p] == q


def intersect_rows_host(a: AtomSlice, pos_a: np.ndarray,
                        b: AtomSlice, pos_b: np.ndarray,
                        counts_only: bool = False):
    """Pairwise row intersections: for each i, row ``pos_a[i]`` of ``a``
    against row ``pos_b[i]`` of ``b`` (positions must be valid).

    ``counts_only`` returns the total match count; otherwise
    ``(pair_ids, values)`` of every intersection element, in pair-major
    ascending-value order. The smaller side is probed into the larger
    (the min(d_x, d_y) accounting of Thm. 17)."""
    _, av, ra = gather(a, pos_a)
    _, bv, rb = gather(b, pos_b)
    stride = np.int64(max(int(av.max(initial=0)), int(bv.max(initial=0))) + 1)
    ak = ra * stride + av
    bk = rb * stride + bv
    small_v, small_r = av, ra
    if len(ak) > len(bk):
        ak, bk = bk, ak
        small_v, small_r = bv, rb
    if len(ak) == 0 or len(bk) == 0:
        if counts_only:
            return 0
        return np.zeros(0, np.int64), np.zeros(0, np.int32)
    p = np.searchsorted(bk, ak)
    np.minimum(p, len(bk) - 1, out=p)
    hit = bk[p] == ak
    if counts_only:
        return int(hit.sum())
    return small_r[hit], small_v[hit]


# ---------------------------------------------------------------------------
# the frontier machine
# ---------------------------------------------------------------------------

@dataclass
class BoundAtom:
    """An atom as the box executor sees it: its slice plus the dims of its
    first/second variable in the chosen order."""

    first_dim: int
    second_dim: int
    slc: AtomSlice


class VectorizedBoxJoin:
    """Execute one box of a binary-atom conjunctive query.

    ``mode`` is ``"count"`` or ``"list"``; ``kernel_lane`` lowers the
    innermost two-atom intersection onto ``kernels/intersect`` (Pallas on
    TPU, interpret elsewhere) instead of the host ``searchsorted`` lane.

    ``device`` picks the box-level lane: ``"host"`` (this module's staged
    per-level frontier machine) or ``"fused"``, which dispatches the
    *whole box* to the ``kernels/lftj_fused`` megakernel — one device
    invocation per box instead of one per frontier level. Boxes or
    patterns outside the fused kernel's static envelope (depth bound,
    unbound intermediate variable, VMEM budget) transparently fall back
    to the staged path; ``used_fused`` records which lane actually ran.

    ``capacity`` bounds the materialized listing buffer: at most that many
    binding rows are kept (``emitted``), while ``count`` stays the *exact*
    result count — the caller detects overflow from ``count > capacity``
    and rescans at doubled capacity, exactly the triangle engine's
    overflow→rescan protocol. Emitted rows are always the deterministic
    prefix of the full binding order, so a rescan extends, never reorders
    (the fused lane has its own fixed traversal order with the same
    prefix guarantee).
    """

    def __init__(self, atoms: Sequence[BoundAtom], n_vars: int,
                 mode: str = "count", *,
                 kernel_lane: bool = False,
                 use_pallas: bool = True,
                 interpret: bool = True,
                 device: str = "host",
                 chunk_entries: int = 4_000_000,
                 capacity: Optional[int] = None):
        if device not in ("host", "fused"):
            raise ValueError(f"unknown device lane {device!r}")
        self.n = n_vars
        self.mode = mode
        self.kernel_lane = kernel_lane
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.device = device
        self.chunk_entries = int(chunk_entries)
        self.capacity = None if capacity is None else int(capacity)
        self.atoms = list(atoms)
        self.by_second: List[List[BoundAtom]] = [[] for _ in range(n_vars)]
        self.by_first: List[List[BoundAtom]] = [[] for _ in range(n_vars)]
        for a in atoms:
            self.by_second[a.second_dim].append(a)
            self.by_first[a.first_dim].append(a)
        self.count = 0
        self.emitted = 0
        self.rows_out: List[np.ndarray] = []
        self.used_kernel = False
        self.used_fused = False
        self.max_frontier = 0

    # -- public --------------------------------------------------------------

    def run(self):
        """Returns the result count; ``rows_out`` holds the bindings
        (columns in variable order) when ``mode == 'list'``."""
        if self.device == "fused" and self._run_fused():
            return self.count
        cand = self._key_intersection(self.by_first[0])
        if len(cand) == 0:
            return 0
        self._eval(1, [cand])
        return self.count

    def _run_fused(self) -> bool:
        """Whole-box dispatch to the fused megakernel; False -> the box
        is outside its envelope and the staged path should run."""
        from repro.kernels.lftj_fused.ops import (FusedUnsupported,
                                                  fused_count, fused_list,
                                                  fused_supported)

        dims = [(a.first_dim, a.second_dim) for a in self.atoms]
        if fused_supported(dims, self.n) is not None:
            return False
        csrs = [(a.slc.keys, a.slc.off, a.slc.vals) for a in self.atoms]
        try:
            if self.mode == "count":
                self.count = fused_count(dims, csrs, self.n,
                                         interpret=self.interpret)
            else:
                cap = self.capacity
                if cap is None:
                    # unbounded listing: probe at a small cap, then rerun
                    # sized to the exact total the probe returned
                    total, rows = fused_list(dims, csrs, self.n,
                                             capacity=1024,
                                             interpret=self.interpret)
                    if total > 1024:
                        total, rows = fused_list(dims, csrs, self.n,
                                                 capacity=total,
                                                 interpret=self.interpret)
                else:
                    total, rows = fused_list(dims, csrs, self.n,
                                             capacity=cap,
                                             interpret=self.interpret)
                self.count = total
                self.emitted = len(rows)
                if len(rows):
                    self.rows_out = [rows]
        except FusedUnsupported:
            return False
        self.used_fused = True
        return True

    def bindings(self) -> np.ndarray:
        if not self.rows_out:
            return np.zeros((0, self.n), dtype=np.int64)
        return np.concatenate(self.rows_out, axis=0)

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _key_intersection(atoms: Sequence[BoundAtom]) -> np.ndarray:
        cand = None
        for a in atoms:
            k = a.slc.keys
            cand = k if cand is None \
                else cand[in_sorted(k, cand)]
            if len(cand) == 0:
                break
        return cand if cand is not None else np.zeros(0, np.int64)

    def _eval(self, d: int, cols: List[np.ndarray]) -> None:
        n_f = len(cols[0])
        if n_f == 0:
            return
        self.max_frontier = max(self.max_frontier, n_f)
        bound = self.by_second[d]
        # projected expansion: split the frontier so the lifted arrays and
        # candidate buffers stay bounded regardless of the result size
        if n_f > 1 and bound:
            a0 = bound[0]
            pos, ok = row_lookup(a0.slc, cols[a0.first_dim])
            est = int(a0.slc.deg[pos[ok]].sum())
            if est > self.chunk_entries:
                mid = n_f // 2
                self._eval(d, [c[:mid] for c in cols])
                self._eval(d, [c[mid:] for c in cols])
                return
        if d == self.n - 1 and self.mode == "count":
            self._final_count(cols, bound)
            return
        rep, cand = self._expand(d, cols, bound)
        if len(cand) == 0:
            return
        if d == self.n - 1:
            # count is exact regardless of capacity; only the materialized
            # rows are clipped (deterministic prefix -> rescan-safe)
            self.count += len(cand)
            take = len(cand)
            if self.capacity is not None:
                take = min(take, self.capacity - self.emitted)
            if take > 0:
                new_cols = [c[rep[:take]] for c in cols] \
                    + [cand[:take].astype(np.int64)]
                self.emitted += take
                self.rows_out.append(np.stack(new_cols, axis=1))
            return
        new_cols = [c[rep] for c in cols] + [cand.astype(np.int64)]
        self._eval(d + 1, new_cols)

    def _expand(self, d: int, cols: List[np.ndarray],
                bound: Sequence[BoundAtom]):
        """Candidates for depth ``d``: (frontier index per candidate,
        candidate values), after every incident-atom filter."""
        starts = self.by_first[d]
        if bound:
            a0 = bound[0]
            pos, ok = row_lookup(a0.slc, cols[a0.first_dim])
            live = np.flatnonzero(ok)
            _, cand, rep_local = gather(a0.slc, pos[live])
            rep = live[rep_local]
            mask = np.ones(len(cand), dtype=bool)
            for ai in bound[1:]:
                pos_i, ok_i = row_lookup(ai.slc, cols[ai.first_dim][rep])
                mask &= ok_i & member_rows(ai.slc, pos_i, cand)
        else:
            # the variable only *starts* atoms here: candidates are the
            # intersection of their key sets, crossed with the frontier
            cand0 = self._key_intersection(starts)
            n_f = len(cols[0])
            rep = np.repeat(np.arange(n_f, dtype=np.int64), len(cand0))
            cand = np.tile(cand0, n_f)
            return rep, cand
        for aj in starts:
            mask &= in_sorted(aj.slc.keys, cand.astype(np.int64))
        return rep[mask], cand[mask]

    def _final_count(self, cols: List[np.ndarray],
                     bound: Sequence[BoundAtom]) -> None:
        """Innermost depth, count only: never materialize the bindings."""
        a0 = bound[0]
        pos0, ok0 = row_lookup(a0.slc, cols[a0.first_dim])
        if len(bound) == 1:
            self.count += int(a0.slc.deg[pos0[ok0]].sum())
            return
        a1 = bound[1]
        pos1, ok1 = row_lookup(a1.slc, cols[a1.first_dim])
        live = np.flatnonzero(ok0 & ok1)
        if len(live) == 0:
            return
        if len(bound) == 2:
            if self.kernel_lane:
                self.count += self._kernel_pair_count(
                    a0, pos0[live], a1, pos1[live])
            else:
                self.count += intersect_rows_host(
                    a0.slc, pos0[live], a1.slc, pos1[live],
                    counts_only=True)
            return
        # >= 3 incident atoms (e.g. the 4-clique's last variable):
        # materialize the pairwise intersection once, then filter
        pair_ids, values = intersect_rows_host(a0.slc, pos0[live],
                                               a1.slc, pos1[live])
        mask = np.ones(len(values), dtype=bool)
        for ai in bound[2:]:
            pos_i, ok_i = row_lookup(ai.slc,
                                     cols[ai.first_dim][live][pair_ids])
            mask &= ok_i & member_rows(ai.slc, pos_i, values)
        self.count += int(mask.sum())

    def _kernel_pair_count(self, a: BoundAtom, pos_a,
                           b: BoundAtom, pos_b) -> int:
        from repro.kernels.intersect.ops import intersect_count_rows

        self.used_kernel = True
        return intersect_count_rows(
            a.slc.off, a.slc.vals, pos_a,
            b.slc.off, b.slc.vals, pos_b,
            use_pallas=self.use_pallas, interpret=self.interpret)
