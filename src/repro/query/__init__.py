"""General-purpose join subsystem: boxed, out-of-core, multi-worker LFTJ
for arbitrary binary-atom conjunctive queries (paper §2 generalization).

``QueryEngine`` executes any validated ``core.queries.Query`` — 4-cliques,
diamonds, paths, cycles, the triangle as a special case — through the same
out-of-core machinery as ``core.engine.TriangleEngine``: degree-index box
planning under the Thm. 13 rank-r I/O bound (``planner``), per-atom slice
streaming over ``EdgeSource``/``SliceCache``/``BlockDevice`` with the PR-4
worker-pool scheduler (``executor``), and batched numpy/Pallas leapfrog
inner loops (``vectorized``). ``patterns`` holds the canonical pattern
queries.
"""

from . import patterns
from .executor import QueryEngine, QueryStats, query_count
from .planner import QueryPlan, plan_query_boxes, thm13_io_bound
from .vectorized import AtomSlice, BoundAtom, VectorizedBoxJoin, \
    build_atom_slice

__all__ = [
    "QueryEngine", "QueryStats", "query_count", "QueryPlan",
    "plan_query_boxes", "thm13_io_bound", "patterns", "AtomSlice",
    "BoundAtom", "VectorizedBoxJoin", "build_atom_slice",
]
