"""QueryEngine: boxed, out-of-core, multi-worker LFTJ for conjunctive queries.

The generic counterpart of ``core.engine.TriangleEngine``: any validated
``core.queries.Query`` over *binary* relations (graph patterns: 4-cliques,
diamonds, paths, cycles — and the triangle as a special case) executes
through the same out-of-core machinery the triangle engine uses:

* **planning** — ``query.planner.plan_query_boxes`` cuts the n-dimensional
  variable space into boxes from the *resident degree indexes* alone
  (never touching the neighbor streams), budgeted per Thm. 13's rank-r
  bound. The triangle special case reproduces the triangle planner's boxes
  cut for cut.
* **fetching** — per box, each owned dimension's row ranges are read
  through the relation's ``EdgeSource`` (``data.edgestore.EdgeStore`` on
  disk, ``InMemoryEdgeSource`` in RAM, optionally behind a
  ``core.executor.SliceCache``), with already-covered intervals deduped
  (§5 slice sharing) and a full-conjunctive early exit: an atom whose
  box-restricted slice is empty kills the box before further reads —
  byte-for-byte the read stream ``TriangleEngine`` issues on the triangle
  query, which is how ``tests/test_query_engine.py`` pins measured
  ``block_reads`` equality.
* **executing** — ``query.vectorized.VectorizedBoxJoin`` runs the batched
  leapfrog over the per-atom slices (numpy ``searchsorted`` lanes that
  release the GIL; innermost two-variable intersections optionally lower
  onto the ``kernels/intersect`` Pallas op).
* **scheduling** — boxes drain on the shared PR-4 worker pool
  (``core.executor.run_box_queue``) under the same workers=1-oracle
  determinism contract: serialized fetches in queue order, fixed box-order
  reduction, in-flight (boxes, words) window.

``TriangleEngine`` remains the specialized fast path; its golden counts
are the QueryEngine's oracle in the test suite.

Usage::

    from repro.query import QueryEngine, patterns

    eng = QueryEngine.from_graph(patterns.four_clique(), src, dst,
                                 mem_words=1 << 16)
    n   = eng.count()
    eng = QueryEngine(patterns.diamond(), store="graph.csr",
                      mem_words=1 << 16, workers=4)
    rows = eng.list()              # (m, 4) bindings in head order
    eng.stats                      # boxes, rank, I/O, cache, telemetry
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import (SliceCache, _pow2, merge_queue_telemetry,
                                 run_box_queue, run_box_serial)
from repro.core.iomodel import BlockDevice
from repro.core.leapfrog import Atom
from repro.core.lftj_jax import csr_from_edges, orient_edges
from repro.core.queries import Query, is_consistent, validate
from repro.data.edgestore import EdgeStore, InMemoryEdgeSource
from repro.parallel.sharding import (box_queue_order, interval_gaps,
                                     merge_interval)

from repro.kernels import ledger as kernel_ledger

from .planner import QueryPlan, plan_query_boxes
from .vectorized import BoundAtom, VectorizedBoxJoin, build_atom_slice

BACKENDS = ("auto", "host", "pallas", "fused")


@dataclass
class QueryStats:
    """One ``count()`` / ``list()`` run of the QueryEngine, faithfully:
    plan size and rank, backend lane mix, streaming working-set peaks,
    slice-cache hits, measured block I/O, and the shared box-scheduler
    telemetry (the ``merge_queue_telemetry`` contract)."""

    order: Tuple[str, ...] = ()
    rank: int = 0
    n_boxes: int = 0
    n_results: int = 0
    n_rescans: int = 0                 # bounded-listing overflow rescans
    # skew-aware planning (skew="heavy_light"): the plan's lane mix
    skew: str = "uniform"
    heavy_threshold: int = 0
    n_hub_boxes: int = 0
    n_light_boxes: int = 0
    n_mixed_boxes: int = 0
    # per-box execution
    n_streamed_boxes: int = 0
    slice_words_read: int = 0          # raw CSR words fetched across boxes
    max_slice_words: int = 0           # largest single-box fetch
    max_frontier: int = 0              # peak binding-frontier rows
    n_kernel_boxes: int = 0            # innermost pair on kernels/intersect
    n_host_boxes: int = 0              # innermost stage on the host lane
    n_fused_boxes: int = 0             # whole box on the fused megakernel
    # per-box device ledger (kernels/ledger): launches + padded transfer
    # bytes across every kernel lane — the measured basis of the fused
    # kernel's >=10x launch-reduction claim
    device_invocations: int = 0
    device_transfer_bytes: int = 0
    max_box_device_invocations: int = 0
    # async scheduler (workers > 1)
    n_workers: int = 1
    inflight_boxes: int = 0
    queue_wait_s: float = 0.0
    build_s: float = 0.0
    compute_s: float = 0.0
    overlap_s: float = 0.0
    # busy/(pool*wall); None when the run was too short to measure
    # (wall == 0 at perf_counter granularity) — see merge_queue_telemetry
    worker_utilization: Optional[float] = None
    max_inflight_boxes: int = 0
    max_inflight_words: int = 0
    # measured block I/O on the attached BlockDevice
    block_reads: int = 0
    block_writes: int = 0
    word_reads: int = 0
    # LRU slice cache(s)
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_words: int = 0
    source: str = "memory"

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0


@dataclass
class _AtomMeta:
    """A resolved body atom: relation source key + dims in the order."""

    idx: int
    key: str                           # key into the engine's source table
    vars: Tuple[str, str]
    first_dim: int
    second_dim: int
    direction: int                     # +1: val0 < val1 on every tuple,
    #                                    -1: reversed index of one, 0: unknown


# §5 interval bookkeeping now lives in ``parallel.sharding`` (the fabric's
# shipping planner shares it); the old private names remain as aliases.
_merge_interval = merge_interval
_gaps = interval_gaps


def _extract_rows(slabs: List[Tuple[int, int, np.ndarray, np.ndarray]],
                  lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
    """(local indptr, values) of rows [lo, hi] out of covering slabs."""
    parts_ip, parts_v = [], []
    for slo, shi, ip, vals in sorted(slabs, key=lambda s: s[0]):
        a, b = max(lo, slo), min(hi, shi)
        if b < a:
            continue
        s, e = int(ip[a - slo]), int(ip[b - slo + 1])
        parts_ip.append(np.diff(ip[a - slo:b - slo + 2]))
        parts_v.append(vals[s:e])
    if not parts_ip:
        return np.zeros(1, np.int64), np.zeros(0, np.int32)
    deg = np.concatenate(parts_ip)
    ip_out = np.concatenate([np.zeros(1, np.int64),
                             np.cumsum(deg, dtype=np.int64)])
    return ip_out, np.concatenate(parts_v)


class QueryEngine:
    """Boxed out-of-core execution of a binary-atom conjunctive query.

    Parameters
    ----------
    query : a ``core.queries.Query`` whose atoms are all binary (graph
        patterns); general-arity queries stay on the scalar
        ``core.queries.run_query`` reference path.
    relations : mapping relation name -> source: an ``EdgeStore`` (or a
        path to one), an ``InMemoryEdgeSource``, or a ``(src, dst)`` pair
        of *directed* edge arrays. Use ``from_graph`` to orient an
        undirected graph the way ``TriangleEngine`` does.
    store : shortcut for single-relation queries: the one relation name
        maps to this edge store path/instance.
    order : variable order; default = the minimum-rank order
        (``core.queries.best_order``), restricted to orders keeping every
        atom consistent when any relation is store-backed (reordered
        indexes need the relation in memory).
    mem_words : box-planner budget; ``None`` = one box.
    cache_words : per-relation LRU ``SliceCache`` budget (0 disables).
    device : ``core.iomodel.BlockDevice`` charging source reads; defaults
        to a fresh device for store-backed runs, ``None`` in memory.
    backend : 'auto' (kernel lane on TPU, host lane otherwise), 'host'
        (pure numpy), 'pallas' (force the kernels/intersect lowering,
        interpret off-TPU), or 'fused' (force whole-box dispatch to the
        ``kernels/lftj_fused`` megakernel — one device invocation per
        box; boxes outside its envelope fall back to the staged path).
    workers / inflight_boxes / prefetch_depth : the shared PR-4 box
        scheduler knobs — identical semantics to ``TriangleEngine``.
    dim_ratio : per-variable budget weights for the §5 split (default:
        4:1 in favour of the first owned dimension).
    skew : 'uniform' (default) or 'heavy_light': break each owned
        dimension's cuts at heavy/light class transitions
        (``query.planner``), carry a lane per box, and route hub boxes
        whole to the fused megakernel (on TPU) while light/mixed boxes
        stay on the host searchsorted lane. Lane mix is recorded in
        ``QueryStats``.
    heavy_threshold : hub degree cut for ``skew='heavy_light'``; default
        √(2·Σdeg)-style per owned dimension.
    plan : a previously computed ``QueryPlan`` for this (query, sources,
        mem_words, skew) — skips re-planning (the serving layer's
        per-pattern-shape plan cache).
    cancel : optional ``threading.Event``; once set, no further box is
        claimed, in-progress boxes finish, and the run raises
        ``core.executor.BoxQueueCancelled`` (boxes are idempotent, so a
        cancelled query can simply be resubmitted).
    """

    def __init__(self, query: Query, *,
                 relations: Optional[Dict[str, object]] = None,
                 store=None,
                 order: Optional[Sequence[str]] = None,
                 mem_words: Optional[int] = None,
                 cache_words: int = 0,
                 device: Optional[BlockDevice] = None,
                 io_block_words: int = 4096,
                 backend: str = "auto",
                 workers: int = 1,
                 inflight_boxes: Optional[int] = None,
                 prefetch_depth: int = 2,
                 dim_ratio: Optional[Dict[str, float]] = None,
                 chunk_entries: int = 4_000_000,
                 skew: str = "uniform",
                 heavy_threshold: Optional[int] = None,
                 plan: Optional[QueryPlan] = None,
                 cancel: Optional[threading.Event] = None,
                 use_pallas_kernels: Optional[bool] = None,
                 tracer=None,
                 metrics=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if skew not in ("uniform", "heavy_light"):
            raise ValueError(
                f"skew {skew!r} not in ('uniform', 'heavy_light')")
        for a in query.atoms:
            if len(a.vars) != 2:
                raise ValueError(
                    f"atom {a.rel}{a.vars}: QueryEngine executes binary "
                    "(graph-pattern) atoms; use core.queries.run_query for "
                    "general arities")
        self.query = query
        self.backend = backend
        # observability: span/event recorder (obs.trace.Tracer) and the
        # cross-layer MetricsRegistry; both None by default so the traced-
        # off path is a single attribute check at each site
        self.tracer = tracer
        self.metrics = metrics
        self.mem_words = mem_words
        self.cache_words = int(cache_words)
        self.dim_ratio = dim_ratio
        self.chunk_entries = int(chunk_entries)
        self.skew = skew
        self.heavy_threshold = heavy_threshold
        self._lane: Dict[object, str] = {}
        self.workers = max(1, int(workers))
        self.inflight_boxes = max(1, int(inflight_boxes)) \
            if inflight_boxes is not None else max(2, 2 * self.workers)
        self.prefetch_depth = max(1, int(prefetch_depth))
        if use_pallas_kernels is None:
            import jax
            use_pallas_kernels = jax.default_backend() == "tpu"
        self.use_pallas_kernels = bool(use_pallas_kernels)

        # -- resolve relation sources ------------------------------------
        rel_names: List[str] = []
        for a in query.atoms:
            if a.rel not in rel_names:
                rel_names.append(a.rel)
        if store is not None:
            if relations is not None:
                raise ValueError("pass either relations= or store=, not both")
            if len(rel_names) != 1:
                raise ValueError(
                    f"store= shorthand needs a single-relation query; this "
                    f"one uses {rel_names}")
            relations = {rel_names[0]: store}
        if relations is None:
            raise ValueError("QueryEngine needs relations= or store=")
        missing = [r for r in rel_names if r not in relations]
        if missing:
            raise ValueError(f"no source given for relation(s) {missing}")

        raw: Dict[str, object] = {}
        any_store = False
        for name in rel_names:
            src = relations[name]
            if isinstance(src, (str, os.PathLike)):
                src = EdgeStore(src)
            if isinstance(src, EdgeStore):
                any_store = True
            elif not (isinstance(src, tuple) and len(src) == 2) \
                    and not hasattr(src, "read_rows"):
                raise ValueError(
                    f"relation {name!r}: unsupported source {type(src)}")
            raw[name] = src
        if device is None and any_store:
            cache = max(2, (mem_words or (1 << 22)) // io_block_words)
            device = BlockDevice(block_words=io_block_words,
                                 cache_blocks=cache)
        self.device = device
        for name, src in raw.items():
            if isinstance(src, EdgeStore):
                if device is not None:
                    src.attach_device(device)
                continue
            if isinstance(src, tuple):
                # deduplicate the directed pairs: set semantics, matching
                # the TrieArray reference path (and from_graph's
                # orient_edges) so scalar run_query and the engine agree
                u = np.asarray(src[0], dtype=np.int64)
                v = np.asarray(src[1], dtype=np.int64)
                nv = int(max(u.max(initial=-1), v.max(initial=-1))) + 1
                if len(u):
                    e = np.unique(np.stack([u, v], axis=1), axis=0)
                    u, v = e[:, 0], e[:, 1]
                ip, ix = csr_from_edges(u, v, n_nodes=nv) if nv else \
                    (np.zeros(1, np.int64), np.zeros(0, np.int32))
                # the device (given or store-created) charges these reads
                # too — the ledger stays symmetric with reversed indexes
                raw[name] = InMemoryEdgeSource(ip, ix, orientation="raw",
                                               device=device)
        # pre-seeded reversed indexes: a relations entry "<rel>~rev"
        # supplies the reordered index of an order-inconsistent atom
        # directly, skipping ``_reversed_source`` — the distributed fabric
        # ships shard-local reversed slices this way instead of deriving
        # them from a (partial) forward slice
        for name, src in relations.items():
            if not name.endswith("~rev") or name in raw:
                continue
            if name[:-len("~rev")] not in rel_names:
                raise ValueError(
                    f"reversed-index source {name!r} matches no relation "
                    f"of this query ({rel_names})")
            if not hasattr(src, "read_rows"):
                raise ValueError(
                    f"reversed-index source {name!r}: unsupported source "
                    f"{type(src)} (needs the EdgeSource interface)")
            raw[name] = src
        self._any_store = any_store

        # -- resolve the variable order and per-atom metadata -------------
        in_memory = not any_store
        self.order = validate(query, order, require_consistent=not in_memory)
        self.n = len(self.order)
        pos = {v: i for i, v in enumerate(self.order)}
        self._raw = raw
        metas: List[_AtomMeta] = []
        for i, a in enumerate(query.atoms):
            ori = getattr(raw[a.rel], "orientation", "raw")
            if is_consistent(a, self.order):
                key, vars_, direction = a.rel, tuple(a.vars), \
                    (1 if ori == "minmax" else 0)
            else:
                key = f"{a.rel}~rev"
                vars_ = (a.vars[1], a.vars[0])
                direction = -1 if ori == "minmax" else 0
                if key not in raw:
                    raw[key] = self._reversed_source(raw[a.rel])
            metas.append(_AtomMeta(i, key, vars_, pos[vars_[0]],
                                   pos[vars_[1]], direction))
        self._atoms = metas
        self._owned: List[List[_AtomMeta]] = [[] for _ in range(self.n)]
        for m in metas:
            self._owned[m.first_dim].append(m)

        # -- cache wrap + bookkeeping --------------------------------------
        self._caches: List[SliceCache] = []
        self._sources: Dict[str, object] = {}
        used_keys = {m.key for m in metas}
        for key in list(raw):
            if key not in used_keys:
                continue
            src = raw[key]
            if self.cache_words > 0:
                src = SliceCache(src, self.cache_words, tracer=tracer)
                self._caches.append(src)
            self._sources[key] = src
        self._nv_all = max((s.n_nodes for s in self._sources.values()),
                           default=0)
        # plan injection (the serving layer's per-pattern-shape plan cache
        # hands a previously-computed plan straight in; planning inputs —
        # degree indexes, budget, skew — must match, which the cache key
        # guarantees)
        self._plan_cache: Optional[Tuple[Optional[int], QueryPlan]] = \
            (mem_words, plan) if plan is not None else None
        self.cancel = cancel
        self._stats_lock = threading.Lock()
        self.stats = QueryStats(order=self.order)

    # -- construction helpers -------------------------------------------------

    @classmethod
    def from_graph(cls, query: Query, src, dst, *,
                   orientation: str = "minmax", **kw) -> "QueryEngine":
        """Engine over one undirected graph: orient (exactly as
        ``TriangleEngine`` does), build the CSR source, and bind it to the
        query's single relation name."""
        rel_names = {a.rel for a in query.atoms}
        if len(rel_names) != 1:
            raise ValueError(
                f"from_graph needs a single-relation query; got {rel_names}")
        a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
        nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        ip, ix = csr_from_edges(a, b, n_nodes=nv) if nv else \
            (np.zeros(1, np.int64), np.zeros(0, np.int32))
        source = InMemoryEdgeSource(ip, ix, orientation=orientation)
        return cls(query, relations={rel_names.pop(): source}, **kw)

    def _reversed_source(self, src) -> InMemoryEdgeSource:
        """In-memory reversed index R(y, x) for an inconsistent atom.

        The reversed CSR is memoized on the source object (the analogue of
        ``core.queries.reordered_index`` at the EdgeSource layer), so
        repeated engines over the same relation re-sort once."""
        if isinstance(src, EdgeStore):
            raise ValueError(
                "an atom inconsistent with the variable order needs a "
                "reordered index, which requires the relation in memory; "
                "choose a consistent order or load the store's edges")
        csr = getattr(src, "_reverse_csr", None)
        if csr is None:
            indptr = np.asarray(src.indptr, dtype=np.int64)
            indices = np.asarray(src.indices, dtype=np.int64)
            rows = np.repeat(np.arange(len(indptr) - 1, dtype=np.int64),
                             np.diff(indptr))
            nv = max(src.n_nodes, int(indices.max(initial=-1)) + 1)
            csr = csr_from_edges(indices, rows, n_nodes=nv)
            src._reverse_csr = csr
        return InMemoryEdgeSource(csr[0], csr[1], orientation="raw",
                                  device=self.device)

    # -- planning -------------------------------------------------------------

    def plan(self) -> QueryPlan:
        """The n-dimensional box plan (cached per ``mem_words``), derived
        from the resident degree indexes only."""
        if self._plan_cache is not None \
                and self._plan_cache[0] == self.mem_words:
            plan = self._plan_cache[1]
        elif self.tracer is not None:
            with self.tracer.span("query.plan", n_vars=self.n,
                                  skew=self.skew):
                plan = self._plan_uncached()
            self._plan_cache = (self.mem_words, plan)
        else:
            plan = self._plan_uncached()
            self._plan_cache = (self.mem_words, plan)
        self._lane = dict(zip(plan.boxes, plan.lanes)) \
            if plan.lanes else {}
        return plan

    def _plan_uncached(self) -> QueryPlan:
        atoms = [Atom(m.key, m.vars) for m in self._atoms]
        directions = {m.idx: m.direction for m in self._atoms}
        rel_indptr = {k: np.asarray(s.indptr)
                      for k, s in self._sources.items()}
        plan = plan_query_boxes(atoms, self.order, rel_indptr,
                                self.mem_words, dim_ratio=self.dim_ratio,
                                directions=directions,
                                skew=self.skew,
                                heavy_threshold=self.heavy_threshold)
        if self._nv_all == 0 or all(s.n_edges == 0
                                    for s in self._sources.values()):
            plan.boxes = []
            plan.lanes = []
        return plan

    # -- per-box stages (fetch serialized; build/work parallel) ----------------

    def _est_box_words(self, box) -> int:
        """Raw words ``_fetch_box`` will read: the same per-dimension gap
        walk over the resident degree indexes, without the reads."""
        covered: Dict[str, List[Tuple[int, int]]] = {}
        words = 0
        for d in range(self.n):
            atoms_d = self._owned[d]
            if not atoms_d:
                continue
            lo, hi = box[d]
            for key in self._dim_keys(atoms_d):
                src = self._sources[key]
                ip = np.asarray(src.indptr)
                lo_, hi_ = max(int(lo), 0), min(int(hi), src.n_nodes - 1)
                if hi_ < lo_:
                    continue
                for glo, ghi in _gaps(covered.get(key, []), lo_, hi_):
                    words += int(ip[ghi + 1] - ip[glo])
                covered[key] = _merge_interval(covered.get(key, []),
                                               lo_, hi_)
        return words

    @staticmethod
    def _dim_keys(atoms_d: Sequence[_AtomMeta]) -> List[str]:
        keys: List[str] = []
        for m in atoms_d:
            if m.key not in keys:
                keys.append(m.key)
        return keys

    def _fetch_box(self, box):
        """All source reads of one box (the serialized scheduler stage),
        dim by dim with §5 interval dedup, plus the per-atom slice builds
        needed for the full-conjunctive early exit: an empty atom slice
        stops the box before any later dimension is read — exactly the
        triangle executor's read stream on the triangle query. Returns
        ``(payload, words_read)``; payload ``None`` for an empty box."""
        slabs: Dict[str, list] = {}
        covered: Dict[str, List[Tuple[int, int]]] = {}
        slices: Dict[int, object] = {}
        words = 0
        for d in range(self.n):
            atoms_d = self._owned[d]
            if not atoms_d:
                continue
            lo, hi = box[d]
            for key in self._dim_keys(atoms_d):
                src = self._sources[key]
                lo_, hi_ = max(int(lo), 0), min(int(hi), src.n_nodes - 1)
                if hi_ < lo_:
                    continue
                for glo, ghi in _gaps(covered.get(key, []), lo_, hi_):
                    ip, vals = src.read_rows(glo, ghi)
                    slabs.setdefault(key, []).append((glo, ghi, ip, vals))
                    words += len(vals)
                covered[key] = _merge_interval(covered.get(key, []),
                                               lo_, hi_)
            for m in atoms_d:
                src = self._sources[m.key]
                lo_, hi_ = max(int(lo), 0), min(int(hi), src.n_nodes - 1)
                if hi_ < lo_:
                    return None, words
                ip, vals = _extract_rows(slabs.get(m.key, []), lo_, hi_)
                l2, h2 = box[m.second_dim]
                slc = build_atom_slice(
                    ip, vals, lo_,
                    val_lo=int(l2) if l2 > 0 else None,
                    val_hi=int(h2) if h2 < self._nv_all - 1 else None)
                if slc.n_keys == 0:
                    return None, words
                slices[m.idx] = slc
        return (box, slices, words), words

    def _build_box(self, payload):
        """Assemble the box's work item (parallel stage; no source access)."""
        if payload is None:
            return None
        box, slices, words = payload
        s = self.stats
        with self._stats_lock:
            s.n_streamed_boxes += 1
            s.slice_words_read += words
            s.max_slice_words = max(s.max_slice_words, words)
        bound = [BoundAtom(m.first_dim, m.second_dim, slices[m.idx])
                 for m in self._atoms]
        return (box, bound)

    def _make_join(self, bound, mode: str, lane: Optional[str] = None,
                   capacity: Optional[int] = None) -> VectorizedBoxJoin:
        # heavy_light lane routing: hub boxes dispatch whole to the fused
        # megakernel (worthwhile only compiled, i.e. on TPU), falling
        # back per box to the staged path when outside its envelope;
        # light and mixed boxes are pinned to the host searchsorted lane.
        # backend="fused" forces the fused lane for every box.
        fused = self.backend == "fused" or (
            self.backend == "auto" and self.use_pallas_kernels
            and lane == "hub")
        kernel_lane = self.backend == "pallas" or (
            self.backend == "auto" and self.use_pallas_kernels
            and lane not in ("light", "mixed"))
        return VectorizedBoxJoin(
            bound, self.n, mode,
            kernel_lane=kernel_lane and mode == "count",
            use_pallas=True,
            interpret=not self.use_pallas_kernels,
            device="fused" if fused else "host",
            chunk_entries=self.chunk_entries,
            capacity=capacity)

    def _note_join(self, vj: VectorizedBoxJoin,
                   kl: Optional[kernel_ledger.KernelLedger] = None) -> None:
        with self._stats_lock:
            self.stats.max_frontier = max(self.stats.max_frontier,
                                          vj.max_frontier)
            if vj.used_fused:
                self.stats.n_fused_boxes += 1
            elif vj.used_kernel:
                self.stats.n_kernel_boxes += 1
            else:
                self.stats.n_host_boxes += 1
            if kl is not None and kl.invocations:
                self.stats.device_invocations += kl.invocations
                self.stats.device_transfer_bytes += kl.transfer_bytes
                self.stats.max_box_device_invocations = max(
                    self.stats.max_box_device_invocations, kl.invocations)
        if self.metrics is not None and kl is not None:
            self.metrics.note_kernel(kl, op=self._join_op(vj))

    @staticmethod
    def _join_op(vj: VectorizedBoxJoin) -> str:
        """The ``kernel.*{op=..}`` label of a finished box join: the lane
        that actually executed, fallbacks resolved."""
        if vj.used_fused:
            return "fused"
        if vj.used_kernel:
            return "staged"
        return "host"

    def _work_count(self, built) -> int:
        box, bound = built
        vj = self._make_join(bound, "count", lane=self._lane.get(box))
        with kernel_ledger.attach(tracer=self.tracer) as kl:
            out = vj.run()
        self._note_join(vj, kl)
        return out

    def _work_list(self, built,
                   capacity: Optional[int] = None) -> Optional[np.ndarray]:
        """One box's bindings through the bounded buffer: at most ``cap``
        rows are materialized per pass; the join's exact count detects
        overflow, which rescans *this box* at doubled capacity (the
        triangle executor's box-granular overflow→rescan protocol)."""
        box, bound = built
        cap = capacity
        with kernel_ledger.attach(tracer=self.tracer) as kl:
            while True:
                vj = self._make_join(bound, "list",
                                     lane=self._lane.get(box),
                                     capacity=cap)
                total = vj.run()
                if cap is None or total <= cap:
                    break
                with self._stats_lock:
                    self.stats.n_rescans += 1
                cap *= 2
        self._note_join(vj, kl)
        rows = vj.bindings()
        if len(rows) == 0:
            return None
        if self.device is not None:
            self.device.write_words(rows.size)
        return rows

    # -- run plumbing ----------------------------------------------------------

    def _reset_stats(self, plan: QueryPlan) -> None:
        self.stats = QueryStats(order=self.order, rank=plan.rank,
                                n_boxes=len(plan.boxes),
                                n_workers=self.workers,
                                skew=self.skew,
                                heavy_threshold=plan.heavy_threshold,
                                n_hub_boxes=plan.lanes.count("hub"),
                                n_light_boxes=plan.lanes.count("light"),
                                n_mixed_boxes=plan.lanes.count("mixed"),
                                source="edgestore" if self._any_store
                                else "memory")

    def _io_mark(self):
        cm = [(c.hits, c.misses, c.hit_words) for c in self._caches]
        if self.device is None:
            return (None, cm)
        s = self.device.stats
        return ((s.block_reads, s.block_writes, s.word_reads), cm)

    def _io_collect(self, mark) -> None:
        io_mark, cm = mark
        if self.device is not None and io_mark is not None:
            s = self.device.stats
            self.stats.block_reads = s.block_reads - io_mark[0]
            self.stats.block_writes = s.block_writes - io_mark[1]
            self.stats.word_reads = s.word_reads - io_mark[2]
        for cache, (h, m, w) in zip(self._caches, cm):
            self.stats.cache_hits += cache.hits - h
            self.stats.cache_misses += cache.misses - m
            self.stats.cache_hit_words += cache.hit_words - w

    def _queue_order(self, boxes) -> List[int]:
        ledger = bool(self._caches) or any(
            getattr(s, "device", None) is not None
            for s in self._sources.values())
        return box_queue_order([self._est_box_words(b) for b in boxes],
                               ledger_sensitive=ledger)

    # -- serving-layer hooks ----------------------------------------------------
    # ``repro.serve`` drives the engine's per-box stages through its own
    # run_box_queue round (wrapping them with fault capture, I/O
    # attribution and result streaming); these public accessors are that
    # contract — the stages themselves stay the single implementation.

    def queue_order(self, boxes) -> List[int]:
        """Queue drain order for ``boxes`` (``sharding.box_queue_order``
        policy: plan order whenever an I/O ledger is attached)."""
        return self._queue_order(boxes)

    def box_stages(self, mode: str, capacity: Optional[int] = None):
        """``(est_words, fetch, build, work)`` stage callables for
        ``run_box_queue`` — ``mode`` 'count' or 'list'; ``capacity`` is
        the bounded-listing per-box buffer (None = unbounded)."""
        if mode == "count":
            work = self._work_count
        elif mode == "list":
            work = lambda built: self._work_list(built, capacity)  # noqa: E731
        else:
            raise ValueError(f"mode {mode!r} not in ('count', 'list')")
        return self._est_box_words, self._fetch_box, self._build_box, work

    def default_list_capacity(self) -> Optional[int]:
        """The bounded-buffer per-box listing capacity ``list()`` derives
        from the memory budget (the output buffer is part of the §5
        working set); ``None`` when no budget is set."""
        if self.mem_words is None:
            return None
        return _pow2(max(256, self.mem_words // max(1, self.n)))

    def head_columns(self, rows: np.ndarray) -> np.ndarray:
        """Project raw binding rows (variable-order columns) to the
        query's head order — the last step of ``list()``."""
        head_cols = [self.order.index(h) for h in self.query.head]
        return rows[:, head_cols]

    def io_mark(self):
        """Snapshot of the device + cache counters (pair with
        ``io_collect``). Only meaningful when this engine is the device's
        sole client in the window; the serving layer uses per-query
        attribution tags (``BlockDevice.attributed``) instead."""
        return self._io_mark()

    def io_collect(self, mark) -> None:
        self._io_collect(mark)

    def _run(self, boxes, work) -> List:
        """Per-box results in plan order — serial Prefetcher pipeline for
        ``workers=1`` (the oracle), the shared pool otherwise."""
        if self.workers > 1 and len(boxes) > 1:
            inflight_words = self.inflight_boxes * self.mem_words \
                if self.mem_words is not None else None
            results, tele = run_box_queue(
                boxes, order=self._queue_order(boxes),
                est_words=self._est_box_words,
                fetch=self._fetch_box,
                build=self._build_box,
                work=work,
                workers=self.workers,
                inflight_items=self.inflight_boxes,
                inflight_words=inflight_words,
                cancel=self.cancel,
                tracer=self.tracer)
            merge_queue_telemetry(self.stats, tele, self._stats_lock,
                                  inflight_boxes=self.inflight_boxes,
                                  metrics=self.metrics)
            return results
        return run_box_serial(boxes, fetch=self._fetch_box,
                              build=self._build_box, work=work,
                              prefetch_depth=self.prefetch_depth,
                              cancel=self.cancel,
                              tracer=self.tracer)

    # -- fabric hooks -----------------------------------------------------------
    # ``repro.parallel.fabric`` plans once on a full-source engine, ships
    # each shard only the byte ranges its boxes touch, and re-runs a
    # restricted plan per shard; these accessors expose exactly the plan
    # inputs that shipping needs (relation keys incl. reversed indexes,
    # which dimension provisions which key) without reaching into privates.

    def source_keys(self) -> List[str]:
        """Relation source keys actually read by this engine's atoms, in
        registration order — forward relation names plus any derived
        ``"<rel>~rev"`` reversed indexes."""
        return list(self._sources)

    def source_for(self, key: str):
        """The (possibly cache-wrapped) EdgeSource behind ``key``; the
        unwrapped source is at ``.source`` when a cache is attached."""
        return self._sources[key]

    def owned_dim_keys(self) -> List[Tuple[int, List[str]]]:
        """Per owned dimension, the distinct relation keys whose rows it
        provisions — the ``dim_keys`` input of the fabric's
        ``sharding.box_mass_costs_nd`` / ``shard_shipped_ranges``."""
        return [(d, self._dim_keys(self._owned[d]))
                for d in range(self.n) if self._owned[d]]

    def run_boxes(self, mode: str = "count",
                  capacity: Optional[int] = None) -> List:
        """Execute the plan and return PER-BOX results in plan order
        (``None`` for empty/skipped boxes) instead of the reduced total:
        counts for ``mode='count'``, raw binding rows (variable-order
        columns, unprojected) for ``mode='list'``.

        This is the fabric's shard entry point — the cross-shard reduction
        happens at the caller in global fixed box order, which is what
        keeps a distributed run's count/listing byte-identical to the
        single-host ``count()`` / ``list()`` (both of which are thin
        reductions over this method). Stats and the I/O mark/collect
        window behave exactly as in ``count()``/``list()``."""
        plan = self.plan()
        self._reset_stats(plan)
        if mode == "count":
            work = self._work_count
        elif mode == "list":
            cap0 = capacity if capacity is not None \
                else self.default_list_capacity()
            work = lambda built: self._work_list(built, cap0)  # noqa: E731
        else:
            raise ValueError(f"mode {mode!r} not in ('count', 'list')")
        mark = self._io_mark()
        if self.tracer is not None:
            with self.tracer.span("query.boxes", mode=mode,
                                  n_boxes=len(plan.boxes)):
                results = self._run(plan.boxes, work)
        else:
            results = self._run(plan.boxes, work)
        self._io_collect(mark)
        if mode == "count":
            self.stats.n_results = sum(int(r) for r in results
                                       if r is not None)
        else:
            self.stats.n_results = sum(len(r) for r in results
                                       if r is not None)
        if self.metrics is not None:
            self.metrics.publish_stats(self.stats, "query", mode=mode)
        return results

    # -- public entry points ----------------------------------------------------

    def count(self) -> int:
        self.run_boxes("count")
        return self.stats.n_results

    def list(self, capacity: Optional[int] = None) -> np.ndarray:
        """All result bindings as an (m, len(head)) int64 array, columns in
        the query's head order (bag semantics: one row per LFTJ binding).

        Per-box result buffers are *bounded*: at most ``capacity`` rows
        materialize per box pass (default derived from ``mem_words`` —
        the output buffer is part of the §5 working set). A box whose
        exact count exceeds the buffer rescans at doubled capacity
        (``stats.n_rescans``), so results stay complete and deterministic
        while peak result memory respects the budget."""
        results = self.run_boxes("list", capacity)
        parts = [r for r in results if r is not None]
        rows = np.concatenate(parts) if parts \
            else np.zeros((0, self.n), dtype=np.int64)
        return self.head_columns(rows)


def query_count(query: Query, src, dst, **kw) -> int:
    """One-shot: count a pattern on an undirected graph (minmax DAG)."""
    return QueryEngine.from_graph(query, src, dst, **kw).count()
