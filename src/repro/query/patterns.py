"""Canonical graph-pattern queries over the oriented edge relation.

Every pattern is a full conjunctive query (paper §2.1, Def. 12) over ONE
binary relation — by convention named ``"E"`` — holding the DAG-oriented
edge set G* (paper §2.3). Semantics are the standard CQ bag-of-bindings
semantics over that *directed* relation:

* ``triangle`` and ``k_clique`` counts are orientation-invariant: an
  undirected k-clique maps to exactly one increasing binding under any
  acyclic orientation, so the CQ count equals the undirected subgraph
  count (this is why ``QueryEngine`` on the triangle query reproduces
  ``TriangleEngine`` exactly).
* ``diamond`` / ``path`` / ``cycle`` are DAG patterns: their counts depend
  on the orientation (a 2-path x→y→z exists only where the orientation
  chains), and distinct variables may bind equal values when no atom
  separates them (e.g. the diamond's two middle variables) — exactly what
  LFTJ enumerates. The brute-force references in the test suite implement
  the same semantics over the same oriented relation.

All patterns are consistent with their natural variable order, so they run
against a disk-resident edge store without reordered indexes; ``rank``
values (Def. 12): triangle 2, k-clique k-1, diamond 3, k-path ≤ k-1.
"""

from __future__ import annotations

from itertools import combinations
from typing import List

from repro.core.leapfrog import Atom
from repro.core.queries import Query

EDGE_REL = "E"


def triangle() -> Query:
    """T(x,y,z) <- E(x,y), E(x,z), E(y,z)   (paper eq. Δ)."""
    return Query(head=("x", "y", "z"),
                 atoms=[Atom(EDGE_REL, ("x", "y")),
                        Atom(EDGE_REL, ("x", "z")),
                        Atom(EDGE_REL, ("y", "z"))])


def k_clique(k: int) -> Query:
    """All-pairs-adjacent on k variables; k=3 is the triangle, k=4 the
    4-clique with rank 3 (the Thm. 13 showcase beyond triangles)."""
    if k < 2:
        raise ValueError("k_clique needs k >= 2")
    vs = tuple(f"v{i}" for i in range(k))
    atoms = [Atom(EDGE_REL, (vs[i], vs[j]))
             for i, j in combinations(range(k), 2)]
    return Query(head=vs, atoms=atoms)


def four_clique() -> Query:
    return k_clique(4)


def diamond() -> Query:
    """D(x,y,z,w) <- E(x,y), E(x,z), E(y,w), E(z,w): the directed diamond
    (out-fan x→{y,z} closing on w) — the classic WCOJ benchmark pattern;
    on a minmax-oriented graph each undirected 4-cycle {a<b,c<d} appears
    as its two (y,z) orderings plus the degenerate y=z two-paths."""
    return Query(head=("x", "y", "z", "w"),
                 atoms=[Atom(EDGE_REL, ("x", "y")),
                        Atom(EDGE_REL, ("x", "z")),
                        Atom(EDGE_REL, ("y", "w")),
                        Atom(EDGE_REL, ("z", "w"))])


def path(k: int = 3) -> Query:
    """k-edge directed path v0→v1→...→vk over the DAG orientation."""
    if k < 1:
        raise ValueError("path needs k >= 1 edges")
    vs = tuple(f"v{i}" for i in range(k + 1))
    atoms = [Atom(EDGE_REL, (vs[i], vs[i + 1])) for i in range(k)]
    return Query(head=vs, atoms=atoms)


def cycle(k: int = 4) -> Query:
    """k-cycle as a DAG pattern: an increasing (k-1)-edge chain closed by
    the chord E(v0, v_{k-1}); k=3 degenerates to the triangle."""
    if k < 3:
        raise ValueError("cycle needs k >= 3")
    vs = tuple(f"v{i}" for i in range(k))
    atoms = [Atom(EDGE_REL, (vs[i], vs[i + 1])) for i in range(k - 1)]
    atoms.append(Atom(EDGE_REL, (vs[0], vs[k - 1])))
    return Query(head=vs, atoms=atoms)


PATTERNS = {
    "triangle": triangle,
    "four_clique": four_clique,
    "diamond": diamond,
    "path3": lambda: path(3),
    "cycle4": lambda: cycle(4),
}


def pattern_names() -> List[str]:
    return list(PATTERNS)
