"""n-dimensional box planner for conjunctive queries (paper §3.3, Thm. 13).

Generalizes the triangle planner (``core.boxing.plan_boxes_from_degrees``)
to any validated ``core.queries.Query``: the variable search space is cut
into n-dimensional boxes along every dimension that *owns* at least one
atom (an atom is owned by the dimension of its first unbound variable —
only those dimensions need provisioned slices, paper §5), budgeted so that
the per-box working set fits ``mem_words``.

Planning is done entirely from the *resident degree indexes* (the (V+1)-word
``indptr`` arrays every ``EdgeSource`` keeps in memory), never by touching
the neighbor streams — the same out-of-core contract the triangle engine's
store-backed planner honours. Each owned dimension is cut with the shared
``core.boxing.greedy_degree_cuts`` primitive, so the triangle query's 2-D
special case reproduces ``plan_boxes_from_degrees`` *cut for cut* (and
therefore read for read — the I/O-parity contract ``tests/test_query_engine.py``
pins against ``TriangleEngine``).

The budget split follows §5: only owned dimensions get budget, weighted
4:1 in favour of the first owned dimension by default (the paper's x:y
ratio for the triangle query), with the last owned dimension taking the
integer remainder — again matching the triangle planner exactly.

``thm13_io_bound`` evaluates the paper's rank-r no-spill envelope
O(|I|^r / (M^{r-1} B) + K/B) that ``benchmarks/query_patterns.py`` compares
measured block reads against.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.boxing import greedy_degree_cuts
from repro.core.leapfrog import Atom
from repro.core.queries import rank_for_order

Box = Tuple[Tuple[int, int], ...]        # per-dimension (lo, hi), inclusive


@dataclass
class QueryPlan:
    """A box plan plus the metadata the executor and benchmarks consume."""

    order: Tuple[str, ...]
    rank: int
    owned_dims: Tuple[int, ...]          # dims owning >= 1 atom
    boxes: List[Box]
    budgets: Dict[int, int] = field(default_factory=dict)
    single_box: bool = False
    # skew="heavy_light" metadata: lanes[i] classifies boxes[i] by the
    # heavy/light class of its *owned* ranges ("hub"/"light"/"mixed");
    # heavy_threshold is the hub degree cut the cutter used
    skew: str = "uniform"
    lanes: List[str] = field(default_factory=list)
    heavy_threshold: int = 0

    @property
    def n_boxes(self) -> int:
        return len(self.boxes)

    def lane_of(self, box: Box) -> Optional[str]:
        try:
            return self.lanes[self.boxes.index(box)]
        except ValueError:
            return None


def owned_atoms_by_dim(atoms: Sequence[Atom],
                       order: Sequence[str]) -> List[List[Atom]]:
    """Atoms grouped by the dimension of their first variable."""
    out: List[List[Atom]] = [[] for _ in order]
    pos = {v: i for i, v in enumerate(order)}
    for a in atoms:
        out[pos[a.vars[0]]].append(a)
    return out


def slice_cost(indptr: np.ndarray, row_overhead: int = 2) -> np.ndarray:
    """Per-row provisioning cost in words: deg + row_overhead for present
    rows (values + idx entries, mirroring ``TrieArray.slice_words``)."""
    deg = np.diff(np.asarray(indptr, dtype=np.int64))
    return np.where(deg > 0, deg + row_overhead, 0)


def dim_budgets(mem_words: int, owned: Sequence[int],
                order: Sequence[str],
                dim_ratio: Optional[Dict[str, float]] = None) -> Dict[int, int]:
    """§5 budget split over owned dimensions.

    Default weights: 4.0 for the first owned dimension, 1.0 for the rest
    (the paper's triangle x:y ratio); the last owned dimension takes the
    integer remainder so the split sums to ``mem_words`` exactly — both
    choices match ``plan_boxes_from_degrees`` on two owned dimensions.
    """
    if not owned:
        return {}
    if dim_ratio:
        weights = [float(dim_ratio.get(order[d], 1.0)) for d in owned]
    else:
        weights = [4.0] + [1.0] * (len(owned) - 1)
    wsum = sum(weights) or 1.0
    budgets: Dict[int, int] = {}
    spent = 0
    for d, w in zip(owned[:-1], weights[:-1]):
        b = max(1, int(mem_words * w / wsum))
        budgets[d] = b
        spent += b
    budgets[owned[-1]] = max(1, mem_words - spent)
    return budgets


def monotone_prune_pairs(atoms: Sequence[Atom], order: Sequence[str],
                         directions: Dict[int, int]) -> List[Tuple[int, int]]:
    """(u_dim, v_dim) pairs such that a box with hi_v < lo_u is provably
    empty: atom value monotonicity (§5) from the storage orientation.

    ``directions[atom_index]`` is +1 when every stored tuple of that atom
    satisfies val(first) < val(second) (a minmax-oriented edge relation),
    -1 for the reversed index of one, 0 when unknown (no pruning).
    """
    pos = {v: i for i, v in enumerate(order)}
    pairs = []
    for i, a in enumerate(atoms):
        sign = directions.get(i, 0)
        if sign == 0 or len(a.vars) != 2:
            continue
        lo_var, hi_var = (a.vars[0], a.vars[1]) if sign > 0 \
            else (a.vars[1], a.vars[0])
        pairs.append((pos[lo_var], pos[hi_var]))
    return sorted(set(pairs))


def plan_query_boxes(atoms: Sequence[Atom], order: Sequence[str],
                     rel_indptr: Dict[str, np.ndarray],
                     mem_words: Optional[int],
                     *,
                     dim_ratio: Optional[Dict[str, float]] = None,
                     directions: Optional[Dict[int, int]] = None,
                     monotone_prune: bool = True,
                     row_overhead: int = 2,
                     skew: str = "uniform",
                     heavy_threshold: Optional[int] = None) -> QueryPlan:
    """Box plan for a consistent atom list over resident degree indexes.

    ``rel_indptr`` maps relation name -> (V+1)-word CSR prefix sums (the
    resident index of each ``EdgeSource``). Returns boxes as per-dimension
    inclusive (lo, hi) tuples; unowned dimensions span their full domain.

    ``skew="heavy_light"`` classifies each owned dimension's rows heavy
    (combined degree >= ``heavy_threshold``, default √(2·Σdeg)-style) vs
    light and breaks that dimension's cuts at class transitions
    (``core.boxing.class_cuts``), so each box range is pure-class per
    owned dimension. The plan then carries a lane per box ("hub" = every
    owned range heavy, "light" = every owned range light, else "mixed")
    that the executor's dispatch consumes.
    """
    if skew not in ("uniform", "heavy_light"):
        raise ValueError(
            f"skew {skew!r} not in ('uniform', 'heavy_light')")
    order = tuple(order)
    n = len(order)
    owned_lists = owned_atoms_by_dim(atoms, order)
    owned = tuple(d for d in range(n) if owned_lists[d])
    r = rank_for_order(Query_shim(atoms), order)

    # full per-dimension domains: values are vertex ids of the relations
    nv_all = max((len(ip) - 1 for ip in rel_indptr.values()), default=0)
    full: List[Tuple[int, int]] = [(0, max(0, nv_all - 1))] * n
    plan = QueryPlan(order=order, rank=r, owned_dims=owned, boxes=[],
                     single_box=True, skew=skew)
    if nv_all <= 0 or any(len(ip) < 2 for ip in rel_indptr.values()):
        return plan

    def dim_cost_deg(d):
        """(cost, degree) per row of dim d, combined over owning rels."""
        rels = []
        for a in owned_lists[d]:
            if a.rel not in rels:
                rels.append(a.rel)
        nv_d = max(len(rel_indptr[rn]) - 1 for rn in rels)
        cost = np.zeros(nv_d, dtype=np.int64)
        deg = np.zeros(nv_d, dtype=np.int64)
        for rn in rels:
            c = slice_cost(rel_indptr[rn], row_overhead)
            cost[:len(c)] += c
            dd = np.diff(np.asarray(rel_indptr[rn], dtype=np.int64))
            deg[:len(dd)] += dd
        return cost, deg

    heavy_by_dim: Dict[int, np.ndarray] = {}
    if skew == "heavy_light":
        from repro.core.boxing import heavy_threshold_default
        thr = 0
        for d in owned:
            _, deg = dim_cost_deg(d)
            t = int(heavy_threshold) if heavy_threshold is not None \
                else heavy_threshold_default(int(deg.sum()))
            heavy_by_dim[d] = deg >= t
            thr = max(thr, t)
        plan.heavy_threshold = thr

    def lane_for(classes) -> str:
        """Lane of one box from its owned ranges' classes (None = the
        range was never classified, e.g. the unbounded single box)."""
        if classes and all(c is True for c in classes):
            return "hub"
        if classes and all(c is False for c in classes):
            return "light"
        return "mixed"

    # §5 slice dedup at the cost level too: a relation read once per box
    # serves every atom sharing it, so each distinct relation is charged
    # once in the fits-in-memory test and once per owning dimension
    total = sum(int(slice_cost(ip, row_overhead).sum())
                for ip in rel_indptr.values())
    if mem_words is None or total <= mem_words:
        plan.boxes = [tuple(full)]
        if skew == "heavy_light":
            classes = []
            for d in owned:
                live = heavy_by_dim[d][dim_cost_deg(d)[1] > 0]
                if len(live) and live.all():
                    classes.append(True)
                elif len(live) and not live.any():
                    classes.append(False)
                else:
                    classes.append(None)
            plan.lanes = [lane_for(classes)]
        return plan

    plan.single_box = False
    budgets = dim_budgets(mem_words, owned, order, dim_ratio)
    plan.budgets = budgets
    cuts: List[List[Tuple[int, int, Optional[bool]]]] = []
    for d in range(n):
        if d not in budgets:
            cuts.append([(full[d][0], full[d][1], None)])
            continue
        cost, deg = dim_cost_deg(d)
        if skew == "heavy_light":
            from repro.core.boxing import class_cuts
            cuts.append(class_cuts(cost, budgets[d], heavy_by_dim[d]))
        else:
            cuts.append([(lo, hi, None)
                         for lo, hi in greedy_degree_cuts(cost,
                                                          budgets[d])])

    prune_pairs = monotone_prune_pairs(atoms, order, directions or {}) \
        if monotone_prune else []
    for combo in itertools.product(*cuts):
        if any(combo[v][1] < combo[u][0] for u, v in prune_pairs):
            continue
        plan.boxes.append(tuple((lo, hi) for lo, hi, _cls in combo))
        if skew == "heavy_light":
            plan.lanes.append(
                lane_for([combo[d][2] for d in owned]))
    return plan


class Query_shim:
    """Minimal duck-typed Query (atoms only) for ``rank_for_order``."""

    def __init__(self, atoms: Sequence[Atom]):
        self.atoms = list(atoms)


def thm13_io_bound(input_words: int, mem_words: int, block_words: int,
                   r: int, output_words: int = 0) -> float:
    """The paper's Thm. 13 no-spill envelope for a rank-r query:
    O(|I|^r / (M^{r-1} B) + K/B), in block I/Os."""
    m = max(1, int(mem_words))
    b = max(1, int(block_words))
    return float(input_words) ** r / (float(m) ** (r - 1) * b) \
        + float(output_words) / b
