"""Pure-jnp oracle for the dense triangle-count kernel.

count = Σ_{x,y} A[x,y] · (A Aᵀ)[x,y]  over a 0/1 DAG adjacency
      = number of (x,y,z) with (x,y),(x,z),(y,z) ∈ E  (paper query Δ),

optionally restricted by an edge mask M (the box's x/y window):
count = Σ M ⊙ (A Bᵀ) where A = rows of the x-slice, B = rows of the y-slice.
"""

from __future__ import annotations

import jax.numpy as jnp


def triangle_count_ref(a: jnp.ndarray, b: jnp.ndarray,
                       mask: jnp.ndarray) -> jnp.ndarray:
    """a: (nx, d) 0/1 rows for x-range; b: (ny, d) rows for y-range;
    mask: (nx, ny) in-box edge indicator. fp32 accumulate, int64-safe sum."""
    paths = a.astype(jnp.float32) @ b.astype(jnp.float32).T
    return jnp.sum(mask.astype(jnp.float32) * paths).astype(jnp.float32)
