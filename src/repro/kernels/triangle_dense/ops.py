"""jit'd public wrapper for the dense triangle-count kernel (pads + dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import triangle_count_pallas
from .ref import triangle_count_ref


def _pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    p0 = rows - x.shape[0]
    p1 = cols - x.shape[1]
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def triangle_count(a, b, mask, *, bm: int = 128, bn: int = 128, bk: int = 512,
                   use_pallas: bool = True, interpret: bool | None = None):
    """Masked dense triangle count Σ mask ⊙ (A Bᵀ).

    Pads to tile multiples — zero padding is inert (padded rows/cols
    contribute zero paths and zero mask)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    mask = jnp.asarray(mask)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = max(a.shape[1], b.shape[1])
    bk_eff = min(bk, int(np.ceil(d / 128)) * 128)
    d_pad = int(np.ceil(d / bk_eff)) * bk_eff
    nx = int(np.ceil(a.shape[0] / bm)) * bm
    ny = int(np.ceil(b.shape[0] / bn)) * bn
    a = _pad2(a, nx, d_pad)
    b = _pad2(b, ny, d_pad)
    mask = _pad2(mask, nx, ny)
    if not use_pallas:
        return triangle_count_ref(a, b, mask)
    return triangle_count_pallas(a, b, mask, bm=bm, bn=bn, bk=bk_eff,
                                 interpret=interpret)
