"""Pallas TPU kernel: masked-SYRK triangle count over dense 0/1 tiles.

count = Σ mask ⊙ (A Bᵀ): A (nx,d) = x-slice rows, B (ny,d) = y-slice rows,
mask (nx,ny) = in-box edge indicator. This is the MXU formulation of the
per-box level-z leapfrog joins (DESIGN.md §2): for dense boxes a bitmap
matmul beats per-edge sorted intersection.

Grid: (nx/bm, ny/bn, d/bk) with the contraction axis innermost so A/B tile
DMAs double-buffer across k-steps. Each (i,j) cell accumulates
paths += A_tile @ B_tileᵀ in an fp32 VMEM scratch, then applies the mask
once at k == nsteps-1 and writes a per-cell scalar partial; the host-side
wrapper reduces the (nx/bm, ny/bn) partial grid.

VMEM per cell @ (bm,bn,bk)=(128,128,512): (bm·bk + bn·bk + bm·bn + bm·bn)·4B
≈ 0.63 MiB — far under the ~16 MiB/core VMEM; bk=512 keeps the MXU k-dim
pipelined at its native 128 multiple.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tri_kernel(a_ref, b_ref, m_ref, out_ref, acc_ref, *, nsteps_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                       # (bm, bk)
    b = b_ref[...]                                       # (bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # MXU matmul

    @pl.when(k == nsteps_k - 1)
    def _finish():
        out_ref[0, 0] = jnp.sum(m_ref[...] * acc_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def triangle_count_pallas(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                          bm: int = 128, bn: int = 128, bk: int = 512,
                          interpret: bool = False) -> jnp.ndarray:
    """All dims must be multiples of block sizes (ops.py pads). fp32 count."""
    nx, d = a.shape
    ny = b.shape[0]
    assert nx % bm == 0 and ny % bn == 0 and d % bk == 0, (nx, ny, d, bm, bn, bk)
    grid = (nx // bm, ny // bn, d // bk)
    partials = pl.pallas_call(
        functools.partial(_tri_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), mask.astype(jnp.float32))
    return jnp.sum(partials)
