"""Pallas TPU kernel: batched leapfrog join (sorted-set intersection counts).

Per grid cell, a (bE, K) tile of x-side neighbor rows and the matching
(bE, K) tile of y-side rows sit in VMEM; the kernel emits per-row |a ∩ b|.

Hardware adaptation (DESIGN.md §2): the paper's leapfrog join advances
iterators with binary searches — a *gather* access pattern the TPU VPU has
no efficient cross-lane primitive for. We instead compare a against all K
rotations of b (`jnp.roll` by a constant 1 per step), which lowers to cheap
lane shuffles: K steps × (bE, K) lane-parallel compares = O(K²) flops/row,
but at full 8×128 VPU width with zero data-dependent control flow. For the
K ≤ 512 regime the boxing planner produces (degree-capped slices), the
rotation form wins over an in-VMEM binary search by avoiding serialization;
rows are *sets* (strictly sorted), so each (j,k) pair matches at most once
across rotations and the count is exact. SENTINEL padding never matches
because hits are gated on a != SENTINEL.

VMEM per cell @ (bE,K)=(256,512): 3 × 256·512·4 B = 1.5 MiB « 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

SENTINEL = np.iinfo(np.int32).max


def _intersect_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                                  # (bE, K) int32 sorted rows
    b = b_ref[...]
    k = a.shape[1]
    valid = (a != SENTINEL)

    def step(i, carry):
        acc, b_rot = carry
        acc = acc + jnp.where((a == b_rot) & valid, 1, 0)
        b_rot = jnp.roll(b_rot, 1, axis=1)          # constant-shift lane rotate
        return acc, b_rot

    acc0 = jnp.zeros(a.shape, jnp.int32)
    acc, _ = jax.lax.fori_loop(0, k, step, (acc0, b))
    out_ref[...] = jnp.sum(acc, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("be", "interpret"))
def intersect_count_pallas(a: jnp.ndarray, b: jnp.ndarray,
                           be: int = 256, interpret: bool = False) -> jnp.ndarray:
    """a, b: (E, K) int32 sorted SENTINEL-padded rows; returns (E,) int32.

    E must be a multiple of ``be`` and K a multiple of 128 (ops.py pads)."""
    e, k = a.shape
    assert e % be == 0 and k % 128 == 0, (e, k, be)
    grid = (e // be,)
    out = pl.pallas_call(
        _intersect_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, k), lambda i: (i, 0)),
            pl.BlockSpec((be, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((be, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, 1), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:, 0]
