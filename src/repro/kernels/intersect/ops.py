"""jit'd public wrapper for the leapfrog-intersection kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import SENTINEL, intersect_count_pallas
from .ref import intersect_count_ref


def intersect_count(a, b, *, be: int = 256, use_pallas: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Per-row sorted-set intersection counts |a_i ∩ b_i|.

    Pads rows with SENTINEL to a lane multiple and the row count to ``be``;
    padded rows return 0 and are stripped. ``be`` shrinks (to a sublane
    multiple) for small batches so a per-box call from the triangle engine
    never pads a handful of edges up to a full 256-row tile."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    e, ka = a.shape
    kb = b.shape[1]
    k = int(np.ceil(max(ka, kb, 1) / 128)) * 128
    be = min(be, int(np.ceil(max(e, 1) / 8)) * 8)
    ep = int(np.ceil(max(e, 1) / be)) * be
    a = jnp.pad(a, ((0, ep - e), (0, k - ka)), constant_values=SENTINEL)
    b = jnp.pad(b, ((0, ep - e), (0, k - kb)), constant_values=SENTINEL)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_pallas:
        out = intersect_count_ref(a, b)
    else:
        out = intersect_count_pallas(a, b, be=be, interpret=interpret)
    return out[:e]
