"""jit'd public wrapper for the leapfrog-intersection kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ledger

from .kernel import SENTINEL, intersect_count_pallas
from .ref import intersect_count_ref

# distinct padded shape signatures seen so far — one jit program each.
# Power-of-two bucketing below bounds this at O(log E · log K) per lane
# instead of one program per exact padded shape; GIL-atomic set.add keeps
# it safe under the multi-worker box scheduler.
_shape_signatures: set = set()


def _pow2(n: int, lo: int = 1) -> int:
    return max(lo, 1 << int(np.ceil(np.log2(max(1, n)))))


def jit_cache_info() -> int:
    """Number of distinct compiled-program shape signatures
    (kernel_bench reports this so cache growth is visible in CI)."""
    return len(_shape_signatures)


def intersect_count(a, b, *, be: int = 256, use_pallas: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Per-row sorted-set intersection counts |a_i ∩ b_i|.

    Pads rows with SENTINEL to a power-of-two lane count (>= 128) and the
    row count to a power-of-two multiple of ``be`` — bucketed shapes, so
    the jit cache holds O(log E · log K) programs instead of one per
    exact padded shape (the ``core/executor.py`` bucketing idiom).
    Padded rows return 0 and are stripped. ``be`` shrinks for small
    batches so a per-box call from the triangle engine never pads a
    handful of edges up to a full 256-row tile."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    e, ka = a.shape
    kb = b.shape[1]
    k = _pow2(max(ka, kb, 1), lo=128)
    be = min(be, _pow2(max(e, 1), lo=8))      # both pow2 -> ep % be == 0
    ep = _pow2(max(e, 1), lo=be)
    a = jnp.pad(a, ((0, ep - e), (0, k - ka)), constant_values=SENTINEL)
    b = jnp.pad(b, ((0, ep - e), (0, k - kb)), constant_values=SENTINEL)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _shape_signatures.add((ep, k, be, bool(use_pallas), bool(interpret)))
    if not use_pallas:
        out = intersect_count_ref(a, b)
    else:
        out = intersect_count_pallas(a, b, be=be, interpret=interpret)
    ledger.note(1, bytes_in=2 * ep * k * 4, bytes_out=ep * 4)
    return out[:e]


def _pad_rows(off: np.ndarray, vals: np.ndarray, pos: np.ndarray,
              k: int) -> np.ndarray:
    """(len(pos), k) SENTINEL-padded value rows gathered from compact CSR
    (``off``/``vals``) at key positions ``pos``."""
    off = np.asarray(off, dtype=np.int64)
    deg = np.diff(off)[pos]
    out = np.full((len(pos), k), SENTINEL, dtype=np.int32)
    total = int(deg.sum())
    if total:
        idx = np.repeat(off[:-1][pos], deg) \
            + np.arange(total, dtype=np.int64) \
            - np.repeat(np.cumsum(deg) - deg, deg)
        rr = np.repeat(np.arange(len(pos)), deg)
        cc = np.arange(total, dtype=np.int64) \
            - np.repeat(np.cumsum(deg) - deg, deg)
        out[rr, cc] = vals[idx]
    return out


def intersect_count_rows(off_a, vals_a, pos_a, off_b, vals_b, pos_b, *,
                         use_pallas: bool = True,
                         interpret: bool | None = None,
                         chunk: int = 8192) -> int:
    """Σ_i |row_a(pos_a[i]) ∩ row_b(pos_b[i])| from two compact-CSR
    relations — the generic QueryEngine's lowering of its innermost
    two-variable leapfrog onto this kernel.

    Rows are gathered host-side into SENTINEL-padded tiles and fed to
    ``intersect_count`` in ``chunk``-row batches, so device memory is
    O(chunk · K_box) regardless of the binding-frontier size. Returns the
    total as a Python int (per-pair counts never leave the device loop).
    """
    import jax.numpy as jnp

    pos_a = np.asarray(pos_a, dtype=np.int64)
    pos_b = np.asarray(pos_b, dtype=np.int64)
    if len(pos_a) == 0:
        return 0
    deg_a = np.diff(np.asarray(off_a, dtype=np.int64))
    deg_b = np.diff(np.asarray(off_b, dtype=np.int64))
    ka = int(deg_a[pos_a].max(initial=1))
    kb = int(deg_b[pos_b].max(initial=1))
    total = 0
    for s in range(0, len(pos_a), chunk):
        pa, pb = pos_a[s:s + chunk], pos_b[s:s + chunk]
        a = _pad_rows(off_a, vals_a, pa, ka)
        b = _pad_rows(off_b, vals_b, pb, kb)
        out = intersect_count(a, b, use_pallas=use_pallas,
                              interpret=interpret)
        total += int(jnp.sum(out))
    return total
