"""Pure-jnp oracle for the vectorized leapfrog-intersection kernel.

Inputs: two (E, K) int32 matrices of sorted, SENTINEL-padded neighbor rows.
Output: (E,) int32 per-row intersection sizes |a_i ∩ b_i|.

This is the batched form of the paper's leapfrog join at trie level z
(Alg. 1 line 3): probing each element of the x-row into the sorted y-row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.iinfo(np.int32).max


def intersect_count_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    def row(a_row, b_row):
        pos = jnp.clip(jnp.searchsorted(b_row, a_row), 0, b_row.shape[0] - 1)
        hit = (b_row[pos] == a_row) & (a_row != SENTINEL)
        return jnp.sum(hit.astype(jnp.int32))

    return jax.vmap(row)(a, b)
