"""Per-box device-invocation / transfer-bytes ledger for the kernel lanes.

The fused-megakernel PR makes a quantitative claim — one device dispatch
per box instead of one per frontier level — so the dispatch count has to
be *measured*, not asserted. Every kernel wrapper (``kernels/intersect``,
``kernels/lftj_fused``) calls :func:`note` once per device program it
launches, with the padded host→device and device→host byte counts it
shipped. Executors attach a :class:`KernelLedger` around each box's join
and fold the totals into ``EngineStats`` / ``QueryStats``.

The attachment is thread-local so the multi-worker box scheduler's
concurrent joins each see only their own box's launches; ledgers nest
(an outer run-level ledger and an inner per-box one both accumulate), and
:func:`note` is a no-op when nothing is attached, so the kernels stay
usable standalone.
"""

from __future__ import annotations

import threading
from typing import List, Optional


class KernelLedger:
    """Accumulated device launches and padded transfer bytes."""

    __slots__ = ("invocations", "bytes_in", "bytes_out")

    def __init__(self) -> None:
        self.invocations = 0
        self.bytes_in = 0
        self.bytes_out = 0

    @property
    def transfer_bytes(self) -> int:
        return self.bytes_in + self.bytes_out


_tls = threading.local()


def _stack() -> List[KernelLedger]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


class attach:
    """Context manager scoping kernel launches to ``ledger`` (current
    thread only). ``with attach() as kl: ...`` creates a fresh ledger.
    Passing ``tracer=`` additionally mirrors every :func:`note` inside
    the scope as a ``kernel.launch`` instant event on that tracer (the
    observability layer's per-launch timeline marks)."""

    def __init__(self, ledger: Optional[KernelLedger] = None, tracer=None):
        self.ledger = ledger if ledger is not None else KernelLedger()
        self.tracer = tracer

    def __enter__(self) -> KernelLedger:
        _stack().append((self.ledger, self.tracer))
        return self.ledger

    def __exit__(self, *exc) -> bool:
        _stack().pop()
        return False


def note(invocations: int = 1, bytes_in: int = 0, bytes_out: int = 0) -> None:
    """Record ``invocations`` device launches on every attached ledger
    (and emit a ``kernel.launch`` trace event per tracer-carrying
    attachment)."""
    for kl, tracer in _stack():
        kl.invocations += invocations
        kl.bytes_in += bytes_in
        kl.bytes_out += bytes_out
        if tracer is not None:
            tracer.event("kernel.launch", invocations=invocations,
                         bytes_in=bytes_in, bytes_out=bytes_out)
