"""Pure-jnp oracle for the embedding-bag kernel (gather + segment-sum).

JAX has no native EmbeddingBag (kernel_taxonomy §B.6): the reference is
``jnp.take`` over the table followed by a masked sum over the bag axis.

Inputs:
  table   (V, D)      embedding table
  idx     (B, L)      per-bag indices, PAD (= V) marks empty slots
  weights (B, L) opt  per-sample weights
Output:
  (B, D) bag sums.
"""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray,
                      weights: jnp.ndarray | None = None) -> jnp.ndarray:
    v = table.shape[0]
    safe = jnp.minimum(idx, v - 1)
    gathered = jnp.take(table, safe, axis=0)            # (B, L, D)
    mask = (idx < v).astype(table.dtype)[..., None]
    if weights is not None:
        mask = mask * weights[..., None].astype(table.dtype)
    return jnp.sum(gathered * mask, axis=1)
