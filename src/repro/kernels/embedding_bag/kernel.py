"""Pallas TPU kernels: EmbeddingBag (ragged gather + bag-sum).

Two TPU-native formulations (DESIGN.md §2 / kernel_taxonomy B.6):

1. ``embedding_bag_pallas_dma`` — the table stays in HBM (ANY memory
   space); bag indices are scalar-prefetched into SMEM; the kernel issues
   per-row async DMAs HBM→VMEM and accumulates bag sums in VMEM. This is
   the sparse-access-dominant regime (V·D ≫ VMEM): exactly the paper's
   *slice provisioning* pattern — only the touched rows move, charged at
   row granularity (cf. TrieArray slices, Prop. 7).

2. ``embedding_bag_pallas_onehot`` — MXU formulation for the per-device
   sub-table after vocab sharding (V_shard·D ≤ VMEM budget): bag-block ×
   vocab-block one-hot matmul, grid-accumulated. Dense flops for sparse
   work, but at 197 TFLOP/s the crossover sits near V_shard ≈ 64k for
   L = 64 (napkin math in EXPERIMENTS.md §Perf).

Both validated against ref.py in interpret mode; ops.py picks by shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# 1. HBM row-DMA formulation
# ---------------------------------------------------------------------------

def _bag_dma_kernel(idx_ref, table_ref, out_ref, row_buf, sem, *, bb, ll, v):
    i = pl.program_id(0)

    def bag_body(bi, _):
        def slot_body(si, acc):
            ix = idx_ref[i * bb + bi, si]
            safe = jnp.minimum(ix, v - 1)
            cp = pltpu.make_async_copy(table_ref.at[safe], row_buf, sem)
            cp.start()
            cp.wait()
            take = (ix < v).astype(table_ref.dtype)
            return acc + take * row_buf[...]

        acc0 = jnp.zeros(out_ref.shape[1:], out_ref.dtype)
        out_ref[bi, :] = jax.lax.fori_loop(0, ll, slot_body, acc0)
        return 0

    jax.lax.fori_loop(0, bb, bag_body, 0)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def embedding_bag_pallas_dma(table: jnp.ndarray, idx: jnp.ndarray,
                             bb: int = 8, interpret: bool = False) -> jnp.ndarray:
    """table (V, D) in HBM; idx (B, L) int32 with PAD == V. B % bb == 0."""
    v, d = table.shape
    b, ll = idx.shape
    assert b % bb == 0, (b, bb)
    grid = (b // bb,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],      # table stays in HBM
        out_specs=pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((d,), table.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        functools.partial(_bag_dma_kernel, bb=bb, ll=ll, v=v),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx, table)


# ---------------------------------------------------------------------------
# 2. one-hot MXU formulation
# ---------------------------------------------------------------------------

def _bag_onehot_kernel(idx_ref, table_ref, out_ref, *, nsteps_v, bv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                                   # (bb, L) int32
    tab = table_ref[...]                                 # (bv, D)
    base = j * bv
    # one-hot of the local vocab window: (bb, L, bv) contracted on (L, bv)
    local = idx - base
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bv), 2)
    onehot = (local[..., None] == iota).astype(tab.dtype)  # (bb, L, bv)
    bag_hist = jnp.sum(onehot, axis=1)                   # (bb, bv) multi-hot counts
    out_ref[...] += jax.lax.dot_general(
        bag_hist, tab, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bv", "interpret"))
def embedding_bag_pallas_onehot(table: jnp.ndarray, idx: jnp.ndarray,
                                bb: int = 128, bv: int = 512,
                                interpret: bool = False) -> jnp.ndarray:
    """table (V, D) with V % bv == 0; idx (B, L) with PAD >= V; B % bb == 0."""
    v, d = table.shape
    b, ll = idx.shape
    assert b % bb == 0 and v % bv == 0, (b, v, bb, bv)
    grid = (b // bb, v // bv)
    return pl.pallas_call(
        functools.partial(_bag_onehot_kernel, nsteps_v=grid[1], bv=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, ll), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx, table)
