"""jit'd public wrapper for the EmbeddingBag kernels (pad + dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import embedding_bag_pallas_dma, embedding_bag_pallas_onehot
from .ref import embedding_bag_ref


def embedding_bag(table, idx, *, use_pallas: bool = True,
                  mode: str = "auto", interpret: bool | None = None):
    """Bag-sum embedding lookup. idx uses PAD == table.shape[0].

    mode: 'dma' (HBM row gather), 'onehot' (MXU), 'auto' (by table size)."""
    table = jnp.asarray(table)
    idx = jnp.asarray(idx, jnp.int32)
    v, d = table.shape
    b, ll = idx.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_pallas:
        return embedding_bag_ref(table, idx)
    if mode == "auto":
        mode = "onehot" if v * d * table.dtype.itemsize <= (1 << 22) else "dma"
    if mode == "onehot":
        bv = 512 if v >= 512 else int(np.ceil(v / 8)) * 8
        vp = int(np.ceil(v / bv)) * bv
        bb = min(128, b) if b % min(128, b) == 0 else 1
        bp = int(np.ceil(b / bb)) * bb
        tab = jnp.pad(table, ((0, vp - v), (0, 0)))
        # PAD indices (== v) must fall outside every vocab window: send to vp
        ix = jnp.where(idx >= v, vp + 1, idx)
        ix = jnp.pad(ix, ((0, bp - b), (0, 0)), constant_values=vp + 1)
        out = embedding_bag_pallas_onehot(tab, ix, bb=bb, bv=bv,
                                          interpret=interpret)
        return out[:b]
    if mode == "dma":
        bb = 8 if b % 8 == 0 else 1
        bp = int(np.ceil(b / bb)) * bb
        ix = jnp.pad(idx, ((0, bp - b), (0, 0)), constant_values=v)
        out = embedding_bag_pallas_dma(table, ix, bb=bb, interpret=interpret)
        return out[:b]
    raise ValueError(mode)
